//! Bench: regenerate Figure 3 (scaled grid). `cargo bench --bench fig3`.
//!
//! Full-scale run: `cargo run --release -- fig3` (see README). Here a
//! reduced grid keeps `cargo bench` within minutes while exercising the
//! identical code path and printing the same stacked-bar report.

use kube_packd::harness::figures;
use kube_packd::harness::grid::GridConfig;
use kube_packd::util::bench::Bencher;

fn main() {
    let cfg = GridConfig {
        nodes: vec![4, 8],
        pods_per_node: vec![4],
        priority_tiers: vec![1, 2],
        usage: vec![1.0, 1.05],
        timeouts: vec![0.1, 0.3],
        instances: 4,
        max_gen_attempts: 200,
        verbose: false,
        ..Default::default()
    };
    let out = std::env::temp_dir().join("kp-bench-fig3");
    std::fs::create_dir_all(&out).unwrap();
    let out = out.to_str().unwrap().to_string();

    let b = Bencher::heavy();
    let mut last = String::new();
    b.run("fig3/reduced-grid", || {
        last = figures::fig3(&cfg, &out).unwrap();
    });
    println!("{last}");
}
