//! Bench: default-scheduler throughput (scheduling cycles per second).
//!
//! The paper's design requires the default path to dwarf solver cost;
//! this bench verifies the L3 scheduler is nowhere near the bottleneck.

use kube_packd::simulator::KwokSimulator;
use kube_packd::util::bench::{black_box, Bencher};
use kube_packd::workload::{GenParams, Instance};

fn main() {
    let b = Bencher::new(2, 10, std::time::Duration::from_secs(30));

    for (nodes, ppn) in [(8usize, 8usize), (32, 8), (32, 16)] {
        let inst = Instance::generate(
            GenParams {
                nodes,
                pods_per_node: ppn,
                priority_tiers: 4,
                usage: 0.95,
            },
            7,
        );
        let pods = inst.pods.len();
        let m = b.run(&format!("scheduler/drain-n{nodes}-p{pods}"), || {
            let mut sim = KwokSimulator::new(3);
            let (state, res) = sim.run(inst.nodes.clone(), inst.pods.clone());
            black_box((state.placed_count(), res.bound))
        });
        println!(
            "  -> ~{:.0} scheduling cycles/sec",
            pods as f64 / m.median_s
        );
    }
}
