//! Bench: CP solver micro-benchmarks — time-to-optimal on packing models
//! of increasing size, plus propagation throughput.

use kube_packd::cluster::ClusterState;
use kube_packd::optimizer::algorithm::{optimize, OptimizerConfig};
use kube_packd::simulator::KwokSimulator;
use kube_packd::solver::{solve_max, LinearExpr, Model, SolverConfig};
use kube_packd::telemetry::Deadline;
use kube_packd::util::bench::{black_box, Bencher};
use kube_packd::util::rng::Rng;
use kube_packd::workload::{GenParams, Instance};

/// Build a pure packing model (pods × nodes) from a generated instance.
fn packing_model(inst: &Instance) -> (Model, LinearExpr) {
    let mut m = Model::new();
    let mut vars = Vec::new();
    for _ in &inst.pods {
        let xs = m.new_vars(inst.nodes.len());
        m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
        vars.push(xs);
    }
    let mut cpu_class = Vec::new();
    let mut ram_class = Vec::new();
    for (j, n) in inst.nodes.iter().enumerate() {
        cpu_class.push(m.next_constraint_index());
        m.add_le(
            LinearExpr::of(vars.iter().zip(&inst.pods).map(|(xs, p)| (xs[j], p.request.cpu))),
            n.capacity.cpu,
        );
        ram_class.push(m.next_constraint_index());
        m.add_le(
            LinearExpr::of(vars.iter().zip(&inst.pods).map(|(xs, p)| (xs[j], p.request.ram))),
            n.capacity.ram,
        );
    }
    m.add_resource_class(cpu_class);
    m.add_resource_class(ram_class);
    let obj = LinearExpr::of(vars.iter().flatten().map(|&v| (v, 1)));
    (m, obj)
}

fn main() {
    let b = Bencher::new(1, 8, std::time::Duration::from_secs(30));
    let mut rng = Rng::new(42);

    for (nodes, ppn) in [(4, 4), (8, 4), (8, 8), (16, 4)] {
        let inst = Instance::generate(
            GenParams {
                nodes,
                pods_per_node: ppn,
                priority_tiers: 1,
                usage: 1.0,
            },
            rng.next_u64(),
        );
        let (m, obj) = packing_model(&inst);
        b.run(&format!("solver/pack-n{nodes}-p{}", inst.pods.len()), || {
            let sol = solve_max(
                &m,
                &obj,
                Deadline::after(std::time::Duration::from_millis(500)),
                &SolverConfig::default(),
            );
            black_box(sol.objective)
        });
    }

    // Full Algorithm 1 on a challenging instance (the paper's real unit).
    for (nodes, tiers) in [(4usize, 2u32), (8, 2), (8, 4)] {
        let insts = Instance::generate_challenging(
            GenParams {
                nodes,
                pods_per_node: 4,
                priority_tiers: tiers,
                usage: 1.0,
            },
            1,
            rng.next_u64(),
            200,
        );
        let Some(inst) = insts.into_iter().next() else { continue };
        let mut sim = KwokSimulator::new(inst.params.p_max());
        let (state, _) = sim.run(inst.nodes.clone(), inst.pods.clone());
        let state: ClusterState = state;
        b.run(&format!("optimize/n{nodes}-t{tiers}-T0.5s"), || {
            black_box(optimize(
                &state,
                inst.params.p_max(),
                &OptimizerConfig::with_timeout(0.5),
            ))
        });
    }
}
