//! Bench: incremental solve sessions — cold vs warm solve cost on a
//! seeded churn trace, plus the no-op-delta replay microbenchmark.
//!
//! Emits machine-readable `BENCH_incremental.json` in the working
//! directory: one cell per (scenario, mode) with timing and session
//! reuse counters, and a determinism record asserting the warm run
//! reproduced the cold run's end metrics (the session contract: caching
//! changes how fast, never what).

use std::time::Duration;

use kube_packd::cluster::ClusterState;
use kube_packd::lifecycle::{run_churn, ChurnConfig, Policy, SweepConfig};
use kube_packd::optimizer::algorithm::{optimize, OptimizerConfig};
use kube_packd::optimizer::SolveSession;
use kube_packd::simulator::KwokSimulator;
use kube_packd::util::bench::{black_box, Bencher};
use kube_packd::util::json::Json;
use kube_packd::workload::{ChurnParams, ChurnTraceGenerator, GenParams, Instance};

fn main() {
    let b = Bencher::new(0, 3, Duration::from_secs(60));
    let mut cells: Vec<Json> = Vec::new();

    // ---- churn: the driver the session layer exists for -------------------
    let trace = ChurnTraceGenerator::new(
        ChurnParams {
            horizon_ms: 10_000,
            mean_arrival_ms: 800,
            mean_lifetime_ms: 6_000,
            ..ChurnParams::for_cluster(GenParams {
                nodes: 6,
                pods_per_node: 4,
                priority_tiers: 2,
                usage: 0.95,
            })
        },
        0xC01D,
    )
    .generate();
    let mut base = ChurnConfig::for_policy(Policy::FallbackSweep);
    base.sweep_every_ms = 1_000;
    base.fallback_timeout = Duration::from_secs(2);
    base.sweep = SweepConfig {
        optimizer: OptimizerConfig::with_timeout(2.0),
        eviction_budget: 8,
    };

    let mut cold_res = None;
    let m_cold = b.run("incremental/churn-cold", || {
        cold_res = Some(run_churn(&trace, &base));
    });
    let warm_cfg = ChurnConfig {
        incremental: true,
        ..base.clone()
    };
    let mut warm_res = None;
    let m_warm = b.run("incremental/churn-warm", || {
        warm_res = Some(run_churn(&trace, &warm_cfg));
    });
    let cold = cold_res.expect("cold churn ran");
    let warm = warm_res.expect("warm churn ran");
    let deterministic = cold.log.digest() == warm.log.digest()
        && cold.served_per_priority == warm.served_per_priority
        && cold.final_placed == warm.final_placed;
    println!(
        "  -> warm reuse: full={} solve={} component={} warm-seeds={} deterministic-match={}",
        warm.session_full_hits,
        warm.solve_cache_hits,
        warm.component_cache_hits,
        warm.warm_starts,
        deterministic
    );
    for (mode, m, r) in [("cold", &m_cold, &cold), ("warm", &m_warm, &warm)] {
        let mut cell = Json::obj();
        cell.set("scenario", "churn")
            .set("mode", mode)
            .set("mean_s", m.mean_s)
            .set("median_s", m.median_s)
            .set("min_s", m.min_s)
            .set("max_s", m.max_s)
            .set("solver_invocations", r.solver_invocations as u64)
            .set("sweeps_run", r.sweeps_run as u64)
            .set("session_full_hits", r.session_full_hits)
            .set("solve_cache_hits", r.solve_cache_hits)
            .set("component_cache_hits", r.component_cache_hits)
            .set("warm_starts", r.warm_starts);
        cells.push(cell);
    }

    // ---- resolve: cold first solve vs no-op-delta replay -------------------
    let insts = Instance::generate_challenging(
        GenParams {
            nodes: 8,
            pods_per_node: 4,
            priority_tiers: 2,
            usage: 1.0,
        },
        1,
        0xBEEF,
        300,
    );
    if let Some(inst) = insts.first() {
        let p_max = inst.params.p_max();
        let mut sim = KwokSimulator::new(p_max);
        let (state, _): (ClusterState, _) = sim.run(inst.nodes.clone(), inst.pods.clone());
        // Generous window: the no-op replay only arms off a fully
        // certified run, and byte-identity is only contractual for
        // solves that complete in-window.
        let cfg = OptimizerConfig::with_timeout(10.0);

        let m_first = b.run("incremental/resolve-cold", || {
            black_box(optimize(&state, p_max, &cfg));
        });
        let mut session = SolveSession::new();
        let reference = session.solve(&state, p_max, &cfg);
        let certified = reference.as_ref().is_some_and(|r| r.proved_optimal);
        let m_noop = b.run("incremental/resolve-noop", || {
            let replay = session.solve(&state, p_max, &cfg);
            if certified {
                assert_eq!(
                    replay.as_ref().map(|r| &r.target),
                    reference.as_ref().map(|r| &r.target),
                    "replay must be byte-identical"
                );
            }
            black_box(replay);
        });
        println!(
            "  -> no-op replays: {} (optimizer runs stayed at {})",
            session.stats.full_hits, session.stats.optimizer_runs
        );
        for (mode, m) in [("cold", &m_first), ("noop", &m_noop)] {
            let mut cell = Json::obj();
            cell.set("scenario", "resolve")
                .set("mode", mode)
                .set("mean_s", m.mean_s)
                .set("median_s", m.median_s)
                .set("min_s", m.min_s)
                .set("max_s", m.max_s)
                .set("session_full_hits", session.stats.full_hits)
                .set("solve_cache_hits", session.cache_stats().solve_hits)
                .set("component_cache_hits", session.cache_stats().component_hits)
                .set("warm_starts", session.cache_stats().warm_seeds);
            cells.push(cell);
        }
    } else {
        println!("resolve scenario: no challenging instance generated; skipped");
    }

    let mut determinism = Json::obj();
    determinism
        .set("cold_digest", format!("{:016x}", cold.log.digest()))
        .set("warm_digest", format!("{:016x}", warm.log.digest()))
        .set("byte_identical", deterministic);

    let mut doc = Json::obj();
    doc.set("bench", "incremental")
        .set("schema", 1u64)
        .set(
            "host_threads",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as u64,
        )
        .set("trace_seed", 0xC01Du64)
        .set("determinism", determinism)
        .set("cells", Json::Arr(cells));
    std::fs::write("BENCH_incremental.json", doc.to_string_pretty())
        .expect("write BENCH_incremental.json");
    println!("wrote BENCH_incremental.json");
}
