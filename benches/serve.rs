//! Bench: scheduler-as-a-service — the serve daemon under closed- and
//! open-loop admission load over loopback.
//!
//! Emits machine-readable `BENCH_serve.json` in the working directory:
//! one cell per arrival mode with sustained admissions/sec and the
//! p50/p95/p99 decision-latency distribution (window batching
//! included), plus a determinism record asserting the reply stream and
//! final state digest of an in-process replay are byte-identical at 1
//! and 8 portfolio threads.
//!
//! Run with `--quick` (or env `BENCH_QUICK=1`) for the CI-sized
//! workload.

use kube_packd::server::loadgen::bench_document;
use kube_packd::util::json::Json;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let mut doc = bench_document(quick).expect("serve bench");
    doc.set(
        "host_threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as u64,
    );
    let det = doc.get("determinism").expect("determinism record");
    println!(
        "serve bench: {} cells, thread_independent={}",
        doc.get("cells").and_then(Json::as_arr).map(|c| c.len()).unwrap_or(0),
        det.get("thread_independent").and_then(Json::as_bool).unwrap_or(false)
    );
    for cell in doc.get("cells").and_then(Json::as_arr).cloned().unwrap_or_default() {
        println!(
            "  {:<10} {:>6} req  {:>8.1} adm/s  p50 {:>7.2}ms  p95 {:>7.2}ms  p99 {:>7.2}ms",
            cell.get("mode").and_then(Json::as_str).unwrap_or("?"),
            cell.get("requests").and_then(Json::as_i64).unwrap_or(0),
            cell.get("admissions_per_s").and_then(Json::as_f64).unwrap_or(0.0),
            cell.get("latency_p50_ms").and_then(Json::as_f64).unwrap_or(0.0),
            cell.get("latency_p95_ms").and_then(Json::as_f64).unwrap_or(0.0),
            cell.get("latency_p99_ms").and_then(Json::as_f64).unwrap_or(0.0),
        );
    }
    std::fs::write("BENCH_serve.json", doc.to_string_pretty()).expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json");
}
