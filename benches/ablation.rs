//! Bench: solver-feature ablations (DESIGN.md "Ablations").
//!
//! Measures time-to-optimal (or best-found-within-budget) with each
//! feature disabled in turn: objective bound, capacity bound, hints,
//! best-fit ordering, symmetry skipping, LNS. The paper reports
//! symmetry-breaking "did not improve the solving time" — compare the
//! `no-symmetry` row.

use kube_packd::optimizer::algorithm::{optimize, OptimizerConfig};
use kube_packd::simulator::KwokSimulator;
use kube_packd::solver::SolverConfig;
use kube_packd::util::bench::{black_box, Bencher};
use kube_packd::workload::{GenParams, Instance};

fn main() {
    let params = GenParams {
        nodes: 8,
        pods_per_node: 4,
        priority_tiers: 2,
        usage: 1.0,
    };
    let insts = Instance::generate_challenging(params, 3, 123, 300);
    if insts.is_empty() {
        println!("no challenging instances; nothing to ablate");
        return;
    }
    let states: Vec<_> = insts
        .iter()
        .map(|inst| {
            let mut sim = KwokSimulator::new(inst.params.p_max());
            let (state, _) = sim.run(inst.nodes.clone(), inst.pods.clone());
            state
        })
        .collect();

    let variants: Vec<(&str, SolverConfig)> = vec![
        ("full", SolverConfig::default()),
        (
            "no-bound",
            SolverConfig {
                use_bound: false,
                ..Default::default()
            },
        ),
        (
            "no-capacity-bound",
            SolverConfig {
                use_capacity_bound: false,
                ..Default::default()
            },
        ),
        (
            "no-hints",
            SolverConfig {
                use_hints: false,
                ..Default::default()
            },
        ),
        (
            "no-best-fit",
            SolverConfig {
                use_best_fit: false,
                ..Default::default()
            },
        ),
        (
            "no-symmetry",
            SolverConfig {
                use_symmetry: false,
                ..Default::default()
            },
        ),
        (
            "easiest-first",
            SolverConfig {
                branch_easiest_first: true,
                ..Default::default()
            },
        ),
        (
            "no-lns",
            SolverConfig {
                use_lns: false,
                ..Default::default()
            },
        ),
    ];

    let b = Bencher::new(0, 3, std::time::Duration::from_secs(60));
    for (name, solver) in variants {
        let cfg = OptimizerConfig {
            total_timeout: std::time::Duration::from_millis(400),
            alpha: 0.8,
            solver,
            ..Default::default()
        };
        let mut improved = 0usize;
        let mut proved = 0usize;
        b.run(&format!("ablation/{name}"), || {
            for (inst, state) in insts.iter().zip(&states) {
                if let Some(res) = optimize(state, inst.params.p_max(), &cfg) {
                    let base = state.placed_per_priority(inst.params.p_max());
                    if kube_packd::metrics::lex_better(&res.placed_per_priority, &base) {
                        improved += 1;
                    }
                    if res.proved_optimal {
                        proved += 1;
                    }
                    black_box(&res.target);
                }
            }
        });
        println!("  -> improved={improved} proved-optimal={proved} (across iterations)");
    }
}
