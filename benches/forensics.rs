//! Bench: solve-forensics overhead — the full optimiser on the same
//! instances with the probe off and armed. Arming must be close to
//! free (it only counts work the search already does), and the armed
//! pass additionally reports the attributed-effort ledger: how many
//! conflicts/propagations landed on a provenance slug, gap-timeline
//! samples, and folded-stack lines per scenario.
//!
//! Emits machine-readable `BENCH_forensics.json` in the working
//! directory: one cell per scenario with off/armed timings and the
//! attribution totals — the seed of the forensics trajectory.

use std::time::Duration;

use kube_packd::cluster::ClusterState;
use kube_packd::optimizer::algorithm::{optimize, optimize_probed, OptimizerConfig};
use kube_packd::simulator::KwokSimulator;
use kube_packd::solver::Probe;
use kube_packd::telemetry::Telemetry;
use kube_packd::util::bench::{black_box, Bencher};
use kube_packd::util::json::Json;
use kube_packd::workload::{ConstraintProfile, GenParams, Instance};

fn main() {
    let b = Bencher::new(0, 3, Duration::from_secs(45));
    let timeout_s = 1.0; // the paper's headline window
    let scenarios = [
        ("plain", ConstraintProfile::None),
        ("taints", ConstraintProfile::Taints),
        ("mixed", ConstraintProfile::Mixed),
    ];

    let mut cells: Vec<Json> = Vec::new();
    for (name, profile) in scenarios {
        let insts = Instance::generate_challenging_constrained(
            GenParams {
                nodes: 8,
                pods_per_node: 4,
                priority_tiers: 2,
                usage: 1.0,
            },
            2,
            0xF04E,
            300,
            profile,
        );
        if insts.is_empty() {
            println!("scenario {name}: no challenging instances; skipped");
            continue;
        }
        let states: Vec<(u32, ClusterState)> = insts
            .iter()
            .map(|inst| {
                let mut sim = KwokSimulator::new(inst.params.p_max());
                let (state, _) = sim.run(inst.nodes.clone(), inst.pods.clone());
                (inst.params.p_max(), state)
            })
            .collect();

        let cfg = OptimizerConfig::with_timeout(timeout_s);
        let m_off = b.run(&format!("forensics/{name}-off"), || {
            for (p_max, state) in &states {
                black_box(optimize(state, *p_max, &cfg));
            }
        });

        // Armed pass: fresh probe per instance (the serve daemon's
        // per-window discipline); the ledger is summed across them.
        let mut effort: Vec<(String, &'static str, u64)> = Vec::new();
        let mut gap_samples = 0usize;
        let mut folded_lines = 0usize;
        let m_armed = b.run(&format!("forensics/{name}-armed"), || {
            effort.clear();
            gap_samples = 0;
            folded_lines = 0;
            for (p_max, state) in &states {
                let prof = Probe::armed();
                black_box(optimize_probed(state, *p_max, &cfg, None, &Telemetry::off(), &prof));
                for (slug, kind, n) in prof.module_effort() {
                    match effort.iter().position(|(s, k, _)| *s == slug && *k == kind) {
                        Some(i) => effort[i].2 += n,
                        None => effort.push((slug, kind, n)),
                    }
                }
                gap_samples += prof.gap_samples().len();
                folded_lines += prof.export_folded().lines().count();
            }
        });

        let total = |kind: &str| -> u64 {
            effort.iter().filter(|(_, k, _)| *k == kind).map(|r| r.2).sum()
        };
        let conflicts = total("conflicts");
        let propagations = total("propagations");
        println!(
            "  -> module-rows={} conflicts={conflicts} propagations={propagations} \
             gap-samples={gap_samples} folded-lines={folded_lines}",
            effort.len()
        );

        let mut cell = Json::obj();
        cell.set("scenario", name)
            .set("instances", states.len())
            .set("off_mean_s", m_off.mean_s)
            .set("armed_mean_s", m_armed.mean_s)
            .set(
                "overhead_pct",
                if m_off.mean_s > 0.0 {
                    (m_armed.mean_s / m_off.mean_s - 1.0) * 100.0
                } else {
                    0.0
                },
            )
            .set("module_rows", effort.len())
            .set("attributed_conflicts", conflicts)
            .set("attributed_propagations", propagations)
            .set("gap_samples", gap_samples)
            .set("folded_lines", folded_lines);
        cells.push(cell);
    }

    let mut doc = Json::obj();
    doc.set("bench", "forensics")
        .set("schema", 1u64)
        .set(
            "host_threads",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        )
        .set("timeout_s", timeout_s)
        .set("cells", Json::Arr(cells));
    std::fs::write("BENCH_forensics.json", doc.to_string_pretty())
        .expect("write BENCH_forensics.json");
    println!("wrote BENCH_forensics.json");
}
