//! Bench: CP-driven autoscaling — elastic vs static churn on an
//! overloaded seeded trace, plus the provisioning-solve microbench.
//!
//! Emits machine-readable `BENCH_autoscaler.json` in the working
//! directory: one cell per (scenario, mode) with timing and autoscaler
//! counters, and a determinism record asserting scale decisions are
//! identical at 1 and 8 portfolio threads (the certificate contract:
//! decisions are proofs, so they replay).

use std::time::Duration;

use kube_packd::autoscaler::{plan_provisioning, AutoscaleConfig, NodePool, ProvisionOutcome};
use kube_packd::cluster::ClusterState;
use kube_packd::lifecycle::{run_churn, ChurnConfig, ChurnResult, Policy, SweepConfig};
use kube_packd::optimizer::{constraints::ModuleRegistry, OptimizerConfig};
use kube_packd::portfolio::PortfolioConfig;
use kube_packd::solver::SolverConfig;
use kube_packd::telemetry::{Deadline, Telemetry};
use kube_packd::util::bench::{black_box, Bencher};
use kube_packd::util::json::Json;
use kube_packd::workload::{ChurnParams, ChurnTraceGenerator, GenParams, Instance};

fn churn_cfg(autoscale: bool, threads: usize) -> ChurnConfig {
    ChurnConfig {
        policy: Policy::FallbackSweep,
        sweep_every_ms: 2_000,
        sweep: SweepConfig {
            optimizer: OptimizerConfig::with_timeout(2.0).with_threads(threads),
            eviction_budget: 8,
        },
        fallback_timeout: Duration::from_secs(2),
        fallback_portfolio: PortfolioConfig::with_threads(threads),
        incremental: false,
        autoscale: autoscale.then(|| AutoscaleConfig {
            pools: NodePool::standard_mix(),
            provision_timeout: Duration::from_secs(2),
            max_removals: 2,
            ..AutoscaleConfig::default()
        }),
    }
}

fn churn_cell(scenario: &str, mode: &str, m: &kube_packd::util::bench::Measurement, r: &ChurnResult) -> Json {
    let mut cell = Json::obj();
    cell.set("scenario", scenario)
        .set("mode", mode)
        .set("mean_s", m.mean_s)
        .set("median_s", m.median_s)
        .set("min_s", m.min_s)
        .set("max_s", m.max_s)
        .set("served_total", r.served_total() as u64)
        .set("final_pending", r.final_pending as u64)
        .set("final_ready_nodes", r.final_ready_nodes as u64)
        .set("autoscale", r.autoscale.to_json());
    cell
}

fn main() {
    let b = Bencher::new(0, 3, Duration::from_secs(90));
    let mut cells: Vec<Json> = Vec::new();

    // ---- elastic vs static churn on an overloaded trace -------------------
    let trace = ChurnTraceGenerator::new(
        ChurnParams {
            horizon_ms: 8_000,
            mean_arrival_ms: 700,
            mean_lifetime_ms: 3_000,
            drain_chance: 0.0,
            join_chance: 0.0,
            ..ChurnParams::for_cluster(GenParams {
                nodes: 4,
                pods_per_node: 4,
                priority_tiers: 2,
                usage: 1.15,
            })
        },
        0xE1A5,
    )
    .generate();

    let mut static_res = None;
    let m_static = b.run("autoscaler/churn-static", || {
        static_res = Some(run_churn(&trace, &churn_cfg(false, 1)));
    });
    let mut elastic_res = None;
    let m_elastic = b.run("autoscaler/churn-elastic", || {
        elastic_res = Some(run_churn(&trace, &churn_cfg(true, 1)));
    });
    let static_run = static_res.expect("static churn ran");
    let elastic = elastic_res.expect("elastic churn ran");
    println!(
        "  -> elastic: +{} nodes (cost {}), -{} consolidated, served {} vs {} static, pending {} vs {}",
        elastic.autoscale.nodes_added,
        elastic.autoscale.cost_added,
        elastic.autoscale.nodes_removed,
        elastic.served_total(),
        static_run.served_total(),
        elastic.final_pending,
        static_run.final_pending,
    );
    cells.push(churn_cell("churn", "static", &m_static, &static_run));
    cells.push(churn_cell("churn", "elastic", &m_elastic, &elastic));

    // Determinism record: identical decisions at 1 and 8 threads —
    // asserted, not just recorded (scale decisions are certificates, so
    // divergence is a bug, not noise).
    let t8 = run_churn(&trace, &churn_cfg(true, 8));
    let thread_independent =
        t8.log.digest() == elastic.log.digest() && t8.autoscale == elastic.autoscale;
    assert!(
        thread_independent,
        "autoscale decisions diverged between 1 and 8 threads: digests {:016x} vs {:016x}",
        elastic.log.digest(),
        t8.log.digest()
    );

    // ---- provisioning microbench: certified min-cost from scratch ----------
    let inst = Instance::generate(
        GenParams {
            nodes: 8,
            pods_per_node: 4,
            priority_tiers: 1,
            usage: 1.0,
        },
        0xBEEF,
    );
    let empty = ClusterState::new(Vec::new(), inst.pods.clone());
    let pending: Vec<_> = empty.pending_pods();
    let pools = vec![NodePool::new("std", 1000, 1)];
    let reference = inst.reference_capacity;
    let mut certified = false;
    let mut provisioned = 0usize;
    let m_prov = b.run("autoscaler/provision-from-scratch", || {
        let out = plan_provisioning(
            &empty,
            &pending,
            &pools,
            reference,
            pending.len(),
            Deadline::after(Duration::from_secs(30)),
            &SolverConfig::default(),
            &PortfolioConfig::default(),
            &ModuleRegistry::standard(),
            &Telemetry::off(),
        );
        if let ProvisionOutcome::Plan(p) = &out {
            certified = p.certified();
            provisioned = p.node_count;
        }
        black_box(out);
    });
    println!("  -> from-scratch fleet: {provisioned} nodes, certified={certified}");
    let mut cell = Json::obj();
    cell.set("scenario", "provision")
        .set("mode", "from-scratch")
        .set("mean_s", m_prov.mean_s)
        .set("median_s", m_prov.median_s)
        .set("min_s", m_prov.min_s)
        .set("max_s", m_prov.max_s)
        .set("pods", pending.len() as u64)
        .set("nodes_provisioned", provisioned as u64)
        .set("certified", certified);
    cells.push(cell);

    let mut determinism = Json::obj();
    determinism
        .set("t1_digest", format!("{:016x}", elastic.log.digest()))
        .set("t8_digest", format!("{:016x}", t8.log.digest()))
        .set("thread_independent", thread_independent);

    let mut doc = Json::obj();
    doc.set("bench", "autoscaler")
        .set("schema", 1u64)
        .set(
            "host_threads",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as u64,
        )
        .set("trace_seed", 0xE1A5u64)
        .set("determinism", determinism)
        .set("cells", Json::Arr(cells));
    std::fs::write("BENCH_autoscaler.json", doc.to_string_pretty())
        .expect("write BENCH_autoscaler.json");
    println!("wrote BENCH_autoscaler.json");
}
