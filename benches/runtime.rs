//! Bench: PJRT scorer latency — single-row vs whole-batch execution,
//! against the native scorer. Quantifies the amortisation the batch
//! formulation buys (DESIGN.md §Perf, Runtime).

use kube_packd::cluster::ClusterState;
use kube_packd::runtime::{NativeScorer, XlaScorer};
use kube_packd::scheduler::default::BatchScorer;
use kube_packd::util::bench::{black_box, Bencher};
use kube_packd::workload::{GenParams, Instance};

fn main() {
    let b = Bencher::new(3, 20, std::time::Duration::from_secs(20));

    let inst = Instance::generate(
        GenParams {
            nodes: 32,
            pods_per_node: 8,
            priority_tiers: 1,
            usage: 1.0,
        },
        11,
    );
    let state = ClusterState::new(inst.nodes.clone(), inst.pods.clone());
    let pending = state.pending_pods();
    println!("cluster: {} nodes, {} pending pods", inst.nodes.len(), pending.len());

    let mut native = NativeScorer;
    b.run("scorer/native-row", || {
        black_box(native.score_row(&state, pending[0]))
    });
    b.run("scorer/native-matrix-256", || {
        black_box(native.score_matrix(&state, &pending))
    });

    match XlaScorer::from_artifacts() {
        Ok(mut xla) => {
            b.run("scorer/xla-row (1 pod padded to 64)", || {
                black_box(xla.score_row(&state, pending[0]))
            });
            b.run("scorer/xla-matrix-256 (one execute)", || {
                black_box(xla.score_matrix(&state, &pending))
            });
            println!("  total PJRT executions: {}", xla.executions);
        }
        Err(e) => println!("skipping XLA benches: {e:#}"),
    }
}
