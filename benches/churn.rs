//! Bench: steady-state lifecycle throughput (simulated events/sec).
//!
//! Measures the discrete-event loop itself (default-only policy: no CP
//! solver in the hot path), so later PRs can track scheduling-loop
//! regressions in BENCH_*.json without solver-timeout noise. A second
//! pass reports the fallback+sweep policy for context.

use kube_packd::lifecycle::{run_churn, ChurnConfig, Policy, SweepConfig};
use kube_packd::optimizer::algorithm::OptimizerConfig;
use kube_packd::portfolio::PortfolioConfig;
use kube_packd::util::bench::{black_box, Bencher};
use kube_packd::workload::churn::{ChurnParams, ChurnTraceGenerator};
use kube_packd::workload::GenParams;

fn main() {
    let b = Bencher::new(1, 5, std::time::Duration::from_secs(60));

    for nodes in [8usize, 16, 32] {
        let params = ChurnParams::for_cluster(GenParams {
            nodes,
            pods_per_node: 4,
            priority_tiers: 2,
            usage: 0.90,
        });
        let trace = ChurnTraceGenerator::new(params, 7).generate();
        let cfg = ChurnConfig::for_policy(Policy::DefaultOnly);
        let events = run_churn(&trace, &cfg).events_processed;

        let m = b.run(&format!("churn/default-only-n{nodes}-ev{events}"), || {
            black_box(run_churn(&trace, &cfg).events_processed)
        });
        println!("  -> ~{:.0} simulated events/sec", events as f64 / m.median_s);
    }

    // Context: one fallback+sweep run at the acceptance-criterion scale.
    let params = ChurnParams::for_cluster(GenParams {
        nodes: 16,
        pods_per_node: 4,
        priority_tiers: 2,
        usage: 0.95,
    });
    let trace = ChurnTraceGenerator::new(params, 42).generate();
    let cfg = ChurnConfig {
        policy: Policy::FallbackSweep,
        sweep_every_ms: 5_000,
        sweep: SweepConfig {
            optimizer: OptimizerConfig::with_timeout(0.5),
            eviction_budget: 8,
        },
        fallback_timeout: std::time::Duration::from_millis(500),
        fallback_portfolio: PortfolioConfig::default(),
        incremental: false,
        autoscale: None,
    };
    let heavy = Bencher::heavy();
    let events = run_churn(&trace, &cfg).events_processed;
    let m = heavy.run(&format!("churn/fallback-sweep-n16-ev{events}"), || {
        black_box(run_churn(&trace, &cfg).events_processed)
    });
    println!("  -> ~{:.0} simulated events/sec", events as f64 / m.median_s);
}
