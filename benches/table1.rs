//! Bench: regenerate Table 1 (scaled). `cargo bench --bench table1`.

use kube_packd::harness::figures;
use kube_packd::harness::grid::GridConfig;
use kube_packd::util::bench::Bencher;

fn main() {
    let cfg = GridConfig {
        nodes: vec![4, 8],
        pods_per_node: vec![4, 8],
        priority_tiers: vec![4],
        usage: vec![0.95, 1.00],
        timeouts: vec![0.5],
        instances: 4,
        max_gen_attempts: 200,
        verbose: false,
        ..Default::default()
    };
    let out = std::env::temp_dir().join("kp-bench-table1");
    std::fs::create_dir_all(&out).unwrap();
    let out = out.to_str().unwrap().to_string();

    let b = Bencher::heavy();
    let mut last = String::new();
    b.run("table1/duration-and-deltas", || {
        last = figures::table1(&cfg, &out).unwrap();
    });
    println!("{last}");
}
