//! Bench: portfolio speedup — full Algorithm 1 wall-clock and tiers
//! certified optimal at 1/2/4/8 workers, on the paper's plain workload
//! and on constraint-rich (genuinely decomposable) scenarios.
//!
//! Emits machine-readable `BENCH_portfolio.json` in the working
//! directory: one cell per (scenario, threads) with timing and
//! certification counters — the seed of the bench trajectory.

use std::time::Duration;

use kube_packd::cluster::ClusterState;
use kube_packd::optimizer::algorithm::{optimize, OptimizerConfig};
use kube_packd::simulator::KwokSimulator;
use kube_packd::solver::SolveStatus;
use kube_packd::util::bench::{black_box, Bencher};
use kube_packd::util::json::Json;
use kube_packd::workload::{ConstraintProfile, GenParams, Instance};

fn main() {
    let b = Bencher::new(0, 3, Duration::from_secs(45));
    let timeout_s = 1.0; // the paper's headline window
    let scenarios = [
        ("plain", ConstraintProfile::None),
        ("taints", ConstraintProfile::Taints),
        ("mixed", ConstraintProfile::Mixed),
    ];

    let mut cells: Vec<Json> = Vec::new();
    for (name, profile) in scenarios {
        let insts = Instance::generate_challenging_constrained(
            GenParams {
                nodes: 8,
                pods_per_node: 4,
                priority_tiers: 2,
                usage: 1.0,
            },
            2,
            0xBEEF,
            300,
            profile,
        );
        if insts.is_empty() {
            println!("scenario {name}: no challenging instances; skipped");
            continue;
        }
        let states: Vec<(u32, ClusterState)> = insts
            .iter()
            .map(|inst| {
                let mut sim = KwokSimulator::new(inst.params.p_max());
                let (state, _) = sim.run(inst.nodes.clone(), inst.pods.clone());
                (inst.params.p_max(), state)
            })
            .collect();

        for threads in [1usize, 2, 4, 8] {
            let cfg = OptimizerConfig::with_timeout(timeout_s).with_threads(threads);
            let mut certified = 0u64;
            let mut improved = 0u64;
            let mut components = 0u64;
            let m = b.run(&format!("portfolio/{name}-t{threads}"), || {
                certified = 0;
                improved = 0;
                components = 0;
                for (p_max, state) in &states {
                    if let Some(res) = optimize(state, *p_max, &cfg) {
                        certified += res
                            .tiers
                            .iter()
                            .filter(|t| t.phase1_status == SolveStatus::Optimal)
                            .count() as u64;
                        if kube_packd::metrics::lex_better(
                            &res.placed_per_priority,
                            &state.placed_per_priority(*p_max),
                        ) {
                            improved += 1;
                        }
                        components += res.portfolio.components;
                        black_box(&res.target);
                    }
                }
            });
            println!(
                "  -> tiers-certified={certified} improved={improved} components={components}"
            );
            let mut cell = Json::obj();
            cell.set("scenario", name)
                .set("threads", threads)
                .set("instances", states.len())
                .set("mean_s", m.mean_s)
                .set("median_s", m.median_s)
                .set("min_s", m.min_s)
                .set("tiers_certified", certified)
                .set("improved", improved)
                .set("components", components);
            cells.push(cell);
        }
    }

    let mut doc = Json::obj();
    doc.set("bench", "portfolio")
        .set("schema", 1u64)
        .set(
            "host_threads",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        )
        .set("timeout_s", timeout_s)
        .set("cells", Json::Arr(cells));
    std::fs::write("BENCH_portfolio.json", doc.to_string_pretty())
        .expect("write BENCH_portfolio.json");
    println!("wrote BENCH_portfolio.json");
}
