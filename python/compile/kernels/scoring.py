"""L1 — Pallas batch scoring kernel.

Computes the (pods x nodes) feasibility-masked LeastAllocated score matrix
used by the L3 rust scheduler's scoring phase. See ``ref.py`` for the exact
semantics; this file is the tiled Pallas realisation.

TPU mapping (DESIGN.md §Hardware-Adaptation): the matrix is tiled over the
pod axis with ``BlockSpec``s — each grid step stages a (TP, 2) block of pod
requests plus the full (N, 2) node vectors into VMEM and emits a (TP, N)
output tile. All arithmetic is element-wise VPU work; VMEM footprint per
step is (TP*2 + N*4 + TP*N) * 4 bytes (~33 KiB at TP=128, N=32), far under
the ~16 MiB VMEM budget, so a single pass with no double buffering is the
right schedule. ``interpret=True`` everywhere: the CPU PJRT plugin cannot
execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import INFEASIBLE

# Default pod-axis tile. P must be padded to a multiple of this by callers
# (aot.py bakes padded shapes; the rust runtime pads before execute).
DEFAULT_TILE_P = 64


def _score_kernel(pod_ref, free_ref, cap_ref, out_ref):
    """One grid step: score a (TP, 2) pod block against all N nodes."""
    pod = pod_ref[...]  # [TP, 2]
    free = free_ref[...]  # [N, 2]
    cap = cap_ref[...]  # [N, 2]
    rem = free[None, :, :] - pod[:, None, :]  # [TP, N, 2]
    feasible = jnp.all(rem >= 0.0, axis=-1)  # [TP, N]
    denom = jnp.maximum(cap[None, :, :], 1.0)
    score = 100.0 * jnp.mean(rem / denom, axis=-1)
    out_ref[...] = jnp.where(feasible, score, INFEASIBLE)


@functools.partial(jax.jit, static_argnames=("tile_p",))
def score_pallas(pod_req, node_free, node_cap, *, tile_p=DEFAULT_TILE_P):
    """Pallas-tiled score matrix; numerics identical to ``ref.score_ref``.

    Args:
      pod_req:   f32[P, 2], P a multiple of ``tile_p`` (pad with zeros).
      node_free: f32[N, 2].
      node_cap:  f32[N, 2].
      tile_p:    pod-axis tile size.

    Returns:
      f32[P, N] score matrix.
    """
    p, _ = pod_req.shape
    n, _ = node_free.shape
    tile_p = min(tile_p, p)
    if p % tile_p != 0:
        raise ValueError(f"P={p} not a multiple of tile_p={tile_p}")
    grid = (p // tile_p,)
    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            # Pod block marches down the P axis with the grid index;
            # node vectors are re-staged whole each step (tiny: N*2 f32).
            pl.BlockSpec((tile_p, 2), lambda i: (i, 0)),
            pl.BlockSpec((n, 2), lambda i: (0, 0)),
            pl.BlockSpec((n, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_p, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, n), jnp.float32),
        interpret=True,
    )(pod_req, node_free, node_cap)
