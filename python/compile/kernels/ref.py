"""Pure-jnp oracle for the batch scoring kernel.

This module is the CORRECTNESS REFERENCE for the Pallas kernel in
``scoring.py`` and, transitively, for the Rust native scorer
(``rust/src/runtime/scorer.rs``), which mirrors the same arithmetic in f32.

Semantics (kube-scheduler ``NodeResourcesFit`` + ``LeastAllocated``):

  For pod *i* with resource request ``req[i] = (cpu, ram)`` and node *j*
  with free (unallocated) capacity ``free[j]`` and total capacity
  ``cap[j]``:

    remaining[i, j] = free[j] - req[i]                       (per resource)
    feasible[i, j]  = all(remaining[i, j] >= 0)
    score[i, j]     = 100 * mean_r(remaining[i, j, r] / max(cap[j, r], 1))
                      if feasible else -1.0

  ``score`` is kube-scheduler's LeastAllocated score in [0, 100]; -1 marks
  an infeasible (filtered-out) node. ``best[i]`` is the index of the first
  maximal score — with nodes pre-sorted lexicographically by name this is
  exactly the paper's deterministic tie-break plugin.
"""

import jax.numpy as jnp

INFEASIBLE = -1.0


def score_ref(pod_req, node_free, node_cap):
    """Reference score matrix.

    Args:
      pod_req:   f32[P, 2] resource requests (cpu_milli, ram_mib).
      node_free: f32[N, 2] free capacity per node.
      node_cap:  f32[N, 2] total capacity per node.

    Returns:
      f32[P, N] LeastAllocated scores, ``INFEASIBLE`` where the pod does
      not fit.
    """
    rem = node_free[None, :, :] - pod_req[:, None, :]  # [P, N, 2]
    feasible = jnp.all(rem >= 0.0, axis=-1)  # [P, N]
    denom = jnp.maximum(node_cap[None, :, :], 1.0)
    score = 100.0 * jnp.mean(rem / denom, axis=-1)
    return jnp.where(feasible, score, INFEASIBLE)


def best_node_ref(scores):
    """Index of the first maximal score per pod (deterministic tie-break)."""
    return jnp.argmax(scores, axis=-1).astype(jnp.int32)
