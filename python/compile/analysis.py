"""L1/L2 performance analysis (DESIGN.md §Perf).

Static analysis of the lowered scorer — no execution:

* XLA HLO cost analysis (flops / bytes accessed / peak memory) of the
  L2 graph per (P, N) variant;
* Pallas kernel VMEM footprint per grid step and the arithmetic
  intensity, from which the TPU roofline position is argued (this kernel
  is bandwidth-bound VPU work; MXU is idle by design).

Usage:  python -m compile.analysis   (from python/)
"""

import jax

from .aot import SHAPE_VARIANTS
from .kernels.scoring import DEFAULT_TILE_P
from .model import scorer_fn


def analyze(p: int, n: int) -> dict:
    f32 = jax.ShapeDtypeStruct((p, 2), jax.numpy.float32)
    nf = jax.ShapeDtypeStruct((n, 2), jax.numpy.float32)
    lowered = jax.jit(scorer_fn).lower(f32, nf, nf)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    # jax returns either a dict or a list of dicts depending on version
    if isinstance(cost, (list, tuple)):
        cost = cost[0]

    tile_p = min(DEFAULT_TILE_P, p)
    # VMEM residency per grid step (f32 = 4 bytes):
    #   pod block (tile_p, 2) + node free/cap (n, 2) x2 + out (tile_p, n)
    vmem_bytes = 4 * (tile_p * 2 + 2 * n * 2 + tile_p * n)
    hbm_bytes = 4 * (p * 2 + 2 * n * 2 + p * n + 2 * p)  # in + out + best/feasible

    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", hbm_bytes))
    return {
        "P": p,
        "N": n,
        "flops": flops,
        "bytes_accessed": bytes_accessed,
        "arith_intensity": flops / max(bytes_accessed, 1.0),
        "vmem_per_step_bytes": vmem_bytes,
        "vmem_budget_fraction": vmem_bytes / (16 * 2**20),  # 16 MiB VMEM
        "grid_steps": p // tile_p,
    }


def main() -> None:
    print(f"{'variant':>12} {'flops':>10} {'bytes':>10} {'AI':>6} "
          f"{'VMEM/step':>10} {'%VMEM':>7} {'steps':>5}")
    for p, n in SHAPE_VARIANTS:
        a = analyze(p, n)
        print(
            f"  p{p:<4} n{n:<4} {a['flops']:>10.0f} {a['bytes_accessed']:>10.0f} "
            f"{a['arith_intensity']:>6.2f} {a['vmem_per_step_bytes']:>10} "
            f"{a['vmem_budget_fraction']*100:>6.2f}% {a['grid_steps']:>5}"
        )
    print(
        "\ninterpretation: arithmetic intensity << 1 flop/byte ⇒ the kernel\n"
        "is memory-bandwidth-bound on any backend; VMEM per grid step is\n"
        "<1% of a TPU core's ~16 MiB ⇒ single-pass schedule, no double\n"
        "buffering needed; the batch formulation reads each node vector\n"
        "once per tile instead of once per (pod, node) pair."
    )


if __name__ == "__main__":
    main()
