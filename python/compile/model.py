"""L2 — JAX compute graph for the scheduler's scoring phase.

``scorer_fn`` is the function AOT-lowered to HLO text by ``aot.py`` and
executed by the rust runtime (``rust/src/runtime/``) on the request path.
It wraps the L1 Pallas kernel (``kernels/scoring.py``) with the
post-processing the scheduler needs:

  * the full (P, N) score matrix (LeastAllocated, -1 = infeasible), and
  * per-pod best-node selection with the paper's deterministic
    lexicographic tie-break (first argmax over name-sorted nodes), and
  * per-pod feasibility count (how many nodes passed filtering — the rust
    side uses it for queue/metrics decisions without a second pass).

Outputs are returned as a tuple so the HLO root is a tuple (the xla crate
unwraps with ``to_tuple``; see /opt/xla-example/load_hlo).
"""

import jax.numpy as jnp

from .kernels.scoring import score_pallas


def scorer_fn(pod_req, node_free, node_cap):
    """Batch scorer: the L2 graph lowered into artifacts/*.hlo.txt.

    Args:
      pod_req:   f32[P, 2] pending-pod resource requests (padded rows = 0).
      node_free: f32[N, 2] free capacity (padded nodes = -1 → infeasible).
      node_cap:  f32[N, 2] total capacity (padded nodes = 1).

    Returns:
      scores:   f32[P, N]
      best:     i32[P]  first-argmax node index (lexicographic tie-break)
      feasible: i32[P]  number of feasible nodes per pod
    """
    scores = score_pallas(pod_req, node_free, node_cap)
    best = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    feasible = jnp.sum(scores >= 0.0, axis=-1).astype(jnp.int32)
    return scores, best, feasible
