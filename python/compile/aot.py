"""AOT lowering: JAX (L2, wrapping the L1 Pallas kernel) → HLO text.

HLO **text** (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids, which the xla crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py and /opt/xla-example/README.md.

One artifact per (P, N) shape variant; the rust runtime picks the smallest
variant that fits the cluster and pads inputs (see
``rust/src/runtime/scorer.rs`` for the padding semantics, which the tests
in ``python/tests/test_model.py`` pin down).

Usage:  python -m compile.aot --out ../artifacts/   (from python/)
        python -m compile.aot --out ../artifacts/scorer_p64_n8.hlo.txt
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import scorer_fn

# (P, N) variants baked as artifacts. N covers the paper's cluster sizes
# (4..32 nodes); P covers ppn=8 at 32 nodes (256 pods) with headroom.
SHAPE_VARIANTS = [
    (64, 8),    # small clusters (<=8 nodes), fast path
    (256, 32),  # up to the paper's 32-node / 8-ppn configurations
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_scorer(p: int, n: int) -> str:
    f32 = jax.ShapeDtypeStruct((p, 2), jax.numpy.float32)
    nf = jax.ShapeDtypeStruct((n, 2), jax.numpy.float32)
    lowered = jax.jit(scorer_fn).lower(f32, nf, nf)
    return to_hlo_text(lowered)


def artifact_name(p: int, n: int) -> str:
    return f"scorer_p{p}_n{n}.hlo.txt"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out",
        default="../artifacts",
        help="output directory (or a single .hlo.txt path to emit one variant)",
    )
    args = ap.parse_args()

    if args.out.endswith(".hlo.txt"):
        # Single-artifact mode: parse P/N out of the filename if it matches
        # the scorer_p{P}_n{N} convention, else default to the large variant.
        base = os.path.basename(args.out)
        p, n = SHAPE_VARIANTS[-1]
        if base.startswith("scorer_p"):
            parts = base[len("scorer_p"):].split(".")[0].split("_n")
            p, n = int(parts[0]), int(parts[1])
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        text = lower_scorer(p, n)
        with open(args.out, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {args.out}")
        return

    os.makedirs(args.out, exist_ok=True)
    for p, n in SHAPE_VARIANTS:
        path = os.path.join(args.out, artifact_name(p, n))
        text = lower_scorer(p, n)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path}")


if __name__ == "__main__":
    main()
