"""L2 correctness: scorer_fn shapes, outputs, and AOT round-trip.

Pins down the contract the rust runtime relies on:
  * output tuple ordering (scores, best, feasible),
  * dtypes (f32 / i32 / i32),
  * padding semantics for both axes,
  * the HLO text artifact parses and mentions the expected parameter shapes.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.aot import artifact_name, lower_scorer
from compile.kernels.ref import score_ref
from compile.model import scorer_fn


def test_scorer_outputs():
    rng = np.random.default_rng(7)
    pod = rng.uniform(100, 1000, size=(64, 2)).astype(np.float32)
    cap = np.full((8, 2), 4000.0, dtype=np.float32)
    free = rng.uniform(0, 4000, size=(8, 2)).astype(np.float32)
    scores, best, feas = scorer_fn(jnp.asarray(pod), jnp.asarray(free), jnp.asarray(cap))
    assert scores.shape == (64, 8) and scores.dtype == jnp.float32
    assert best.shape == (64,) and best.dtype == jnp.int32
    assert feas.shape == (64,) and feas.dtype == jnp.int32
    want = np.asarray(score_ref(jnp.asarray(pod), jnp.asarray(free), jnp.asarray(cap)))
    np.testing.assert_allclose(np.asarray(scores), want, atol=1e-5)
    # best = first argmax; feasible = count of non-negative scores
    np.testing.assert_array_equal(np.asarray(best), want.argmax(axis=1).astype(np.int32))
    np.testing.assert_array_equal(np.asarray(feas), (want >= 0).sum(axis=1).astype(np.int32))


def test_all_infeasible_pod_has_negative_best_score():
    pod = jnp.zeros((64, 2), dtype=jnp.float32).at[0].set(jnp.asarray([1e9, 1e9]))
    free = jnp.full((4, 2), 1000.0, dtype=jnp.float32)
    cap = jnp.full((4, 2), 4000.0, dtype=jnp.float32)
    scores, best, feas = scorer_fn(pod, free, cap)
    assert int(feas[0]) == 0
    # argmax over all -1 rows returns 0; consumer must check scores[best] < 0
    assert float(scores[0, int(best[0])]) < 0.0


@pytest.mark.parametrize("p,n", [(64, 8)])
def test_hlo_text_artifact(p, n):
    text = lower_scorer(p, n)
    assert text.startswith("HloModule")
    # Parameters appear with the expected shapes in the entry computation.
    assert f"f32[{p},2]" in text
    assert f"f32[{n},2]" in text
    assert f"f32[{p},{n}]" in text  # scores output
    assert f"s32[{p}]" in text  # best / feasible outputs
    assert artifact_name(p, n) == f"scorer_p{p}_n{n}.hlo.txt"
