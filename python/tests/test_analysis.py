"""Sanity checks on the static perf analysis (keeps §Perf claims honest)."""

from compile.analysis import analyze


def test_analysis_small_variant():
    a = analyze(64, 8)
    assert a["grid_steps"] == 1
    assert a["vmem_per_step_bytes"] < 16 * 2**20 * 0.01  # < 1% of VMEM
    assert a["flops"] > 0
    assert a["arith_intensity"] < 5.0  # memory-bound, not compute-bound


def test_analysis_large_variant_tiles():
    a = analyze(256, 32)
    assert a["grid_steps"] == 4  # 256 / DEFAULT_TILE_P
    assert a["vmem_per_step_bytes"] == 4 * (64 * 2 + 2 * 32 * 2 + 64 * 32)
