"""L1 correctness: Pallas scoring kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the kernel: hypothesis sweeps
shapes and value ranges; every case must match ``ref.score_ref`` to f32
tolerance (the kernel and the oracle use the same ops, so we can demand
exact equality in practice — we assert allclose with tight atol).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import INFEASIBLE, best_node_ref, score_ref
from compile.kernels.scoring import score_pallas


def _rand_inputs(rng, p, n, lo=0.0, hi=1000.0):
    pod = rng.uniform(lo, hi, size=(p, 2)).astype(np.float32)
    cap = rng.uniform(1000.0, 8000.0, size=(n, 2)).astype(np.float32)
    alloc = rng.uniform(0.0, 1.0, size=(n, 2)).astype(np.float32) * cap
    free = (cap - alloc).astype(np.float32)
    return pod, free, cap


@settings(max_examples=30, deadline=None)
@given(
    p_tiles=st.integers(min_value=1, max_value=4),
    tile_p=st.sampled_from([8, 16, 64]),
    n=st.integers(min_value=1, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pallas_matches_ref_hypothesis(p_tiles, tile_p, n, seed):
    rng = np.random.default_rng(seed)
    p = p_tiles * tile_p
    pod, free, cap = _rand_inputs(rng, p, n)
    got = score_pallas(jnp.asarray(pod), jnp.asarray(free), jnp.asarray(cap), tile_p=tile_p)
    want = score_ref(jnp.asarray(pod), jnp.asarray(free), jnp.asarray(cap))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-6)


def test_infeasible_marked():
    pod = jnp.asarray([[500.0, 500.0], [9000.0, 100.0]], dtype=jnp.float32)
    pod = jnp.pad(pod, ((0, 62), (0, 0)))  # pad to tile
    free = jnp.asarray([[600.0, 600.0]], dtype=jnp.float32)
    cap = jnp.asarray([[1000.0, 1000.0]], dtype=jnp.float32)
    s = score_pallas(pod, free, cap)
    assert s[0, 0] > 0.0
    assert s[1, 0] == INFEASIBLE  # cpu 9000 > free 600


def test_exact_fit_scores_zero_remaining():
    """A pod consuming all free capacity is feasible; zero surplus -> score 0."""
    pod = jnp.zeros((64, 2), dtype=jnp.float32).at[0].set(jnp.asarray([1000.0, 2000.0]))
    free = jnp.asarray([[1000.0, 2000.0]], dtype=jnp.float32)
    cap = jnp.asarray([[4000.0, 4000.0]], dtype=jnp.float32)
    s = score_pallas(pod, free, cap)
    assert s[0, 0] == 0.0  # rem == 0 on both axes -> score 0, still feasible


def test_zero_capacity_denominator_guard():
    """cap=0 nodes must not produce NaN/inf (denominator clamped to 1)."""
    pod = jnp.zeros((64, 2), dtype=jnp.float32)
    free = jnp.zeros((3, 2), dtype=jnp.float32)
    cap = jnp.zeros((3, 2), dtype=jnp.float32)
    s = score_pallas(pod, free, cap)
    assert bool(jnp.all(jnp.isfinite(s)))
    assert bool(jnp.all(s == 0.0))  # rem = 0, feasible, score 0


def test_padding_semantics():
    """Rust runtime pads pods with req=0 and nodes with free=-1/cap=1."""
    pod = jnp.zeros((64, 2), dtype=jnp.float32)  # all padded pods
    free = jnp.full((4, 2), -1.0, dtype=jnp.float32)  # all padded nodes
    cap = jnp.ones((4, 2), dtype=jnp.float32)
    s = score_pallas(pod, free, cap)
    assert bool(jnp.all(s == INFEASIBLE))  # padded nodes never selectable


def test_tile_mismatch_raises():
    pod = jnp.zeros((65, 2), dtype=jnp.float32)
    free = jnp.ones((2, 2), dtype=jnp.float32)
    with pytest.raises(ValueError):
        score_pallas(pod, free, free, tile_p=64)


def test_best_node_lexicographic_tie_break():
    """Equal scores -> first (lexicographically smallest) node index wins."""
    pod = jnp.zeros((64, 2), dtype=jnp.float32).at[0].set(jnp.asarray([100.0, 100.0]))
    free = jnp.asarray([[500.0, 500.0]] * 3, dtype=jnp.float32)
    cap = jnp.asarray([[1000.0, 1000.0]] * 3, dtype=jnp.float32)
    s = score_pallas(pod, free, cap)
    assert int(best_node_ref(s)[0]) == 0
