#!/usr/bin/env python3
"""Stdlib-only client for the `kube-packd serve` daemon.

The wire protocol is newline-delimited JSON over TCP: one request
object per line, one reply object per line. Every request may carry an
opaque integer ``tag`` which the daemon echoes on the reply (including
error replies), so a client can correlate out-of-order arrivals —
``submit`` replies are deferred to the end of their batching window,
while ``query``/``health``/... answer immediately.

Library use::

    with ServeClient(port=7878) as c:
        t_web = c.submit("web", replicas=2, cpu_milli=100, ram_mib=2048)
        t_db = c.submit("db", replicas=1, cpu_milli=100, ram_mib=3072)
        for t in (t_web, t_db):
            reply = c.wait(t)            # blocks until the window closes
            print(reply["certificate"], reply["placements"])
        print(c.request("query")["digest"])
        c.request("shutdown")            # drains the daemon; it exits 0

CLI use (the CI smoke test)::

    python3 python/client.py --port 7979 --figure1 --shutdown
"""

from __future__ import annotations

import argparse
import json
import socket
import sys

#: Every op the daemon speaks, kept in lockstep with ``WireOp::name``
#: in ``rust/src/server/protocol.rs``. The ``wire-parity`` rule of
#: ``kube-packd lint`` asserts set equality in both directions, so a
#: slug added on one side only fails CI instead of drifting silently.
WIRE_OPS = frozenset({
    "submit", "delete", "join", "drain", "remove", "query", "health",
    "metrics", "trace_export", "journal", "watch", "explain", "profile",
    "shutdown",
})

#: Schema tag of the solve-forensics document the ``profile`` op (and
#: ``solve --profile``) emits, mirror of ``PROFILE_SCHEMA`` in
#: ``rust/src/solver/probe.rs``.
PROFILE_SCHEMA = "kube-packd/profile/v1"

#: Structured error slugs (``reply["error"]["code"]``), the mirror of
#: ``WireError::code`` — same wire-parity contract as ``WIRE_OPS``.
ERROR_CODES = frozenset({
    "bad-json", "unknown-op", "bad-request", "oversized", "draining",
    "overloaded",
})


def error_code(reply: dict) -> str | None:
    """Structured error slug of ``reply``, or ``None`` on success.
    Raises if the daemon sends a slug this client doesn't know —
    that's protocol drift, not a user error."""
    err = reply.get("error")
    if err is None:
        return None
    code = err.get("code") if isinstance(err, dict) else str(err)
    if code not in ERROR_CODES:
        raise ValueError(f"daemon sent an unknown error code {code!r}")
    return code


class ServeClient:
    """One connection to the daemon, with tag-based reply correlation."""

    def __init__(self, host: str = "127.0.0.1", port: int = 7878, timeout: float = 60.0):
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._rfile = self._sock.makefile("r", encoding="utf-8", newline="\n")
        self._next_tag = 0
        self._pending: dict[int, dict] = {}
        self._frames: list[dict] = []

    # -- plumbing -----------------------------------------------------------

    def send(self, op: str, **fields) -> int:
        """Send one request; returns its tag (use :meth:`wait`)."""
        if op not in WIRE_OPS:
            raise ValueError(f"unknown wire op {op!r} (known: {sorted(WIRE_OPS)})")
        tag = self._next_tag
        self._next_tag += 1
        line = json.dumps({"op": op, "tag": tag, **fields}, separators=(",", ":"))
        self._sock.sendall(line.encode("utf-8") + b"\n")
        return tag

    def recv(self) -> dict:
        """Read the next reply line, whatever request it answers."""
        line = self._rfile.readline()
        if not line:
            raise ConnectionError("daemon closed the connection")
        return json.loads(line)

    def wait(self, tag: int) -> dict:
        """Block until the reply tagged ``tag`` arrives. Untagged push
        frames (``watch`` deltas / ``lagged`` notices) are diverted to
        the frame queue for :meth:`next_frame` rather than stashed as
        replies."""
        while tag not in self._pending:
            reply = self.recv()
            if "frame" in reply:
                self._frames.append(reply)
            else:
                self._pending[reply.get("tag")] = reply
        return self._pending.pop(tag)

    def request(self, op: str, **fields) -> dict:
        """Send and wait in one step (fine for immediate-reply ops)."""
        return self.wait(self.send(op, **fields))

    # -- conveniences -------------------------------------------------------

    def submit(self, name: str, replicas: int, cpu_milli: int, ram_mib: int,
               priority: int = 0, **constraints) -> int:
        """Submit one ReplicaSet-shaped batch; reply arrives at window
        close, so this returns the tag rather than blocking."""
        return self.send("submit", name=name, replicas=replicas, cpu_milli=cpu_milli,
                         ram_mib=ram_mib, priority=priority, **constraints)

    def watch(self) -> dict:
        """Subscribe this connection to window-close delta frames. The
        daemon acks immediately; frames then arrive untagged — read
        them with :meth:`next_frame`."""
        ack = self.request("watch")
        if "error" in ack:
            raise RuntimeError(f"watch rejected: {ack['error']}")
        return ack

    def next_frame(self) -> dict:
        """Block until the next push frame (``delta`` or ``lagged``)."""
        while not self._frames:
            reply = self.recv()
            if "frame" in reply:
                self._frames.append(reply)
            else:
                self._pending[reply.get("tag")] = reply
        return self._frames.pop(0)

    def journal(self, since: int = 0, limit: int | None = None,
                wall: bool = False) -> list[dict]:
        """Page through the daemon's window-close journal starting at
        window ``since`` (each reply's ``next`` resumes the cursor)."""
        entries: list[dict] = []
        while True:
            fields: dict = {"since": since}
            if limit is not None:
                fields["limit"] = limit
            if wall:
                fields["wall"] = True
            reply = self.request("journal", **fields)
            if "error" in reply:
                raise RuntimeError(f"journal rejected: {reply['error']}")
            page = reply["entries"]
            entries.extend(page)
            if not page or reply["next"] <= since:
                return entries
            since = reply["next"]

    def explain(self, pod: str) -> dict:
        """Per-node rejection census for ``pod`` (why is it pending?)."""
        reply = self.request("explain", pod=pod)
        if "error" in reply:
            raise RuntimeError(f"explain rejected: {reply['error']}")
        return reply

    def profile(self) -> dict:
        """Solve forensics of the daemon's most recent solve window:
        the ``kube-packd/profile/v1`` document (per-constraint-module
        effort, decision-indexed gap timeline, folded stacks), parsed
        and schema-checked. The window it profiles rides along under
        ``"window"`` (``None`` until the first solver invocation)."""
        reply = self.request("profile")
        if "error" in reply:
            raise RuntimeError(f"profile rejected: {reply['error']}")
        doc = json.loads(reply["body"])
        if doc.get("schema") != PROFILE_SCHEMA:
            raise ValueError(f"unexpected profile schema {doc.get('schema')!r}")
        doc["window"] = reply.get("window")
        return doc

    def close(self) -> None:
        try:
            self._rfile.close()
        finally:
            self._sock.close()

    def __enter__(self) -> "ServeClient":
        return self

    def __exit__(self, *_exc) -> None:
        self.close()


def validate_histograms(metrics: str) -> int:
    """Validate every Prometheus histogram series in an exposition:
    per label set, ``_bucket`` samples must be cumulative (monotone
    non-decreasing in file order) and end with ``le="+Inf"`` equal to
    the sibling ``_count``; a ``_sum`` sample must exist. Returns the
    number of bucket series checked; raises ``ValueError`` on any
    violation."""
    buckets: dict[str, list[tuple[str, int]]] = {}
    scalars: dict[str, float] = {}
    for line in metrics.splitlines():
        if not line or line.startswith("#"):
            continue
        name_labels, _, value = line.rpartition(" ")
        if "_bucket" in name_labels and 'le="' in name_labels:
            prefix, _, le_part = name_labels.partition('le="')
            le = le_part.rstrip("}").rstrip('"')
            buckets.setdefault(prefix, []).append((le, int(value)))
        else:
            scalars[name_labels] = float(value)
    for prefix, series in buckets.items():
        counts = [c for _, c in series]
        if any(a > b for a, b in zip(counts, counts[1:])):
            raise ValueError(f"non-monotone buckets for {prefix}: {counts}")
        if series[-1][0] != "+Inf":
            raise ValueError(f"{prefix} does not end at le=\"+Inf\"")
        base = prefix[:-1] if prefix and prefix[-1] in "{," else prefix
        if "{" in base:
            count_name = base.replace("_bucket{", "_count{") + "}"
        else:
            count_name = base.replace("_bucket", "_count")
        if count_name not in scalars:
            raise ValueError(f"missing {count_name}")
        if counts[-1] != scalars[count_name]:
            raise ValueError(
                f"+Inf bucket {counts[-1]} != {count_name} {scalars[count_name]}")
        sum_name = count_name.replace("_count", "_sum")
        if sum_name not in scalars:
            raise ValueError(f"missing {sum_name}")
    return len(buckets)


def validate_profile(doc: dict) -> int:
    """Validate a ``kube-packd/profile/v1`` document: the schema tag,
    well-formed effort/module/gap entries, and the flamegraph.pl folded
    grammar (``stack;frames count``, every stack rooted at ``solve``).
    Returns the number of folded lines checked; raises ``ValueError``
    on any violation."""
    if doc.get("schema") != PROFILE_SCHEMA:
        raise ValueError(f"bad profile schema {doc.get('schema')!r}")
    for key in ("effort", "modules", "gap", "folded"):
        if not isinstance(doc.get(key), list):
            raise ValueError(f"profile key {key!r} missing or not an array")
    for m in doc["modules"]:
        if not m.get("slug") or not m.get("kind") or int(m["count"]) <= 0:
            raise ValueError(f"malformed module row {m}")
    for e in doc["effort"]:
        if not e.get("context") or not e.get("slug") or int(e["count"]) <= 0:
            raise ValueError(f"malformed effort row {e}")
    for s in doc["gap"]:
        if int(s["bound"]) < int(s["incumbent"]):
            raise ValueError(f"inadmissible gap sample {s}")
    for line in doc["folded"]:
        stack, _, count = line.rpartition(" ")
        if not stack or not count.isdigit() or int(count) <= 0:
            raise ValueError(f"malformed folded line {line!r}")
        if stack.split(";")[0] != "solve":
            raise ValueError(f"folded stack not rooted at solve: {line!r}")
    return len(doc["folded"])


def run_figure1(client: ServeClient) -> dict:
    """The paper's figure-1 batch: 2Gi + 2Gi + 3Gi on two 4Gi nodes.

    The default scheduler's spreading strands the 3Gi pod; the window
    solve must re-pack all three and prove it. Raises on anything less.
    """
    tags = [
        client.submit("web", replicas=2, cpu_milli=100, ram_mib=2048),
        client.submit("db", replicas=1, cpu_milli=100, ram_mib=3072),
    ]
    for tag in tags:
        reply = client.wait(tag)
        if "error" in reply:
            raise RuntimeError(f"submit rejected: {reply['error']}")
        placements = reply["placements"]
        unplaced = [p["pod"] for p in placements if p["node"] is None]
        if unplaced:
            raise RuntimeError(f"unplaced pods {unplaced} in window {reply['window']}")
        if reply["certificate"] != "proven-optimal":
            raise RuntimeError(f"expected a proven-optimal window, got {reply['certificate']!r}")
        for p in placements:
            print(f"  {p['pod']} -> {p['node']}  [{reply['certificate']}]")
    query = client.request("query")
    if query["pending"] != 0:
        raise RuntimeError(f"daemon still has {query['pending']} pending pods")
    print(f"figure-1 batch certified: digest {query['digest']}")
    return query


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=7878)
    ap.add_argument("--figure1", action="store_true",
                    help="submit the figure-1 batch and require a certified repack")
    ap.add_argument("--watch-one", action="store_true",
                    help="subscribe to watch frames before --figure1 and require "
                         "the window close's delta frame (matching digest)")
    ap.add_argument("--shutdown", action="store_true",
                    help="drain the daemon before exiting")
    args = ap.parse_args()

    with ServeClient(args.host, args.port) as client:
        health = client.request("health")
        if not health.get("ok"):
            print(f"unhealthy daemon: {health}", file=sys.stderr)
            return 1
        print(f"daemon healthy: protocol v{health['protocol']}, "
              f"{health['windows']} windows closed")
        if args.watch_one:
            ack = client.watch()
            print(f"watch subscribed at window {ack['window']}")
        if args.figure1:
            query = run_figure1(client)
            metrics = client.request("metrics")["body"]
            if "kube_packd_server_windows_total" not in metrics:
                print("metrics exposition missing server counters", file=sys.stderr)
                return 1
            nseries = validate_histograms(metrics)
            if nseries == 0:
                print("no histogram series in metrics exposition", file=sys.stderr)
                return 1
            print(f"histograms well-formed ({nseries} bucket series)")
            journal = client.journal(wall=True)
            if not journal or journal[-1]["pending_after"] != 0:
                print(f"journal tail disagrees with the close: {journal[-1:]}",
                      file=sys.stderr)
                return 1
            print(f"journal replay: {len(journal)} window(s), last certificate "
                  f"{journal[-1]['certificate']!r}")
            prof = client.profile()
            nfolded = validate_profile(prof)
            if prof["window"] is None or not prof["modules"]:
                print(f"profile carries no solve forensics: {prof}", file=sys.stderr)
                return 1
            print(f"profile: window {prof['window']}, {len(prof['modules'])} "
                  f"module rows, {nfolded} folded lines")
            if args.watch_one:
                frame = client.next_frame()
                if frame.get("frame") != "delta":
                    print(f"expected a delta frame, got {frame}", file=sys.stderr)
                    return 1
                if frame["digest"] != query["digest"]:
                    print(f"watch digest {frame['digest']} != query digest "
                          f"{query['digest']}", file=sys.stderr)
                    return 1
                print(f"watch frame: window {frame['window']} digest {frame['digest']} "
                      f"(matches polling query)")
        if args.shutdown:
            ack = client.request("shutdown")
            if not ack.get("draining"):
                print(f"shutdown not acknowledged: {ack}", file=sys.stderr)
                return 1
            print("daemon draining")
    return 0


if __name__ == "__main__":
    sys.exit(main())
