#!/usr/bin/env python3
"""Stdlib-only client for the `kube-packd serve` daemon.

The wire protocol is newline-delimited JSON over TCP: one request
object per line, one reply object per line. Every request may carry an
opaque integer ``tag`` which the daemon echoes on the reply (including
error replies), so a client can correlate out-of-order arrivals —
``submit`` replies are deferred to the end of their batching window,
while ``query``/``health``/... answer immediately.

Library use::

    with ServeClient(port=7878) as c:
        t_web = c.submit("web", replicas=2, cpu_milli=100, ram_mib=2048)
        t_db = c.submit("db", replicas=1, cpu_milli=100, ram_mib=3072)
        for t in (t_web, t_db):
            reply = c.wait(t)            # blocks until the window closes
            print(reply["certificate"], reply["placements"])
        print(c.request("query")["digest"])
        c.request("shutdown")            # drains the daemon; it exits 0

CLI use (the CI smoke test)::

    python3 python/client.py --port 7979 --figure1 --shutdown
"""

from __future__ import annotations

import argparse
import json
import socket
import sys


class ServeClient:
    """One connection to the daemon, with tag-based reply correlation."""

    def __init__(self, host: str = "127.0.0.1", port: int = 7878, timeout: float = 60.0):
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._rfile = self._sock.makefile("r", encoding="utf-8", newline="\n")
        self._next_tag = 0
        self._pending: dict[int, dict] = {}

    # -- plumbing -----------------------------------------------------------

    def send(self, op: str, **fields) -> int:
        """Send one request; returns its tag (use :meth:`wait`)."""
        tag = self._next_tag
        self._next_tag += 1
        line = json.dumps({"op": op, "tag": tag, **fields}, separators=(",", ":"))
        self._sock.sendall(line.encode("utf-8") + b"\n")
        return tag

    def recv(self) -> dict:
        """Read the next reply line, whatever request it answers."""
        line = self._rfile.readline()
        if not line:
            raise ConnectionError("daemon closed the connection")
        return json.loads(line)

    def wait(self, tag: int) -> dict:
        """Block until the reply tagged ``tag`` arrives."""
        while tag not in self._pending:
            reply = self.recv()
            self._pending[reply.get("tag")] = reply
        return self._pending.pop(tag)

    def request(self, op: str, **fields) -> dict:
        """Send and wait in one step (fine for immediate-reply ops)."""
        return self.wait(self.send(op, **fields))

    # -- conveniences -------------------------------------------------------

    def submit(self, name: str, replicas: int, cpu_milli: int, ram_mib: int,
               priority: int = 0, **constraints) -> int:
        """Submit one ReplicaSet-shaped batch; reply arrives at window
        close, so this returns the tag rather than blocking."""
        return self.send("submit", name=name, replicas=replicas, cpu_milli=cpu_milli,
                         ram_mib=ram_mib, priority=priority, **constraints)

    def close(self) -> None:
        try:
            self._rfile.close()
        finally:
            self._sock.close()

    def __enter__(self) -> "ServeClient":
        return self

    def __exit__(self, *_exc) -> None:
        self.close()


def run_figure1(client: ServeClient) -> None:
    """The paper's figure-1 batch: 2Gi + 2Gi + 3Gi on two 4Gi nodes.

    The default scheduler's spreading strands the 3Gi pod; the window
    solve must re-pack all three and prove it. Raises on anything less.
    """
    tags = [
        client.submit("web", replicas=2, cpu_milli=100, ram_mib=2048),
        client.submit("db", replicas=1, cpu_milli=100, ram_mib=3072),
    ]
    for tag in tags:
        reply = client.wait(tag)
        if "error" in reply:
            raise RuntimeError(f"submit rejected: {reply['error']}")
        placements = reply["placements"]
        unplaced = [p["pod"] for p in placements if p["node"] is None]
        if unplaced:
            raise RuntimeError(f"unplaced pods {unplaced} in window {reply['window']}")
        if reply["certificate"] != "proven-optimal":
            raise RuntimeError(f"expected a proven-optimal window, got {reply['certificate']!r}")
        for p in placements:
            print(f"  {p['pod']} -> {p['node']}  [{reply['certificate']}]")
    query = client.request("query")
    if query["pending"] != 0:
        raise RuntimeError(f"daemon still has {query['pending']} pending pods")
    print(f"figure-1 batch certified: digest {query['digest']}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=7878)
    ap.add_argument("--figure1", action="store_true",
                    help="submit the figure-1 batch and require a certified repack")
    ap.add_argument("--shutdown", action="store_true",
                    help="drain the daemon before exiting")
    args = ap.parse_args()

    with ServeClient(args.host, args.port) as client:
        health = client.request("health")
        if not health.get("ok"):
            print(f"unhealthy daemon: {health}", file=sys.stderr)
            return 1
        print(f"daemon healthy: protocol v{health['protocol']}, "
              f"{health['windows']} windows closed")
        if args.figure1:
            run_figure1(client)
            metrics = client.request("metrics")["body"]
            if "kube_packd_server_windows_total" not in metrics:
                print("metrics exposition missing server counters", file=sys.stderr)
                return 1
        if args.shutdown:
            ack = client.request("shutdown")
            if not ack.get("draining"):
                print(f"shutdown not acknowledged: {ack}", file=sys.stderr)
                return 1
            print("daemon draining")
    return 0


if __name__ == "__main__":
    sys.exit(main())
