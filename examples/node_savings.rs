//! Overprovisioning: how many nodes does optimal packing save?
//!
//! The paper's motivation cites clusters that are 99.94% overprovisioned
//! (Cast AI 2025) with ~40% CPU / ~57% memory gaps. This example
//! quantifies the effect on synthetic workloads: for a fixed workload,
//! how many nodes does the default scheduler need to place everything
//! vs. the constraint-based packer?
//!
//! Run: `cargo run --release --example node_savings`

use kube_packd::cluster::{identical_nodes, ClusterState, Resources};
use kube_packd::optimizer::algorithm::{optimize, OptimizerConfig};
use kube_packd::simulator::KwokSimulator;
use kube_packd::workload::{GenParams, Instance};

/// Smallest node count (identical nodes of `cap`) at which `schedule`
/// places every pod.
fn nodes_needed(
    inst: &Instance,
    cap: Resources,
    mut attempt: impl FnMut(&Instance, usize, Resources) -> bool,
) -> usize {
    for n in 1..=inst.params.nodes * 3 {
        if attempt(inst, n, cap) {
            return n;
        }
    }
    inst.params.nodes * 3
}

fn kwok_places_all(inst: &Instance, n: usize, cap: Resources) -> bool {
    let mut sim = KwokSimulator::new(inst.params.p_max());
    let (_, res) = sim.run(identical_nodes(n, cap), inst.pods.clone());
    res.all_placed
}

fn solver_places_all(inst: &Instance, n: usize, cap: Resources) -> bool {
    let state = ClusterState::new(identical_nodes(n, cap), inst.pods.clone());
    match optimize(&state, inst.params.p_max(), &OptimizerConfig::with_timeout(2.0)) {
        Some(res) => res.placed_per_priority.iter().sum::<usize>() == inst.pods.len(),
        None => false,
    }
}

fn main() {
    let params = GenParams {
        nodes: 8,
        pods_per_node: 6,
        priority_tiers: 1,
        usage: 1.0,
    };
    println!("workload: {} pods on identical nodes (seeded runs)\n", params.pod_count());
    println!("{:>5} {:>12} {:>12} {:>8}", "seed", "kwok-nodes", "opt-nodes", "saved");

    let (mut total_kwok, mut total_opt) = (0usize, 0usize);
    for seed in 1..=8u64 {
        let inst = Instance::generate(params, seed);
        let cap = inst.nodes[0].capacity;
        let kwok = nodes_needed(&inst, cap, kwok_places_all);
        let opt = nodes_needed(&inst, cap, solver_places_all);
        total_kwok += kwok;
        total_opt += opt;
        println!("{:>5} {:>12} {:>12} {:>8}", seed, kwok, opt, kwok.saturating_sub(opt));
        assert!(opt <= kwok, "optimal packing can never need more nodes");
    }

    let saved = total_kwok - total_opt;
    println!(
        "\ntotals: kwok={total_kwok} nodes, optimal={total_opt} nodes -> {} node(s) saved ({:.1}%)",
        saved,
        saved as f64 * 100.0 / total_kwok as f64
    );
    println!("node_savings OK");
}
