//! Overprovisioning: how many nodes does optimal packing save?
//!
//! The paper's motivation cites clusters that are 99.94% overprovisioned
//! (Cast AI 2025) with ~40% CPU / ~57% memory gaps. This example
//! quantifies the effect on synthetic workloads: for a fixed workload,
//! how many nodes does the default scheduler need to place everything
//! vs. the constraint-based packer?
//!
//! The packer's side is no longer a hand-rolled linear search over node
//! counts: the autoscaler's provisioning model answers it directly —
//! solve min-cost provisioning from an *empty* cluster with one
//! unit-cost pool of the workload's node shape, and the certified
//! optimum IS the minimum node count (with a proof, not an estimate).
//!
//! Run: `cargo run --release --example node_savings`

use std::time::Duration;

use kube_packd::autoscaler::{plan_provisioning, NodePool, ProvisionOutcome};
use kube_packd::cluster::{identical_nodes, ClusterState, Resources};
use kube_packd::optimizer::constraints::ModuleRegistry;
use kube_packd::portfolio::PortfolioConfig;
use kube_packd::simulator::KwokSimulator;
use kube_packd::solver::SolverConfig;
use kube_packd::telemetry::{Deadline, Telemetry};
use kube_packd::workload::{GenParams, Instance};

/// Smallest node count (identical nodes of `cap`) at which the default
/// scheduler places every pod — still a search, because the heuristic
/// is not monotone-friendly to certificates.
fn kwok_nodes_needed(inst: &Instance, cap: Resources) -> usize {
    for n in 1..=inst.params.nodes * 3 {
        let mut sim = KwokSimulator::new(inst.params.p_max());
        let (_, res) = sim.run(identical_nodes(n, cap), inst.pods.clone());
        if res.all_placed {
            return n;
        }
    }
    inst.params.nodes * 3
}

/// Certified minimum node count: min-cost provisioning from an empty
/// cluster with one unit-cost pool of capacity `cap`. The plan's
/// optimality certificate makes the answer a proof; `None` means the
/// window expired before any fleet was found (anytime caveat).
fn certified_nodes_needed(inst: &Instance, cap: Resources) -> Option<(usize, bool)> {
    let empty = ClusterState::new(Vec::new(), inst.pods.clone());
    let pending = empty.pending_pods();
    let pools = vec![NodePool::new("std", 1000, 1)];
    match plan_provisioning(
        &empty,
        &pending,
        &pools,
        cap,
        pending.len(),
        Deadline::after(Duration::from_secs(30)),
        &SolverConfig::default(),
        &PortfolioConfig::default(),
        &ModuleRegistry::standard(),
        &Telemetry::off(),
    ) {
        ProvisionOutcome::Plan(plan) => Some((plan.node_count, plan.certified())),
        ProvisionOutcome::Infeasible => {
            panic!("unit-pool provisioning cannot be infeasible on this workload")
        }
        ProvisionOutcome::Unknown => None,
    }
}

fn main() {
    let params = GenParams {
        nodes: 8,
        pods_per_node: 6,
        priority_tiers: 1,
        usage: 1.0,
    };
    println!("workload: {} pods on identical nodes (seeded runs)\n", params.pod_count());
    println!(
        "{:>5} {:>12} {:>12} {:>8} {:>10}",
        "seed", "kwok-nodes", "opt-nodes", "saved", "certified"
    );

    let (mut total_kwok, mut total_opt) = (0usize, 0usize);
    for seed in 1..=8u64 {
        let inst = Instance::generate(params, seed);
        let cap = inst.nodes[0].capacity;
        let kwok = kwok_nodes_needed(&inst, cap);
        // A deadline-truncated solve falls back to the kwok fleet (the
        // anytime caveat) — kwok's placement is itself a feasible fleet,
        // so an anytime answer is never allowed to exceed it.
        let (opt, certified) = certified_nodes_needed(&inst, cap).unwrap_or((kwok, false));
        let opt = if certified { opt } else { opt.min(kwok) };
        total_kwok += kwok;
        total_opt += opt;
        println!(
            "{:>5} {:>12} {:>12} {:>8} {:>10}",
            seed,
            kwok,
            opt,
            kwok.saturating_sub(opt),
            if certified { "proven" } else { "anytime" }
        );
        assert!(opt <= kwok, "optimal packing can never need more nodes");
    }

    let saved = total_kwok - total_opt;
    println!(
        "\ntotals: kwok={total_kwok} nodes, optimal={total_opt} nodes -> {} node(s) saved ({:.1}%)",
        saved,
        saved as f64 * 100.0 / total_kwok as f64
    );
    println!("node_savings OK");
}
