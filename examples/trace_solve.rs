//! Observability walkthrough: trace one solve end to end.
//!
//! Runs the Figure-1 repack with a recording [`Telemetry`] handle
//! attached, prints the solver/portfolio counters it collected, and
//! writes the two exports next to the working directory:
//!
//! * `trace_solve.trace.json` — Chrome-trace JSON; open it in Perfetto
//!   (<https://ui.perfetto.dev>) or chrome://tracing to see the span
//!   tree: fallback → session/phase1/phase2 → cache / decompose /
//!   warm-start / strategy-race → per-worker race-task lanes.
//! * `trace_solve.metrics.prom` — Prometheus text exposition of every
//!   counter the run touched (`kube_packd_*`).
//!
//! The same exports are available on the CLI as
//! `kube-packd solve --trace t.json --metrics m.prom`.
//!
//! Telemetry observes and never feeds back: the placements below are
//! byte-identical to a run without the handle.
//!
//! Run: `cargo run --release --example trace_solve`

use kube_packd::cluster::{identical_nodes, ClusterState, Pod, Priority, Resources};
use kube_packd::optimizer::{OptimizerConfig, OptimizingScheduler};
use kube_packd::telemetry::Telemetry;

fn main() -> anyhow::Result<()> {
    let nodes = identical_nodes(2, Resources::new(4000, 4096));
    let pods = vec![
        Pod::new(0, "pod-1", Resources::new(100, 2048), Priority(0)),
        Pod::new(1, "pod-2", Resources::new(100, 2048), Priority(0)),
        Pod::new(2, "pod-3", Resources::new(100, 3072), Priority(0)),
    ];
    let mut state = ClusterState::new(nodes, pods);

    // A recording handle; Telemetry::off() would make every call below
    // a no-op at zero cost, and the plan would be byte-identical.
    let tel = Telemetry::recording();
    let mut scheduler = OptimizingScheduler::new(
        0,
        OptimizerConfig::with_timeout(2.0).with_threads(2),
    );
    let report = scheduler.run_traced(&mut state, &tel);

    println!("placed {:?} -> {:?}", report.placed_before, report.placed_after);
    println!("proved optimal: {}\n", report.proved_optimal);
    assert_eq!(report.placed_after, vec![3], "all three pods must fit");

    // Every counter the pipeline incremented, in deterministic order.
    println!("{:<44} {:>28} {:>10}", "counter", "labels", "value");
    for (metric, labels, _, value) in tel.counters().iter() {
        println!("{metric:<44} {labels:>28} {value:>10}");
    }
    println!("\nspans recorded: {}", tel.span_count());

    std::fs::write("trace_solve.trace.json", tel.export_chrome())?;
    std::fs::write("trace_solve.metrics.prom", tel.export_prometheus())?;
    println!("wrote trace_solve.trace.json (load in Perfetto / chrome://tracing)");
    println!("wrote trace_solve.metrics.prom (Prometheus text exposition)");
    Ok(())
}
