//! The autoscaler loop, narrated: prove the cluster full, buy the
//! cheapest fix, then prove a node drainable once the load recedes.
//!
//! Run: `cargo run --release --example autoscale`

use std::time::Duration;

use kube_packd::autoscaler::{run_consolidation, AutoscaleConfig, NodePool};
use kube_packd::cluster::{identical_nodes, ClusterState, Pod, PodId, Priority, Resources};
use kube_packd::optimizer::{OptimizerConfig, OptimizingScheduler};

fn main() {
    println!("autoscale demo — 2 nodes x (1000m, 1000Mi), menu: small/large/gpu\n");

    // A cluster the default scheduler fills to the brim, plus two
    // arrivals that provably cannot fit.
    let pods = vec![
        Pod::new(0, "web-0", Resources::new(600, 600), Priority(0)),
        Pod::new(1, "web-1", Resources::new(1000, 1000), Priority(0)),
        Pod::new(2, "db-0", Resources::new(400, 400), Priority(0)),
        Pod::new(3, "burst-0", Resources::new(400, 400), Priority(0)),
        Pod::new(4, "burst-1", Resources::new(400, 400), Priority(0)),
    ];
    let mut state = ClusterState::new(identical_nodes(2, Resources::new(1000, 1000)), pods);

    let acfg = AutoscaleConfig {
        pools: vec![NodePool::small(), NodePool::large(), NodePool::gpu()],
        provision_timeout: Duration::from_secs(5),
        max_removals: 2,
        ..AutoscaleConfig::default()
    };
    let mut sched = OptimizingScheduler::new(
        0,
        OptimizerConfig::with_timeout(5.0).with_autoscale(acfg.clone()),
    );

    // --- phase 1: the fallback proves the cluster full and scales up ---
    let report = sched.run(&mut state);
    println!(
        "fallback: placed {:?} -> {:?} (proved optimal: {})",
        report.placed_before, report.placed_after, report.proved_optimal
    );
    let up = report
        .autoscale
        .expect("two pods are certifiably unplaceable");
    println!("  {}", up.log_line());
    assert!(up.applied, "the plan must apply");
    assert!(up.certified, "min cost AND min count, both proven");
    assert!(
        state.pending_pods().is_empty(),
        "every stuck pod landed on a provisioned node"
    );
    println!(
        "  fleet: {} nodes (cost floor proven at {})",
        state.nodes().len(),
        up.cost_bound
    );

    // --- phase 2: load recedes; consolidation proves a node drainable ---
    println!("\nburst-0 and web-0 complete; the fleet is now oversized");
    state.terminate(PodId(3)).expect("burst-0 completes");
    state.terminate(PodId(0)).expect("web-0 completes");
    let pass = run_consolidation(
        &mut state,
        0,
        &acfg,
        &OptimizerConfig::with_timeout(5.0),
        None,
    );
    println!(
        "consolidation: considered={} removed={} moves={} drained={}",
        pass.considered,
        pass.removed.len(),
        pass.moves,
        pass.drained_pods
    );
    assert!(
        !pass.removed.is_empty(),
        "at least one node is provably drainable"
    );
    for n in &pass.removed {
        println!("  removed {}", state.node(*n).name);
    }
    state.check_invariants().expect("state stays consistent");
    let ready = state
        .nodes()
        .iter()
        .filter(|n| state.node_ready(n.id))
        .count();
    println!(
        "  fleet: {ready} ready nodes, {} pods placed",
        state.placed_count()
    );
    println!("\nautoscale OK");
}
