//! Cross-node pre-emption: the capability Kubernetes itself lacks.
//!
//! Kubernetes pre-emption operates within a single node; the paper's
//! plugin performs *cross-node* pre-emption — relocating lower-priority
//! pods across nodes to admit a pending high-priority pod. This example
//! builds a cluster where no single-node eviction helps, but a
//! coordinated two-node shuffle does.
//!
//! Run: `cargo run --release --example priority_preemption`

use kube_packd::cluster::{identical_nodes, ClusterState, Event, NodeId, Pod, PodId, Priority, Resources};
use kube_packd::optimizer::{OptimizerConfig, OptimizingScheduler};

fn main() {
    // Two nodes of 10 CPU. Low-priority pods occupy 6+6 and 5+4 split so
    // that the pending high-priority pod (9 CPU) fits on neither node,
    // and no single eviction on one node frees 9 — but moving the 4-CPU
    // pod from node B to node A (4+6=10) leaves 9 free on B... which is
    // exactly the coordinated move the solver finds.
    let nodes = identical_nodes(2, Resources::new(10_000, 10_000));
    let pods = vec![
        Pod::new(0, "web-a", Resources::new(6_000, 1_000), Priority(2)),
        Pod::new(1, "web-b", Resources::new(5_000, 1_000), Priority(2)),
        Pod::new(2, "web-c", Resources::new(4_000, 1_000), Priority(2)),
        Pod::new(3, "db-primary", Resources::new(9_000, 2_000), Priority(0)),
    ];
    let mut state = ClusterState::new(nodes, pods);
    state.bind(PodId(0), NodeId(0)).unwrap(); // node A: 6
    state.bind(PodId(1), NodeId(1)).unwrap(); // node B: 5
    state.bind(PodId(2), NodeId(1)).unwrap(); // node B: 5+4 = 9

    println!("before: A={:?} B={:?} pending=db-primary(9c, priority 0)\n",
        state.pods_on(NodeId(0)).len(), state.pods_on(NodeId(1)).len());

    let mut scheduler = OptimizingScheduler::new(2, OptimizerConfig::with_timeout(3.0));
    let report = scheduler.run(&mut state);

    assert!(report.solver_invoked, "db-primary must pend first");
    assert!(report.improved, "solver must admit the high-priority pod");
    assert!(
        state.assignment_of(PodId(3)).is_some(),
        "db-primary placed via cross-node pre-emption"
    );

    println!("placement after cross-node pre-emption:");
    for pod in state.pods() {
        println!(
            "  {:12} prio={} -> {}",
            pod.name,
            pod.priority.0,
            state
                .assignment_of(pod.id)
                .map(|n| state.node(n).name.clone())
                .unwrap_or_else(|| "<pending>".into())
        );
    }

    let moves = state.events.count(|e| matches!(e, Event::Evict { .. }));
    println!("\nevictions performed : {moves}");
    println!("placed vector       : {:?} (was {:?})", report.placed_after, report.placed_before);
    println!("priority_preemption OK");
}
