//! End-to-end driver: all three layers composed on a realistic workload.
//!
//! This is the repo's full-system proof:
//!
//! * **L1/L2** — the scheduler's scoring phase executes the AOT-compiled
//!   XLA artifact (Pallas kernel + JAX graph, built by `make artifacts`)
//!   through the PJRT runtime — no Python anywhere in this process.
//! * **L3** — the Rust coordinator: KWOK-style simulator, scheduling
//!   framework, and the paper's constraint-solver fallback with
//!   cross-node pre-emption.
//!
//! Workload: three tenant waves (batch / web / critical) of ReplicaSets
//! on a 95–105% loaded 8-node cluster, scheduled wave by wave. Reports
//! the paper's headline metrics: placements improved, utilisation
//! delta, solver latency, and scheduler throughput.
//!
//! Run: `make artifacts && cargo run --release --example e2e_cluster`

use std::time::Instant;

use kube_packd::cluster::{ClusterState, Event};
use kube_packd::metrics::lex_better;
use kube_packd::optimizer::{OptimizerConfig, OptimizingScheduler};
use kube_packd::runtime::XlaScorer;
use kube_packd::scheduler::default::BatchScorer;
use kube_packd::util::stats;
use kube_packd::workload::{GenParams, Instance};

fn main() -> anyhow::Result<()> {
    // --- runtime: load the AOT artifacts (L1+L2) -------------------------
    let mut xla = match XlaScorer::from_artifacts() {
        Ok(s) => {
            println!("PJRT runtime up — scoring on the compiled XLA/Pallas artifact");
            Some(s)
        }
        Err(e) => {
            println!("(artifacts unavailable: {e:#} — falling back to native scorer)");
            None
        }
    };

    let params = GenParams {
        nodes: 8,
        pods_per_node: 6,
        priority_tiers: 3, // batch=2, web=1, critical=0
        usage: 1.0,
    };
    let waves = 6usize;
    // Challenging waves: ones the default scheduler cannot fully place
    // (the paper's dataset construction).
    let instances = Instance::generate_challenging(params, waves, 4242, waves * 60);
    let mut improved_count = 0usize;
    let mut solver_calls = 0usize;
    let mut solver_latencies = Vec::new();
    let mut util_before = Vec::new();
    let mut util_after = Vec::new();
    let mut total_cycles = 0usize;
    let mut scorer_checks = 0usize;
    let t0 = Instant::now();

    for (wave, inst) in instances.iter().enumerate() {
        let mut state = ClusterState::new(inst.nodes.clone(), inst.pods.clone());

        // Cross-check a scoring row on the real XLA artifact against the
        // native formula for this live state (L1/L2 ↔ L3 parity, on the
        // actual request path data).
        if let Some(x) = xla.as_mut() {
            let pending = state.pending_pods();
            let rows = x.score_matrix(&state, &pending);
            for (k, &pod) in pending.iter().enumerate() {
                let native = kube_packd::runtime::NativeScorer.score_row(&state, pod);
                for (a, b) in rows[k].iter().zip(&native) {
                    assert!((a - b).abs() < 1e-4, "XLA/native scorer divergence");
                }
                scorer_checks += rows[k].len();
            }
        }

        let mut sched = OptimizingScheduler::new(params.p_max(), OptimizerConfig::with_timeout(1.0));
        let report = sched.run(&mut state);
        state.check_invariants().expect("state corrupt");

        total_cycles += report.default_stats.cycles;
        let (cpu_b, _) = {
            // baseline utilisation = utilisation the default pass achieved
            // (reconstructed from placed_before on an untouched clone)
            let mut base = ClusterState::new(inst.nodes.clone(), inst.pods.clone());
            let mut k = kube_packd::simulator::KwokSimulator::new(params.p_max());
            k.run_on(&mut base);
            base.utilization()
        };
        let (cpu_a, _) = state.utilization();
        util_before.push(cpu_b * 100.0);
        util_after.push(cpu_a * 100.0);

        if report.solver_invoked {
            solver_calls += 1;
            solver_latencies.push(report.solver_wall.as_secs_f64());
            if report.improved {
                improved_count += 1;
                assert!(lex_better(&report.placed_after, &report.placed_before));
            }
        }
        println!(
            "wave {wave}: placed {:?} -> {:?}  (solver={} improved={} moves={} evictions={})",
            report.placed_before,
            report.placed_after,
            report.solver_invoked,
            report.improved,
            report.disruptions,
            state.events.count(|e| matches!(e, Event::Evict { .. })),
        );
    }

    let wall = t0.elapsed().as_secs_f64();
    println!("\n=== end-to-end summary ({waves} waves, {} pods each) ===", params.pod_count());
    println!("scheduling cycles          : {total_cycles} ({:.0} cycles/s overall wall)", total_cycles as f64 / wall);
    println!("solver invoked             : {solver_calls}/{waves} waves");
    println!("placements improved        : {improved_count}/{solver_calls} solver calls");
    println!("mean solver latency        : {:.3}s (p95 {:.3}s)",
        stats::mean(&solver_latencies), stats::percentile(&solver_latencies, 95.0));
    println!("mean cpu util (default)    : {:.1}%", stats::mean(&util_before));
    println!("mean cpu util (optimised)  : {:.1}%", stats::mean(&util_after));
    println!("Δ cpu util                 : {:+.1} pp", stats::mean(&util_after) - stats::mean(&util_before));
    if let Some(x) = &xla {
        println!("XLA scorer                 : {} PJRT executions, {scorer_checks} scores parity-checked", x.executions);
    }
    println!("\ne2e_cluster OK");
    Ok(())
}
