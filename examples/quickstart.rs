//! Quickstart: the paper's Figure 1 in ~40 lines.
//!
//! Two 4 Gi nodes; pods of 2, 2 and 3 Gi. The default kube-scheduler's
//! LeastAllocated heuristic spreads the first two pods across both nodes
//! and strands the third, even though the cluster has room for all
//! three. The constraint-based fallback repacks optimally.
//!
//! Run: `cargo run --release --example quickstart`

use kube_packd::cluster::{identical_nodes, ClusterState, Pod, Priority, Resources};
use kube_packd::optimizer::{OptimizerConfig, OptimizingScheduler};

fn main() {
    // 2-node cluster, 4 GiB of memory each (CPU is not the bottleneck).
    let nodes = identical_nodes(2, Resources::new(4000, 4096));
    let pods = vec![
        Pod::new(0, "pod-1", Resources::new(100, 2048), Priority(0)),
        Pod::new(1, "pod-2", Resources::new(100, 2048), Priority(0)),
        Pod::new(2, "pod-3", Resources::new(100, 3072), Priority(0)),
    ];
    let mut state = ClusterState::new(nodes, pods);

    // The default scheduler + constraint-solver fallback, exactly as the
    // paper deploys it: heuristics first, solver only when pods pend.
    let mut scheduler = OptimizingScheduler::new(0, OptimizerConfig::with_timeout(2.0));
    let report = scheduler.run(&mut state);

    println!("default scheduler placed : {:?}", report.placed_before);
    println!("solver invoked           : {}", report.solver_invoked);
    println!("after fallback           : {:?}", report.placed_after);
    println!("pods moved               : {}", report.disruptions);
    println!("proved optimal           : {}", report.proved_optimal);
    println!();
    for pod in state.pods() {
        let placement = state
            .assignment_of(pod.id)
            .map(|n| state.node(n).name.clone())
            .unwrap_or_else(|| "<pending>".into());
        println!("  {:6} ({:4} MiB) -> {placement}", pod.name, pod.request.ram);
    }

    assert_eq!(report.placed_after, vec![3], "all three pods must fit");
    println!("\nquickstart OK — fragmentation repaired by the optimiser");
}
