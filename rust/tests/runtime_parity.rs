//! XLA runtime parity: the compiled L1/L2 artifact must agree with the
//! native Rust scorer (which itself mirrors `kernels/ref.py`) on every
//! score, on randomised cluster states.
//!
//! These tests require `make artifacts`; they skip (with a notice) when
//! the artifacts are missing so `cargo test` stays green in a fresh
//! checkout.

use kube_packd::cluster::{ClusterState, NodeId, PodId};
use kube_packd::runtime::{NativeScorer, XlaScorer, INFEASIBLE};
use kube_packd::scheduler::default::BatchScorer;
use kube_packd::util::rng::Rng;
use kube_packd::workload::{GenParams, Instance};

fn xla() -> Option<XlaScorer> {
    match XlaScorer::from_artifacts() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping runtime parity: {e:#}");
            None
        }
    }
}

#[test]
fn parity_on_random_states() {
    let Some(mut xla) = xla() else { return };
    let mut rng = Rng::new(0xA17A);
    for case in 0..10 {
        let params = GenParams {
            nodes: rng.range_usize(1, 30),
            pods_per_node: rng.range_usize(1, 8),
            priority_tiers: 1,
            usage: 0.9 + rng.f64() * 0.2,
        };
        let inst = Instance::generate(params, rng.next_u64());
        let mut state = ClusterState::new(inst.nodes.clone(), inst.pods.clone());
        // randomly place a subset to vary the free vectors
        for i in 0..state.pods().len() {
            if rng.chance(0.5) {
                let node = NodeId(rng.below(params.nodes as u64) as u32);
                let _ = state.bind(PodId(i as u32), node);
            }
        }
        let pending = state.pending_pods();
        if pending.is_empty() {
            continue;
        }
        let rows = xla.score_matrix(&state, &pending);
        for (k, &pod) in pending.iter().enumerate() {
            let native = NativeScorer.score_row(&state, pod);
            assert_eq!(rows[k].len(), native.len());
            for (j, (a, b)) in rows[k].iter().zip(&native).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4,
                    "case {case}: pod {pod:?} node {j}: xla={a} native={b}"
                );
                // feasibility marker must agree exactly
                assert_eq!(*a == INFEASIBLE, *b == INFEASIBLE);
            }
        }
    }
}

#[test]
fn parity_padding_never_selects_ghost_nodes() {
    let Some(mut xla) = xla() else { return };
    // 3 real nodes in a (64, 8) variant: 5 padded ghost nodes.
    let params = GenParams {
        nodes: 3,
        pods_per_node: 4,
        priority_tiers: 1,
        usage: 1.0,
    };
    let inst = Instance::generate(params, 99);
    let state = ClusterState::new(inst.nodes.clone(), inst.pods.clone());
    let pending = state.pending_pods();
    let rows = xla.score_matrix(&state, &pending);
    for row in &rows {
        assert_eq!(row.len(), 3, "rows must be truncated to real nodes");
    }
}

#[test]
fn parity_large_variant_exercised() {
    let Some(mut xla) = xla() else { return };
    // 20 nodes forces the (256, 32) artifact.
    let params = GenParams {
        nodes: 20,
        pods_per_node: 8,
        priority_tiers: 1,
        usage: 1.0,
    };
    let inst = Instance::generate(params, 7);
    let state = ClusterState::new(inst.nodes.clone(), inst.pods.clone());
    let pending = state.pending_pods();
    assert_eq!(pending.len(), 160);
    let rows = xla.score_matrix(&state, &pending);
    assert_eq!(rows.len(), 160);
    let native = NativeScorer.score_row(&state, pending[0]);
    for (a, b) in rows[0].iter().zip(&native) {
        assert!((a - b).abs() < 1e-4);
    }
    assert_eq!(xla.executions, 1, "one PJRT execute for the whole batch");
}

#[test]
fn infeasible_pod_all_negative_through_xla() {
    let Some(mut xla) = xla() else { return };
    let params = GenParams {
        nodes: 2,
        pods_per_node: 2,
        priority_tiers: 1,
        usage: 1.0,
    };
    let mut inst = Instance::generate(params, 3);
    // make pod 0 impossibly large
    inst.pods[0].request = kube_packd::cluster::Resources::new(10_000_000, 10_000_000);
    let state = ClusterState::new(inst.nodes.clone(), inst.pods.clone());
    let row = xla.score_row(&state, PodId(0));
    assert!(row.iter().all(|&s| s == INFEASIBLE));
}
