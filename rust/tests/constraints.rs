//! Integration tests for the composable constraint-module API: taints,
//! anti-affinity, topology spread, and extended resources end to end —
//! scenarios where the CP fallback places strictly more pods than the
//! default scheduler — plus the CP ⇄ filter-plugin feasibility parity
//! property and the graceful-rollback path for incomplete plans.

use kube_packd::cluster::{
    identical_nodes, ClusterState, Event, Node, NodeId, Pod, PodId, Priority, Resources,
    StateError, Taint, Toleration,
};
use kube_packd::optimizer::builder::{ModelCtx, PackingModelBuilder};
use kube_packd::optimizer::constraints::{ConstraintModule, ModuleRegistry};
use kube_packd::optimizer::{optimize, OptimizerConfig, OptimizingScheduler};
use kube_packd::scheduler::framework::{CycleContext, FilterPlugin};
use kube_packd::scheduler::DefaultScheduler;
use kube_packd::solver::Model;
use kube_packd::util::prop::check;
use kube_packd::util::rng::Rng;

fn cfg() -> OptimizerConfig {
    OptimizerConfig::with_timeout(5.0)
}

// ---------------------------------------------------------------------------
// Taints
// ---------------------------------------------------------------------------

#[test]
fn taints_fallback_repacks_within_untainted_nodes() {
    // Figure-1 fragmentation confined to two of three nodes: node 0 is
    // tainted and nobody tolerates it. The default scheduler spreads the
    // first two pods over nodes 1,2 and strands the third; the CP
    // fallback repacks — without ever touching the tainted node.
    let mut nodes = identical_nodes(3, Resources::new(4000, 4096));
    nodes[0] = nodes[0]
        .clone()
        .with_taint(Taint::no_schedule("dedicated", "infra"));
    let pods = vec![
        Pod::new(0, "pod-1", Resources::new(10, 2048), Priority(0)),
        Pod::new(1, "pod-2", Resources::new(10, 2048), Priority(0)),
        Pod::new(2, "pod-3", Resources::new(10, 3072), Priority(0)),
    ];
    let mut state = ClusterState::new(nodes, pods);
    let mut osched = OptimizingScheduler::new(0, cfg());
    let report = osched.run(&mut state);

    assert!(report.solver_invoked);
    assert!(report.improved, "CP must beat the default scheduler here");
    assert!(!report.plan_incomplete);
    assert_eq!(report.placed_before, vec![2]);
    assert_eq!(report.placed_after, vec![3]);
    for pod in [PodId(0), PodId(1), PodId(2)] {
        assert_ne!(
            state.assignment_of(pod),
            Some(NodeId(0)),
            "tainted node must stay empty"
        );
    }
    state.check_invariants().unwrap();
}

#[test]
fn tolerating_pod_may_use_tainted_node() {
    let mut nodes = identical_nodes(2, Resources::new(1000, 1000));
    nodes[0] = nodes[0]
        .clone()
        .with_taint(Taint::no_schedule("dedicated", "batch"));
    let pods = vec![
        Pod::new(0, "tolerant", Resources::new(100, 100), Priority(0))
            .with_toleration(Toleration::exists("dedicated")),
        // fills node 1 completely, so only the tainted node 0 remains
        Pod::new(1, "filler", Resources::new(1000, 1000), Priority(0)),
    ];
    let mut state = ClusterState::new(nodes, pods);
    state.bind(PodId(1), NodeId(1)).unwrap();
    let res = optimize(&state, 0, &cfg()).unwrap();
    assert_eq!(res.target[0], Some(NodeId(0)), "toleration unlocks the node");
    // and a direct bind of an intolerant pod is refused by the state
    let intolerant = state.add_pod(Pod::new(0, "plain", Resources::new(1, 1), Priority(0)));
    assert!(matches!(
        state.bind(intolerant, NodeId(0)),
        Err(StateError::TaintNotTolerated { .. })
    ));
}

// ---------------------------------------------------------------------------
// Pod anti-affinity
// ---------------------------------------------------------------------------

#[test]
fn anti_affinity_fallback_beats_default() {
    // Two movable ballast pods sit on node B. Two mutually anti-affine
    // pods arrive; the default scheduler places one on A, then dead-ends
    // (A excluded by anti-affinity, B lacks capacity). The CP fallback
    // moves one ballast pod to A and places everything.
    let nodes = identical_nodes(2, Resources::new(1200, 1200));
    let pods = vec![
        Pod::new(0, "m-1", Resources::new(400, 400), Priority(0)),
        Pod::new(1, "m-2", Resources::new(400, 400), Priority(0)),
        Pod::new(2, "web-0", Resources::new(500, 500), Priority(0))
            .with_label("app", "web")
            .with_anti_affinity("app", "web"),
        Pod::new(3, "web-1", Resources::new(500, 500), Priority(0))
            .with_label("app", "web")
            .with_anti_affinity("app", "web"),
    ];
    let mut state = ClusterState::new(nodes, pods);
    state.bind(PodId(0), NodeId(1)).unwrap();
    state.bind(PodId(1), NodeId(1)).unwrap();

    let mut osched = OptimizingScheduler::new(0, cfg());
    let report = osched.run(&mut state);

    assert!(report.solver_invoked);
    assert!(report.improved);
    assert!(!report.plan_incomplete);
    assert_eq!(report.placed_before, vec![3]);
    assert_eq!(report.placed_after, vec![4]);
    let a = state.assignment_of(PodId(2)).unwrap();
    let b = state.assignment_of(PodId(3)).unwrap();
    assert_ne!(a, b, "anti-affine pods must not share a node");
    state.check_invariants().unwrap();
}

// ---------------------------------------------------------------------------
// Topology spread
// ---------------------------------------------------------------------------

#[test]
fn topology_spread_fallback_beats_default() {
    // A ReplicaSet of two pods with max skew 1. The default scheduler
    // parks the first replica on the emptier node A, then dead-ends:
    // a second replica on A would skew 2−0, and B lacks capacity. The
    // CP fallback frees B by moving ballast and splits the group.
    let nodes = identical_nodes(2, Resources::new(1000, 1000));
    let pods = vec![
        Pod::new(0, "m-1", Resources::new(300, 300), Priority(0)),
        Pod::new(1, "m-2", Resources::new(300, 300), Priority(0)),
        Pod::new(2, "grp-0", Resources::new(450, 450), Priority(0))
            .with_owner(7)
            .with_spread(1),
        Pod::new(3, "grp-1", Resources::new(450, 450), Priority(0))
            .with_owner(7)
            .with_spread(1),
    ];
    let mut state = ClusterState::new(nodes, pods);
    state.bind(PodId(0), NodeId(1)).unwrap();
    state.bind(PodId(1), NodeId(1)).unwrap();

    let mut osched = OptimizingScheduler::new(0, cfg());
    let report = osched.run(&mut state);

    assert!(report.solver_invoked);
    assert!(report.improved);
    assert!(!report.plan_incomplete);
    assert_eq!(report.placed_before, vec![3]);
    assert_eq!(report.placed_after, vec![4]);
    let a = state.assignment_of(PodId(2)).unwrap();
    let b = state.assignment_of(PodId(3)).unwrap();
    assert_ne!(a, b, "skew 1 forces the replicas apart");
    state.check_invariants().unwrap();
}

#[test]
fn multi_replica_spread_plan_survives_transient_skew() {
    // A 3-replica group (skew 1) must end up split 2+1 across unequal
    // nodes. The plan binds pods one at a time, so the intermediate
    // state can be transiently skewed (2,0) before the third replica
    // lands — the TopologySpread filter exempts plan-pinned placements
    // precisely so such CP-validated plans complete instead of aborting.
    let nodes = vec![
        Node::new(0, "node-000", Resources::new(2000, 2000)),
        Node::new(1, "node-001", Resources::new(1000, 1000)),
    ];
    let pods = vec![
        Pod::new(0, "ballast", Resources::new(700, 700), Priority(0)),
        Pod::new(1, "grp-0", Resources::new(400, 400), Priority(0))
            .with_owner(9)
            .with_spread(1),
        Pod::new(2, "grp-1", Resources::new(400, 400), Priority(0))
            .with_owner(9)
            .with_spread(1),
        Pod::new(3, "grp-2", Resources::new(400, 400), Priority(0))
            .with_owner(9)
            .with_spread(1),
    ];
    let mut state = ClusterState::new(nodes, pods);
    state.bind(PodId(0), NodeId(1)).unwrap();

    let mut osched = OptimizingScheduler::new(0, cfg());
    let report = osched.run(&mut state);

    assert!(report.solver_invoked);
    assert!(report.improved);
    assert!(!report.plan_incomplete, "CP-validated plan must complete");
    assert_eq!(report.placed_before, vec![2]);
    assert_eq!(report.placed_after, vec![4]);
    // final split honours the skew even though intermediates may not
    let on_a = [1, 2, 3]
        .iter()
        .filter(|&&i| state.assignment_of(PodId(i)) == Some(NodeId(0)))
        .count() as i64;
    let on_b = 3 - on_a;
    assert!((on_a - on_b).abs() <= 1, "final skew {on_a}/{on_b}");
    state.check_invariants().unwrap();
}

// ---------------------------------------------------------------------------
// Extended resources
// ---------------------------------------------------------------------------

#[test]
fn extended_resources_bound_cp_and_filters_identically() {
    // Only node B offers GPUs (2 of them); three one-GPU pods arrive.
    // Both the default scheduler and the CP model must cap placements at
    // two — the solver proves the default outcome optimal instead of
    // "improving" onto a GPU-less node.
    let mut nodes = identical_nodes(2, Resources::new(1000, 1000));
    nodes[1] = nodes[1].clone().with_extended("gpu", 2);
    let pods: Vec<Pod> = (0..3)
        .map(|i| {
            Pod::new(i, format!("gpu-{i}"), Resources::new(100, 100), Priority(0))
                .with_extended("gpu", 1)
        })
        .collect();
    let mut state = ClusterState::new(nodes, pods);
    let mut osched = OptimizingScheduler::new(0, cfg());
    let report = osched.run(&mut state);

    assert!(report.solver_invoked);
    assert!(!report.improved, "GPU capacity binds the CP model too");
    assert!(report.proved_optimal);
    assert_eq!(report.placed_after, vec![2]);
    assert_eq!(state.free_extended(NodeId(1), "gpu"), 0);
    assert_eq!(state.assignment_of(PodId(0)), Some(NodeId(1)));
    // the state itself also refuses a GPU pod on the GPU-less node
    assert!(matches!(
        state.clone().bind(PodId(2), NodeId(0)),
        Err(StateError::InsufficientExtended { .. })
    ));
    state.check_invariants().unwrap();
}

// ---------------------------------------------------------------------------
// Custom modules + graceful rollback
// ---------------------------------------------------------------------------

/// A custom constraint module: pods named `vip-*` never land on node 0.
struct Quarantine;

impl ConstraintModule for Quarantine {
    fn name(&self) -> &'static str {
        "Quarantine"
    }
    fn admits(&self, _state: &ClusterState, pod: &Pod, node: &Node) -> bool {
        !(node.id == NodeId(0) && pod.name.starts_with("vip-"))
    }
    fn emit(&self, _ctx: &ModelCtx, _m: &mut Model) {}
}

#[test]
fn custom_module_extends_the_model() {
    let nodes = identical_nodes(2, Resources::new(1000, 1000));
    let pods = vec![Pod::new(0, "vip-0", Resources::new(100, 100), Priority(0))];
    let state = ClusterState::new(nodes, pods);
    let custom = cfg().with_modules(ModuleRegistry::standard().with(Quarantine));
    let res = optimize(&state, 0, &custom).unwrap();
    assert_eq!(res.target[0], Some(NodeId(1)));
    // without the module, the lexicographic tie-break prefers node 0
    let res = optimize(&state, 0, &cfg()).unwrap();
    assert_eq!(res.target[0], Some(NodeId(0)));
}

/// A filter with no mirroring constraint module: pod 2 is unschedulable
/// everywhere (e.g. an image-pull or volume-topology gate the CP model
/// knows nothing about).
struct RejectPodTwo;

impl FilterPlugin for RejectPodTwo {
    fn filter(&self, _state: &ClusterState, pod: PodId, _node: NodeId, _ctx: &CycleContext) -> bool {
        pod != PodId(2)
    }
    fn name(&self) -> &'static str {
        "RejectPodTwo"
    }
}

#[test]
fn incomplete_plan_rolls_back_gracefully() {
    // Figure-1, but a custom filter vetoes pod 2 everywhere. The CP
    // model (unaware of the filter) plans all three pods; executing the
    // plan fails at pod 2 — previously an assert/crash, now a graceful
    // rollback surfaced in the report.
    let nodes = identical_nodes(2, Resources::new(4000, 4096));
    let pods = vec![
        Pod::new(0, "pod-1", Resources::new(10, 2048), Priority(0)),
        Pod::new(1, "pod-2", Resources::new(10, 2048), Priority(0)),
        Pod::new(2, "pod-3", Resources::new(10, 3072), Priority(0)),
    ];
    let mut state = ClusterState::new(nodes, pods);
    let mut osched = OptimizingScheduler::new(0, cfg());
    osched.scheduler.framework.filter.push(Box::new(RejectPodTwo));

    let report = osched.run(&mut state);

    assert!(report.solver_invoked);
    assert!(report.plan_incomplete, "plan must be reported incomplete");
    assert!(!report.improved, "nothing actually improved");
    assert_eq!(report.placed_after, vec![2]);
    assert_eq!(state.assignment_of(PodId(2)), None);
    assert!(state
        .events
        .all()
        .iter()
        .any(|e| matches!(e, Event::PlanAborted { missing: 1, .. })));
    // pod 2 is parked again, ready for a future retry
    assert_eq!(osched.scheduler.queue.unschedulable_pods(), vec![PodId(2)]);
    state.check_invariants().unwrap();
}

// ---------------------------------------------------------------------------
// CP ⇄ filter-plugin feasibility parity (proptest)
// ---------------------------------------------------------------------------

/// Random cluster with selectors, taints, and pod anti-affinity (spread
/// is excluded on purpose: it is order-sensitive by design, so single-pod
/// filter feasibility and whole-assignment CP feasibility legitimately
/// differ mid-sequence).
fn random_constrained_cluster(rng: &mut Rng) -> ClusterState {
    let n_nodes = rng.range_usize(2, 4);
    let mut nodes = identical_nodes(n_nodes, Resources::new(1000, 1000));
    for node in nodes.iter_mut() {
        let zone = if rng.chance(0.5) { "a" } else { "b" };
        *node = node.clone().with_label("zone", zone);
        if rng.chance(0.3) {
            *node = node.clone().with_taint(Taint::no_schedule("team", "red"));
        }
    }
    let n_pods = rng.range_usize(2, 10);
    let pods: Vec<Pod> = (0..n_pods)
        .map(|i| {
            let mut p = Pod::new(
                i as u32,
                format!("p-{i}"),
                Resources::new(rng.range_i64(50, 500), rng.range_i64(50, 500)),
                Priority(rng.below(2) as u32),
            );
            if rng.chance(0.3) {
                let zone = if rng.chance(0.5) { "a" } else { "b" };
                p = p.with_selector("zone", zone);
            }
            if rng.chance(0.4) {
                p = p.with_toleration(Toleration::equal("team", "red"));
            }
            let group = format!("g{}", rng.below(3));
            p = p.with_label("app", &group);
            if rng.chance(0.3) {
                let target = format!("g{}", rng.below(3));
                p = p.with_anti_affinity("app", &target);
            }
            p
        })
        .collect();
    ClusterState::new(nodes, pods)
}

/// Filter set matching the default profile (fresh per check).
fn filters() -> Vec<Box<dyn FilterPlugin>> {
    let sched = DefaultScheduler::kwok_default();
    sched.framework.filter
}

#[test]
fn proptest_cp_assignment_passes_filter_plugins() {
    // CP → filters: every placement in an optimiser target is accepted
    // by the framework's filter plugins when replayed bind-by-bind.
    check(
        "cp_assignment_passes_filters",
        0xC0_FFEE,
        24,
        random_constrained_cluster,
        |state| {
            let p_max = 1;
            let Some(res) = optimize(state, p_max, &OptimizerConfig::with_timeout(2.0)) else {
                return Ok(()); // solver budget exhausted: nothing to check
            };
            ModuleRegistry::standard()
                .audit(state, &res.target)
                .map_err(|e| format!("module audit rejected the target: {e}"))?;
            let mut live = state.clone();
            let fs = filters();
            let ctx = CycleContext::default();
            for (i, t) in res.target.iter().enumerate() {
                let Some(node) = t else { continue };
                for f in &fs {
                    if !f.filter(&live, PodId(i as u32), *node, &ctx) {
                        return Err(format!(
                            "filter {} rejects pod {i} on {node:?} (CP admitted it)",
                            f.name()
                        ));
                    }
                }
                live.bind(PodId(i as u32), *node)
                    .map_err(|e| format!("bind failed: {e}"))?;
            }
            live.check_invariants()
        },
    );
}

#[test]
fn proptest_filter_schedule_is_cp_feasible() {
    // Filters → CP: any assignment the default scheduler (with the
    // constraint filters) produces is a feasible solution of the CP
    // model built from the standard module registry.
    check(
        "filter_schedule_is_cp_feasible",
        0xBEEF,
        24,
        random_constrained_cluster,
        |state| {
            let mut live = state.clone();
            let mut sched = DefaultScheduler::kwok_default();
            sched.enqueue_pending(&live);
            sched.run_queue(&mut live);

            let registry = ModuleRegistry::standard();
            let (model, table) = PackingModelBuilder::new(&live, 1, &registry).build();
            let mut values = vec![false; model.num_vars()];
            for (i, a) in live.assignment().iter().enumerate() {
                let Some(node) = a else { continue };
                let Some(v) = table.var(i, node.idx()) else {
                    return Err(format!(
                        "scheduled pod {i} has no CP variable on {node:?}"
                    ));
                };
                values[v.idx()] = true;
            }
            if !model.feasible(&values) {
                return Err("scheduled assignment violates the CP model".into());
            }
            registry.audit(&live, live.assignment())
        },
    );
}
