//! Portfolio-subsystem integration tests: thread-count determinism,
//! decomposition merge safety against `ClusterState` invariants, and
//! bit-for-bit parity of `threads = 1` with the legacy solver.
//!
//! Determinism caveat (same as the churn replay digests): byte-identity
//! across worker counts holds whenever every racer completes inside its
//! window, so these tests use tiny models under generous deadlines.

use kube_packd::cluster::{
    identical_nodes, ClusterState, NodeId, Pod, PodId, Priority, Resources, Taint, Toleration,
};
use kube_packd::optimizer::algorithm::{optimize, OptimizerConfig};
use kube_packd::optimizer::plan::MovePlan;
use kube_packd::portfolio::{solve_portfolio, PortfolioConfig};
use kube_packd::simulator::KwokSimulator;
use kube_packd::solver::{solve_max, LinearExpr, Model, SolveStatus, SolverConfig};
use kube_packd::telemetry::Deadline;
use kube_packd::util::prop::check;
use kube_packd::util::rng::Rng;
use kube_packd::workload::{ConstraintProfile, GenParams, Instance};

/// Random small packing model (pods × nodes, two capacity dimensions).
fn random_packing(rng: &mut Rng) -> (Model, LinearExpr) {
    let pods = rng.range_usize(2, 10);
    let nodes = rng.range_usize(1, 4);
    let mut m = Model::new();
    let mut vars = Vec::new();
    let demands: Vec<(i64, i64)> = (0..pods)
        .map(|_| (rng.range_i64(50, 600), rng.range_i64(50, 600)))
        .collect();
    for _ in 0..pods {
        let xs = m.new_vars(nodes);
        m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
        vars.push(xs);
    }
    let cap = rng.range_i64(300, 1500);
    let mut cpu_class = Vec::new();
    let mut ram_class = Vec::new();
    for j in 0..nodes {
        cpu_class.push(m.next_constraint_index());
        m.add_le(
            LinearExpr::of(vars.iter().zip(&demands).map(|(xs, &(c, _))| (xs[j], c))),
            cap,
        );
        ram_class.push(m.next_constraint_index());
        m.add_le(
            LinearExpr::of(vars.iter().zip(&demands).map(|(xs, &(_, r))| (xs[j], r))),
            cap,
        );
    }
    m.add_resource_class(cpu_class);
    m.add_resource_class(ram_class);
    let obj = LinearExpr::of(vars.iter().flatten().map(|&v| (v, 1)));
    (m, obj)
}

#[test]
fn threads_one_is_bit_for_bit_the_legacy_solver() {
    check(
        "portfolio_threads1_legacy_parity",
        0x70F0,
        20,
        random_packing,
        |(m, obj)| {
            let legacy = solve_max(m, obj, Deadline::unlimited(), &SolverConfig::default());
            let out = solve_portfolio(
                m,
                obj,
                Deadline::unlimited(),
                &SolverConfig::default(),
                &PortfolioConfig::with_threads(1),
            );
            if out.solution.status != legacy.status
                || out.solution.objective != legacy.objective
                || out.solution.values != legacy.values
            {
                return Err(format!(
                    "threads=1 diverged: {:?}/{} vs {:?}/{}",
                    out.solution.status, out.solution.objective, legacy.status, legacy.objective
                ));
            }
            Ok(())
        },
    );
}

/// The determinism satellite: the same model/seed solved with
/// `threads` ∈ {1, 2, 8} yields byte-identical assignments and
/// objectives (every racer completes — unlimited deadline).
#[test]
fn prop_solver_thread_counts_yield_identical_solutions() {
    check(
        "portfolio_thread_count_independence",
        0xD37E,
        15,
        random_packing,
        |(m, obj)| {
            let runs: Vec<_> = [1usize, 2, 8]
                .iter()
                .map(|&threads| {
                    solve_portfolio(
                        m,
                        obj,
                        Deadline::unlimited(),
                        &SolverConfig::default(),
                        &PortfolioConfig::with_threads(threads),
                    )
                    .solution
                })
                .collect();
            for (i, run) in runs.iter().enumerate().skip(1) {
                if run.status != runs[0].status
                    || run.objective != runs[0].objective
                    || run.values != runs[0].values
                {
                    return Err(format!(
                        "run {i} diverged: {:?}/{} vs {:?}/{}",
                        run.status, run.objective, runs[0].status, runs[0].objective
                    ));
                }
            }
            Ok(())
        },
    );
}

/// End-to-end determinism through Algorithm 1: identical plans and
/// per-tier objective vectors for `threads` ∈ {1, 2, 8}.
#[test]
fn prop_optimizer_thread_counts_yield_identical_plans() {
    check(
        "optimizer_thread_count_independence",
        0xAB5E,
        6,
        |rng| {
            // Tiny on purpose: byte-identity across worker counts is
            // only guaranteed when every solve completes in-window.
            let params = GenParams {
                nodes: rng.range_usize(2, 4),
                pods_per_node: rng.range_usize(2, 3),
                priority_tiers: rng.range_usize(1, 3) as u32,
                usage: 0.9 + rng.f64() * 0.2,
            };
            Instance::generate(params, rng.next_u64())
        },
        |inst| {
            let p_max = inst.params.p_max();
            let mut sim = KwokSimulator::new(p_max);
            let (state, _) = sim.run(inst.nodes.clone(), inst.pods.clone());
            let runs: Vec<_> = [1usize, 2, 8]
                .iter()
                .map(|&threads| {
                    optimize(
                        &state,
                        p_max,
                        &OptimizerConfig::with_timeout(10.0).with_threads(threads),
                    )
                })
                .collect();
            let Some(base) = &runs[0] else {
                return if runs.iter().all(|r| r.is_none()) {
                    Ok(())
                } else {
                    Err("solvability depended on thread count".into())
                };
            };
            for (i, run) in runs.iter().enumerate().skip(1) {
                let Some(run) = run else {
                    return Err(format!("threads run {i} failed where base succeeded"));
                };
                if run.target != base.target {
                    return Err(format!("plan diverged at run {i}"));
                }
                if run.placed_per_priority != base.placed_per_priority {
                    return Err(format!("objective vector diverged at run {i}"));
                }
                if run.proved_optimal != base.proved_optimal {
                    return Err(format!("certificate diverged at run {i}"));
                }
                let tiers: Vec<_> = run
                    .tiers
                    .iter()
                    .map(|t| (t.phase1_placed, t.phase2_metric))
                    .collect();
                let base_tiers: Vec<_> = base
                    .tiers
                    .iter()
                    .map(|t| (t.phase1_placed, t.phase2_metric))
                    .collect();
                if tiers != base_tiers {
                    return Err(format!("per-tier metrics diverged at run {i}"));
                }
            }
            Ok(())
        },
    );
}

/// Decomposition-merge safety: plans produced by the parallel path must
/// execute cleanly and preserve every `ClusterState` invariant, on both
/// plain and taint-partitioned (genuinely decomposable) workloads.
#[test]
fn prop_decomposed_plans_preserve_cluster_invariants() {
    check(
        "portfolio_plan_invariants",
        0x1A7B,
        8,
        |rng| {
            let params = GenParams {
                nodes: rng.range_usize(3, 6),
                pods_per_node: rng.range_usize(2, 4),
                priority_tiers: rng.range_usize(1, 3) as u32,
                usage: 0.9 + rng.f64() * 0.15,
            };
            let profile = if rng.chance(0.5) {
                ConstraintProfile::Taints
            } else {
                ConstraintProfile::None
            };
            Instance::generate_constrained(params, rng.next_u64(), profile)
        },
        |inst| {
            let p_max = inst.params.p_max();
            let mut sim = KwokSimulator::new(p_max);
            let (state, _) = sim.run(inst.nodes.clone(), inst.pods.clone());
            let Some(res) = optimize(
                &state,
                p_max,
                &OptimizerConfig::with_timeout(10.0).with_threads(4),
            ) else {
                return Ok(()); // a Failure is allowed, corruption is not
            };
            let plan = MovePlan::build(&state, &res.target);
            let mut live = state.clone();
            plan.execute(&mut live).map_err(|e| format!("plan: {e}"))?;
            live.check_invariants()?;
            if live.assignment() != &res.target[..] {
                return Err("plan did not realise the portfolio target".into());
            }
            Ok(())
        },
    );
}

/// A taint-partitioned cluster splits into one component per pool, and
/// the parallel path still agrees with the single-threaded plan.
#[test]
fn taint_pools_decompose_into_components() {
    // Nodes 0-1 are pool "a", nodes 2-3 pool "b"; every pod tolerates
    // exactly one pool, so the candidate node sets partition.
    let mut nodes = identical_nodes(4, Resources::new(1000, 1000));
    for (i, node) in nodes.iter_mut().enumerate() {
        let pool = if i < 2 { "a" } else { "b" };
        *node = node.clone().with_taint(Taint::no_schedule("pool", pool));
    }
    let mut pods = Vec::new();
    for i in 0..6u32 {
        let pool = if i < 3 { "a" } else { "b" };
        pods.push(
            Pod::new(i, format!("pod-{i}"), Resources::new(400, 400), Priority(0))
                .with_toleration(Toleration::equal("pool", pool)),
        );
    }
    let mut state = ClusterState::new(nodes, pods);
    // Fragment pool "a" so the optimiser has real work there.
    state.bind(PodId(0), NodeId(0)).unwrap();
    state.bind(PodId(1), NodeId(1)).unwrap();

    let single = optimize(&state, 0, &OptimizerConfig::with_timeout(10.0)).unwrap();
    let parallel = optimize(
        &state,
        0,
        &OptimizerConfig::with_timeout(10.0).with_threads(4),
    )
    .unwrap();
    assert_eq!(parallel.target, single.target);
    assert_eq!(parallel.placed_per_priority, single.placed_per_priority);
    assert!(parallel.proved_optimal);
    // phase 1 of tier 0 carries no locks: the two pools decompose
    assert!(
        parallel.portfolio.components >= 2,
        "expected the taint pools to split: {:?}",
        parallel.portfolio
    );
    assert!(parallel.portfolio.components_certified >= 2);
}

/// The portfolio certificate is sound: reported bounds dominate the
/// achieved objective, and a proven status closes the gap.
#[test]
fn certificates_are_sound_under_parallel_solving() {
    check(
        "portfolio_certificate_soundness",
        0xCE27,
        10,
        random_packing,
        |(m, obj)| {
            let out = solve_portfolio(
                m,
                obj,
                Deadline::unlimited(),
                &SolverConfig::default(),
                &PortfolioConfig::with_threads(4),
            );
            let sol = &out.solution;
            if sol.bound < sol.objective {
                return Err(format!("bound {} below objective {}", sol.bound, sol.objective));
            }
            if sol.status == SolveStatus::Optimal && sol.bound != sol.objective {
                return Err("proven optimal but bound not closed".into());
            }
            for report in &out.components {
                if report.bound < report.objective {
                    return Err(format!("component bound unsound: {report:?}"));
                }
            }
            Ok(())
        },
    );
}
