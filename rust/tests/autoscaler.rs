//! Autoscaler integration tests: the acceptance scenario (certified
//! scale-up, deterministic replay, provable consolidation) plus the
//! PR 3/PR 4 thread-determinism properties extended to autoscaler runs.
//!
//! Same caveat as every determinism test in this repo: identity is
//! guaranteed when every solve completes inside its window, so cases
//! are tiny and deadlines generous.

use std::time::Duration;

use kube_packd::autoscaler::{AutoscaleConfig, AutoscaleStats, NodePool};
use kube_packd::cluster::{identical_nodes, Priority, ReplicaSet, Resources};
use kube_packd::lifecycle::{run_churn, ChurnConfig, ChurnResult, Policy, SweepConfig};
use kube_packd::optimizer::OptimizerConfig;
use kube_packd::portfolio::PortfolioConfig;
use kube_packd::util::prop::check;
use kube_packd::workload::churn::{ChurnParams, ChurnTrace, TraceOp};
use kube_packd::workload::{ChurnTraceGenerator, GenParams};

/// The acceptance trace: a cluster the fallback *proves* full at t=100,
/// then frees capacity at t=2000 so consolidation can prove a joined
/// node drainable at the t=3000 sweep tick.
///
/// * t=0: three deploys fill both 1000-capacity nodes exactly
///   (600+400 on one, 1000 on the other, after the fallback re-pack).
/// * t=100: two 400-pods arrive — certifiably unplaceable; the min-cost
///   plan is 2×small (cost 10), beating 1×large (cost 16).
/// * t=2000: the 600-pod completes, freeing room on an original node.
/// * t=3000: consolidation drains one joined small (its pod re-packs
///   into the freed capacity, provably lossless) and removes it.
fn acceptance_trace() -> ChurnTrace {
    let base = GenParams {
        nodes: 2,
        pods_per_node: 2,
        priority_tiers: 1,
        usage: 1.0,
    };
    let params = ChurnParams {
        horizon_ms: 4_000,
        ..ChurnParams::for_cluster(base)
    };
    let deploy = |id: u32, replicas: u32, cpu: i64, lifetimes: Vec<u64>| TraceOp::Deploy {
        rs: ReplicaSet::new(id, format!("rs-{id:03}"), replicas, Resources::new(cpu, cpu), Priority(0)),
        lifetimes_ms: lifetimes,
    };
    ChurnTrace {
        params,
        seed: 0,
        nodes: identical_nodes(2, Resources::new(1000, 1000)),
        reference_capacity: Resources::new(1000, 1000),
        p_max: 0,
        ops: vec![
            (0, deploy(0, 1, 600, vec![2_000])),
            (0, deploy(1, 1, 400, vec![999_999])),
            (0, deploy(2, 1, 1000, vec![999_999])),
            (100, deploy(3, 2, 400, vec![999_999, 999_999])),
        ],
    }
}

fn autoscale_cfg() -> AutoscaleConfig {
    AutoscaleConfig {
        pools: NodePool::standard_mix(),
        provision_timeout: Duration::from_secs(5),
        max_removals: 2,
        ..AutoscaleConfig::default()
    }
}

fn churn_cfg_every(autoscale: bool, threads: usize, sweep_every_ms: u64) -> ChurnConfig {
    ChurnConfig {
        policy: Policy::FallbackSweep,
        sweep_every_ms,
        sweep: SweepConfig {
            optimizer: OptimizerConfig::with_timeout(5.0).with_threads(threads),
            eviction_budget: 8,
        },
        fallback_timeout: Duration::from_secs(5),
        fallback_portfolio: PortfolioConfig::with_threads(threads),
        incremental: false,
        autoscale: autoscale.then(autoscale_cfg),
    }
}

/// The acceptance cadence: exactly one sweep tick (t=3000) inside the
/// 4000ms horizon.
fn churn_cfg(autoscale: bool, threads: usize) -> ChurnConfig {
    churn_cfg_every(autoscale, threads, 3_000)
}

/// The ISSUE acceptance criterion, end to end: certified scale-up,
/// deterministic replay at 1 and 8 threads, and a provably-removable
/// node drained within the eviction budget.
#[test]
fn acceptance_certified_scale_up_then_provable_consolidation() {
    let trace = acceptance_trace();

    // Without the autoscaler the two arrivals stay stuck forever.
    let off = run_churn(&trace, &churn_cfg(false, 1));
    assert_eq!(off.final_pending, 2, "the arrivals are provably stuck");
    assert_eq!(off.final_ready_nodes, 2);
    assert_eq!(off.autoscale, AutoscaleStats::default());

    let mut digests = Vec::new();
    for threads in [1usize, 8] {
        let on = run_churn(&trace, &churn_cfg(true, threads));

        // Scale-up: one decision, certified min-cost (2×small = 10
        // beats 1×large = 16), both pods placed.
        assert_eq!(on.autoscale.scale_ups, 1, "threads={threads}");
        assert_eq!(on.autoscale.certified_scale_ups, 1, "plan carries both proofs");
        assert_eq!(on.autoscale.nodes_added, 2);
        assert_eq!(on.autoscale.cost_added, 10, "min-cost: 2x small");
        assert_eq!(on.final_pending, 0, "scale-up placed the stuck pods");
        assert!(on
            .log
            .lines()
            .iter()
            .any(|l| l.contains("scale-up +2 (small x2) cost=10 [certified] pods=2")));

        // Consolidation: after the 600-pod completes, exactly one
        // joined node is provably drainable (its pod re-packs into the
        // freed capacity); the other joined node must stay.
        assert_eq!(on.autoscale.scale_downs, 1, "threads={threads}");
        assert_eq!(on.autoscale.nodes_removed, 1);
        assert_eq!(on.autoscale.drained_pods, 1, "a resident was drained, not an empty node");
        assert!(on.log.lines().iter().any(|l| l.contains("scale-down removed=1")));
        assert_eq!(on.final_ready_nodes, 3, "2 original + 2 joined - 1 consolidated");

        // Elastic fleet serves what the static one provably cannot.
        assert!(on.served_total() > off.served_total());
        // Whole-trace eviction accounting still partitions.
        assert_eq!(
            on.evictions,
            on.evictions_preemption + on.evictions_sweep + on.evictions_drain
        );
        digests.push((on.log.digest(), on.autoscale.clone()));
    }
    // Identical decisions and byte-identical logs at 1 and 8 threads.
    assert_eq!(digests[0].0, digests[1].0, "thread-count must not leak into the log");
    assert_eq!(digests[0].1, digests[1].1, "scale decisions must be thread-independent");

    // And replay: the same config reproduces the same digest.
    let again = run_churn(&trace, &churn_cfg(true, 1));
    assert_eq!(again.log.digest(), digests[0].0);
}

/// Autoscale **off** is byte-identical across repeated runs and across
/// thread counts on generated traces — the historical churn contract,
/// re-pinned now that the autoscaler exists.
#[test]
fn prop_autoscale_off_replays_byte_identical_across_threads() {
    check(
        "autoscale_off_thread_parity",
        0xA5C4,
        4,
        |rng| {
            let params = ChurnParams {
                horizon_ms: 2_500,
                mean_arrival_ms: 700,
                mean_lifetime_ms: 1_500,
                ..ChurnParams::for_cluster(GenParams {
                    nodes: rng.range_usize(2, 3),
                    pods_per_node: 2,
                    priority_tiers: rng.range_usize(1, 2) as u32,
                    usage: 1.0 + rng.f64() * 0.1,
                })
            };
            ChurnTraceGenerator::new(params, rng.next_u64()).generate()
        },
        |trace| {
            // Sweep ticks at 1000/2000 land inside the 2500ms horizon,
            // so the off-runs exercise the sweep path too.
            let base = run_churn(trace, &churn_cfg_every(false, 1, 1_000));
            for threads in [1usize, 8] {
                let r = run_churn(trace, &churn_cfg_every(false, threads, 1_000));
                if r.log.digest() != base.log.digest() {
                    return Err(format!("off-run digest diverged at threads={threads}"));
                }
                if r.autoscale != AutoscaleStats::default() {
                    return Err("autoscale off recorded activity".to_string());
                }
                if r.served_per_priority != base.served_per_priority {
                    return Err(format!("served vector diverged at threads={threads}"));
                }
            }
            Ok(())
        },
    );
}

/// Autoscale **on**: scale decisions (and the whole log) are identical
/// at 1 and 8 threads on generated overloaded traces.
#[test]
fn prop_autoscale_decisions_are_thread_independent() {
    check(
        "autoscale_on_thread_parity",
        0xE1A5,
        4,
        |rng| {
            let params = ChurnParams {
                horizon_ms: 2_500,
                mean_arrival_ms: 800,
                mean_lifetime_ms: 1_200,
                // No node churn from the trace itself: the autoscaler is
                // the only fleet mutator, which keeps the property sharp.
                drain_chance: 0.0,
                join_chance: 0.0,
                ..ChurnParams::for_cluster(GenParams {
                    nodes: 2,
                    pods_per_node: 2,
                    priority_tiers: rng.range_usize(1, 2) as u32,
                    // Overloaded: certified unplaceability is likely.
                    usage: 1.1 + rng.f64() * 0.2,
                })
            };
            ChurnTraceGenerator::new(params, rng.next_u64()).generate()
        },
        |trace| {
            // Sweep ticks at 1000/2000 fire inside the horizon, so the
            // property covers consolidation decisions, not just
            // scale-ups.
            let runs: Vec<ChurnResult> = [1usize, 8]
                .iter()
                .map(|&t| run_churn(trace, &churn_cfg_every(true, t, 1_000)))
                .collect();
            if runs[0].log.digest() != runs[1].log.digest() {
                return Err("autoscale-on digest diverged between 1 and 8 threads".to_string());
            }
            if runs[0].autoscale != runs[1].autoscale {
                return Err(format!(
                    "scale decisions diverged: {:?} vs {:?}",
                    runs[0].autoscale, runs[1].autoscale
                ));
            }
            if runs[0].final_ready_nodes != runs[1].final_ready_nodes {
                return Err("final fleet size diverged".to_string());
            }
            Ok(())
        },
    );
}

/// A pooled (heterogeneous) trace with autoscaling replays
/// deterministically too — pools add no hidden randomness.
#[test]
fn pooled_autoscale_trace_replays_deterministically() {
    let params = ChurnParams {
        horizon_ms: 2_500,
        mean_arrival_ms: 700,
        mean_lifetime_ms: 1_500,
        ..ChurnParams::for_cluster(GenParams {
            nodes: 3,
            pods_per_node: 2,
            priority_tiers: 1,
            usage: 1.1,
        })
    };
    let trace = ChurnTraceGenerator::new(params, 77)
        .with_pools(NodePool::parse_mix("small,large").unwrap())
        .generate();
    assert_ne!(
        trace.nodes[0].capacity, trace.nodes[1].capacity,
        "the initial fleet really is heterogeneous"
    );
    let a = run_churn(&trace, &churn_cfg_every(true, 1, 1_000));
    let b = run_churn(&trace, &churn_cfg_every(true, 1, 1_000));
    assert_eq!(a.log.digest(), b.log.digest());
    assert_eq!(a.autoscale, b.autoscale);
    assert_eq!(a.final_ready_nodes, b.final_ready_nodes);
}
