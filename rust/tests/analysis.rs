//! Fixture corpus for the detlint static pass (`kube_packd::analysis`).
//!
//! Per rule: one snippet that must fire and one clean twin that must
//! not; plus the directive lifecycle (honored with a reason, rejected
//! without), the zone-manifest totality pin (every file under
//! `rust/src` maps to exactly one zone — new files can't silently
//! escape analysis), the wire-parity drift fixtures, and the
//! acceptance gate itself: the committed tree lints clean.

use std::path::{Path, PathBuf};

use kube_packd::analysis::{lint_tree, rules, scan_source, zones};

/// Rule slugs fired by a snippet placed at `rel`.
fn fired(rel: &str, src: &str) -> Vec<&'static str> {
    scan_source(rel, src).findings.iter().map(|f| f.rule).collect()
}

// -- wall-clock -------------------------------------------------------------

#[test]
fn wall_clock_fires_in_core() {
    let f = fired("solver/x.rs", "fn f() { let t = Instant::now(); }");
    assert_eq!(f, vec!["wall-clock"]);
    let f = fired("cluster/x.rs", "fn f() -> SystemTime { SystemTime::now() }");
    assert!(f.contains(&"wall-clock"), "{f:?}");
}

#[test]
fn wall_clock_clean_twins() {
    // Periphery may read clocks…
    assert!(fired("telemetry/x.rs", "fn f() { let t = Instant::now(); }").is_empty());
    // …and deadline-based core code never touches Instant::now.
    let clean = "fn f(d: Deadline) -> bool { d.expired() }";
    assert!(fired("solver/x.rs", clean).is_empty());
    // Mentions in comments and strings don't count.
    let hidden = "// Instant::now()\nfn f() { let s = \"Instant::now()\"; }";
    assert!(fired("solver/x.rs", hidden).is_empty());
}

// -- hash-iter --------------------------------------------------------------

#[test]
fn hash_iter_fires_in_core() {
    let src = "use std::collections::HashMap;\nfn f() { let m: HashMap<u32, u32> = \
               HashMap::new(); }";
    let f = fired("optimizer/x.rs", src);
    assert!(f.iter().all(|r| *r == "hash-iter"), "{f:?}");
    assert!(!f.is_empty());
}

#[test]
fn hash_iter_clean_twin() {
    let src = "use std::collections::BTreeMap;\nfn f() { let m: BTreeMap<u32, u32> = \
               BTreeMap::new(); }";
    assert!(fired("optimizer/x.rs", src).is_empty());
    // Exempt zones may hash.
    let hashed = "use std::collections::HashMap;\nfn f() {}";
    assert!(fired("metrics/x.rs", hashed).is_empty());
}

// -- float-order ------------------------------------------------------------

#[test]
fn float_order_fires_in_every_zone() {
    let src = "fn f(v: &mut Vec<f64>) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }";
    // Exempt zone: the rule is universal (NaN panics are bad everywhere).
    let f = fired("scheduler/x.rs", src);
    assert_eq!(f, vec!["float-order"]);
}

#[test]
fn float_order_catches_soft_fallbacks_too() {
    // `unwrap_or(Equal)` avoids the panic but silently breaks sort
    // totality under NaN: still a finding.
    let src = "fn f(v: &mut Vec<f64>) { v.sort_by(|a, b| \
               a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)); }";
    assert_eq!(fired("scheduler/x.rs", src), vec!["float-order"]);
}

#[test]
fn float_order_clean_twins() {
    let total = "fn f(v: &mut Vec<f64>) { v.sort_by(|a, b| a.total_cmp(b)); }";
    assert!(fired("scheduler/x.rs", total).is_empty());
    // A PartialOrd impl is a definition, not a call site.
    let ord_impl = "impl PartialOrd for E { fn partial_cmp(&self, o: &Self) -> \
                    Option<Ordering> { Some(self.cmp(o)) } }";
    assert!(fired("lifecycle/x.rs", ord_impl).is_empty());
    // Integer comparators are untouched.
    let ints = "fn f(v: &mut Vec<i64>) { v.sort_by(|a, b| b.cmp(a)); }";
    assert!(fired("solver/x.rs", ints).is_empty());
}

// -- panic-on-wire ----------------------------------------------------------

#[test]
fn panic_on_wire_fires_on_server_paths() {
    let f = fired("server/engine.rs", "fn f(x: Option<u32>) { x.unwrap(); }");
    assert_eq!(f, vec!["panic-on-wire"]);
    let f = fired("server/protocol.rs", "fn f() { panic!(\"boom\") }");
    assert_eq!(f, vec!["panic-on-wire"]);
}

#[test]
fn panic_on_wire_clean_twins() {
    // Lock poisoning propagation is structurally allowed…
    let poison = "fn f(&self) { let q = self.q.lock().expect(\"poisoned\"); }";
    assert!(fired("server/batcher.rs", poison).is_empty());
    // …the load generator is out of scope…
    let loadgen = "fn f(x: Option<u32>) { x.unwrap(); }";
    assert!(fired("server/loadgen.rs", loadgen).is_empty());
    // …and so is non-server code (other rules permitting).
    assert!(fired("workload/x.rs", loadgen).is_empty());
}

#[test]
fn panic_on_wire_skips_test_modules() {
    let src = "fn live() -> bool { true }\n#[cfg(test)]\nmod tests {\n    #[test]\n    \
               fn t() { panic!(\"fixtures may panic\") }\n}\n";
    assert!(fired("server/engine.rs", src).is_empty());
}

// -- telemetry-feedback -----------------------------------------------------

#[test]
fn telemetry_feedback_fires_in_core() {
    let src = "fn f(&self) { let m = self.tel.export_prometheus(); }";
    assert_eq!(fired("solver/x.rs", src), vec!["telemetry-feedback"]);
    let src = "fn f(&self) { if self.tel.span_count() > 0 { tighten(); } }";
    assert_eq!(fired("portfolio/x.rs", src), vec!["telemetry-feedback"]);
}

#[test]
fn telemetry_feedback_clean_twins() {
    // Write-path APIs stay legal in the core…
    let writes = "fn f(&self) { let sp = self.tel.span(\"solve\"); sp.arg(\"n\", 1); }";
    assert!(fired("solver/x.rs", writes).is_empty());
    // …and reads are fine outside it (the exporter CLI, telemetry itself).
    let reads = "fn f(&self) { let m = self.tel.export_prometheus(); }";
    assert!(fired("telemetry/x.rs", reads).is_empty());
    assert!(fired("server/mod.rs", reads).is_empty());
}

#[test]
fn telemetry_feedback_covers_the_probe_read_surface() {
    // Reading solve forensics back inside the core would let the
    // profiler steer placement — every Probe read/export API fires.
    for read in [
        "self.prof.export_profile_json()",
        "self.prof.export_folded()",
        "self.prof.module_effort()",
        "self.prof.gap_samples()",
    ] {
        let src = format!("fn f(&self) {{ let x = {read}; }}");
        assert_eq!(
            fired("solver/x.rs", &src),
            vec!["telemetry-feedback"],
            "{read}"
        );
    }
}

#[test]
fn telemetry_feedback_probe_clean_twins() {
    // The probe's write path (frames, attribution, gap samples, child
    // absorption) is the recording contract — legal everywhere.
    let writes = "fn f(&self, prof: &Probe) { let _pf = prof.frame(\"exact\"); \
                  prof.attr(\"capacity:cpu\", \"propagations\", 3); \
                  prof.gap(10, 4, 7); prof.absorb(prof.child()); }";
    assert!(fired("solver/x.rs", writes).is_empty());
    // Reads are fine outside the core: the CLI report printer lives in
    // the exempt zone.
    let reads = "fn f(prof: &Probe) { let doc = prof.export_profile_json(); }";
    assert!(fired("main.rs", reads).is_empty());
}

// -- directives -------------------------------------------------------------

#[test]
fn directive_with_reason_is_honored() {
    let src = "fn f() { let t = Instant::now(); // detlint: allow(wall-clock) — anchor\n}";
    let r = scan_source("solver/x.rs", src);
    assert!(r.findings.is_empty(), "{:?}", r.findings);
    assert_eq!(r.waived, 1);
}

#[test]
fn standalone_directive_covers_next_line() {
    let src = "// detlint: allow(wall-clock) — calibration anchor\n\
               fn f() { let t = Instant::now(); }\n";
    let r = scan_source("solver/x.rs", src);
    assert!(r.findings.is_empty(), "{:?}", r.findings);
    assert_eq!(r.waived, 1);
}

#[test]
fn directive_without_reason_is_rejected() {
    let src = "fn f() { let t = Instant::now(); // detlint: allow(wall-clock)\n}";
    let r = scan_source("solver/x.rs", src);
    let rules: Vec<&str> = r.findings.iter().map(|f| f.rule).collect();
    // The waiver is void (the wall-clock finding stays) and the
    // directive itself is a finding.
    assert!(rules.contains(&"wall-clock"), "{rules:?}");
    assert!(rules.contains(&"bad-directive"), "{rules:?}");
    assert_eq!(r.waived, 0);
}

#[test]
fn directive_with_unknown_rule_is_rejected() {
    let src = "// detlint: allow(wall-clok) — typo\nfn f() { let t = Instant::now(); }";
    let r = scan_source("solver/x.rs", src);
    let rules: Vec<&str> = r.findings.iter().map(|f| f.rule).collect();
    assert!(rules.contains(&"bad-directive"), "{rules:?}");
    assert!(rules.contains(&"wall-clock"), "{rules:?}");
}

// -- zone manifest ----------------------------------------------------------

#[test]
fn every_src_file_maps_to_exactly_one_zone() {
    let mut files = Vec::new();
    walk(Path::new("rust/src"), &mut files);
    assert!(files.len() > 50, "walk found only {} files", files.len());
    for path in files {
        let rel = zones::rel_from(&path.to_string_lossy());
        assert!(
            zones::zone_of(&rel).is_some(),
            "{rel} matches no zone-manifest entry — place it in analysis/zones.rs"
        );
    }
}

#[test]
fn unzoned_files_are_findings() {
    let r = scan_source("freshly_added/module.rs", "fn f() {}");
    assert_eq!(r.findings.len(), 1);
    assert_eq!(r.findings[0].rule, "no-zone");
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
    for entry in std::fs::read_dir(dir).expect("readable source tree") {
        let path = entry.expect("dir entry").path();
        if path.is_dir() {
            walk(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

// -- wire-parity ------------------------------------------------------------

const PROTO_FIXTURE: &str = r#"
    impl WireOp {
        pub fn name(&self) -> &'static str {
            match self {
                WireOp::Submit(_) => "submit",
                WireOp::Query { .. } => "query",
            }
        }
    }
    impl WireError {
        pub fn code(&self) -> &'static str {
            match self {
                WireError::BadJson(_) => "bad-json",
            }
        }
    }
"#;

#[test]
fn wire_parity_accepts_matching_registries() {
    let client = "WIRE_OPS = frozenset({\"submit\", \"query\"})\n\
                  ERROR_CODES = frozenset({\"bad-json\"})\n";
    assert!(rules::wire_parity("p.rs", PROTO_FIXTURE, "c.py", client).is_empty());
}

#[test]
fn wire_parity_flags_drift_in_both_directions() {
    // `query` dropped from the client, `phantom` invented there.
    let client = "WIRE_OPS = frozenset({\"submit\", \"phantom\"})\n\
                  ERROR_CODES = frozenset({\"bad-json\"})\n";
    let f = rules::wire_parity("p.rs", PROTO_FIXTURE, "c.py", client);
    assert_eq!(f.len(), 2, "{f:?}");
    assert!(f.iter().any(|x| x.msg.contains("`query`") && x.path == "p.rs"));
    assert!(f.iter().any(|x| x.msg.contains("`phantom`") && x.path == "c.py"));
}

#[test]
fn wire_parity_flags_a_missing_registry() {
    let f = rules::wire_parity("p.rs", PROTO_FIXTURE, "c.py", "# no registries here\n");
    assert_eq!(f.len(), 2, "{f:?}"); // WIRE_OPS and ERROR_CODES both absent
    assert!(f.iter().all(|x| x.rule == "wire-parity"));
}

// -- the acceptance gate ----------------------------------------------------

#[test]
fn committed_tree_lints_clean() {
    // The same invariant CI enforces with `kube-packd lint rust/src`:
    // every remaining violation in the tree carries a reasoned waiver,
    // and the Python client's registries match the Rust wire protocol.
    let report = lint_tree(Path::new("rust/src")).expect("lint runs");
    assert!(
        report.clean(),
        "unwaived findings on the committed tree:\n{}",
        report.render_human()
    );
    assert!(report.waived > 0, "the known waiver sites disappeared?");
    assert!(report.files > 50, "scanned only {} files", report.files);
}
