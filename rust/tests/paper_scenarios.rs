//! Scenario tests pinning the paper's qualitative claims on small,
//! wall-clock-friendly configurations.

use kube_packd::harness::grid::{run_grid, GridConfig};
use kube_packd::harness::run_instance;
use kube_packd::metrics::categories::Outcome;
use kube_packd::solver::SolverConfig;
use kube_packd::util::stats;
use kube_packd::workload::{GenParams, Instance};

fn challenging(nodes: usize, ppn: usize, tiers: u32, usage: f64, count: usize, seed: u64) -> Vec<Instance> {
    Instance::generate_challenging(
        GenParams {
            nodes,
            pods_per_node: ppn,
            priority_tiers: tiers,
            usage,
        },
        count,
        seed,
        count * 60,
    )
}

/// Claim (abstract): "our approach places more higher-priority pods than
/// the default scheduler ... in over 44% of realisable allocation
/// scenarios where the default scheduler fails" (1s window, small
/// clusters). We check the improving share (Better + Better&Optimal +
/// KwokOptimal — i.e., non-failures) clears a conservative floor on
/// 4-node instances.
#[test]
fn improving_share_on_small_clusters() {
    let insts = challenging(4, 4, 2, 1.0, 8, 0xAB);
    assert!(insts.len() >= 4);
    let mut improved = 0;
    let mut proved_kwok_optimal = 0;
    for inst in &insts {
        let run = run_instance(inst, 1.0, &SolverConfig::default());
        match run.outcome {
            Outcome::Better | Outcome::BetterOptimal => improved += 1,
            Outcome::KwokOptimal => proved_kwok_optimal += 1,
            _ => {}
        }
    }
    let share = (improved + proved_kwok_optimal) as f64 / insts.len() as f64;
    assert!(
        share >= 0.5,
        "only {improved}+{proved_kwok_optimal} of {} instances resolved",
        insts.len()
    );
    assert!(improved >= 1, "no instance improved at all");
}

/// Claim: "increasing the timeout generally allows the optimiser to find
/// more optimal solutions" — non-failure share must be monotone (within
/// noise) from a starved to a comfortable budget.
#[test]
fn longer_timeouts_do_not_hurt() {
    let insts = challenging(8, 4, 2, 1.0, 5, 0xCD);
    let score = |timeout: f64| -> usize {
        insts
            .iter()
            .map(|i| {
                match run_instance(i, timeout, &SolverConfig::default()).outcome {
                    Outcome::Better | Outcome::BetterOptimal | Outcome::KwokOptimal => 1,
                    _ => 0,
                }
            })
            .sum()
    };
    let starved = score(0.05);
    let comfy = score(1.0);
    assert!(
        comfy >= starved,
        "more time made things worse: {starved} -> {comfy}"
    );
}

/// Claim (Table 1): improvements in CPU/memory utilisation remain
/// positive on average across improving instances (the paper reports
/// ≈2–4 pp).
#[test]
fn utilization_deltas_positive_on_average() {
    let insts = challenging(4, 4, 4, 1.0, 8, 0xEF);
    let mut dc = Vec::new();
    let mut dm = Vec::new();
    for inst in &insts {
        let run = run_instance(inst, 1.0, &SolverConfig::default());
        if matches!(run.outcome, Outcome::Better | Outcome::BetterOptimal) {
            dc.push(run.delta_cpu);
            dm.push(run.delta_mem);
        }
    }
    assert!(!dc.is_empty(), "no improving instance found");
    assert!(
        stats::mean(&dc) > 0.0 && stats::mean(&dm) > 0.0,
        "mean deltas not positive: cpu {:?} mem {:?}",
        stats::mean(&dc),
        stats::mean(&dm)
    );
}

/// Claim (Fig. 4): at low usage the default scheduler more often
/// succeeds outright, so fewer challenging instances exist per seed
/// budget — the generator mirrors that.
#[test]
fn low_usage_yields_fewer_challenging_instances() {
    let attempts = 120;
    let low = Instance::generate_challenging(
        GenParams {
            nodes: 4,
            pods_per_node: 4,
            priority_tiers: 1,
            usage: 0.90,
        },
        attempts,
        7,
        attempts,
    );
    let high = Instance::generate_challenging(
        GenParams {
            nodes: 4,
            pods_per_node: 4,
            priority_tiers: 1,
            usage: 1.05,
        },
        attempts,
        7,
        attempts,
    );
    assert!(
        low.len() < high.len(),
        "90% usage produced {} failures vs {} at 105%",
        low.len(),
        high.len()
    );
}

/// Claim: "with fewer pods per node there are fewer possible placements,
/// which makes the problem simpler" — ppn=4 must not fail more often
/// than ppn=8 under the same tight budget.
#[test]
fn density_increases_difficulty() {
    let cfg = GridConfig {
        nodes: vec![8],
        pods_per_node: vec![4, 8],
        priority_tiers: vec![2],
        usage: vec![1.0],
        timeouts: vec![0.2],
        instances: 5,
        max_gen_attempts: 300,
        verbose: false,
        ..Default::default()
    };
    let cells = run_grid(&cfg);
    let fail = |ppn: usize| -> f64 {
        cells
            .iter()
            .find(|c| c.key.params.pods_per_node == ppn)
            .map(|c| c.pct(Outcome::Failure))
            .unwrap_or(0.0)
    };
    assert!(
        fail(4) <= fail(8) + 20.0, // generous noise margin on 5 instances
        "ppn=4 failed more than ppn=8: {} vs {}",
        fail(4),
        fail(8)
    );
}
