//! Telemetry-subsystem integration tests.
//!
//! The contract under test: telemetry *observes* the pipeline and never
//! feeds back — solve results are byte-identical with recording on or
//! off, at any thread count — and the two exports are well-formed and
//! byte-stable for a fixed recorded run.
//!
//! Determinism caveat (same as the portfolio tests): byte-identity
//! across worker counts holds whenever every racer completes inside its
//! window, so the models here are tiny and the deadlines generous.

use std::collections::BTreeMap;
use std::collections::BTreeSet;

use kube_packd::cluster::{identical_nodes, ClusterState, Pod, Priority, Resources};
use kube_packd::lifecycle::{run_churn, run_churn_traced, ChurnConfig, Policy};
use kube_packd::optimizer::{optimize_traced, OptimizerConfig, SolveSession};
use kube_packd::telemetry::{Telemetry, Verbosity};
use kube_packd::util::json;
use kube_packd::util::prop::check;
use kube_packd::util::rng::Rng;
use kube_packd::workload::churn::{ChurnParams, ChurnTraceGenerator};
use kube_packd::workload::GenParams;

/// Random small cluster: every pod pending, mixed priorities, tight
/// enough that phase 1 has real packing work.
fn random_cluster(rng: &mut Rng) -> (ClusterState, u32) {
    let nodes = rng.range_usize(2, 4);
    let pods = rng.range_usize(4, 9);
    let tiers = rng.range_usize(1, 3) as u32;
    let node_list = identical_nodes(nodes, Resources::new(1000, 1000));
    let pod_list: Vec<Pod> = (0..pods)
        .map(|i| {
            Pod::new(
                i as u32,
                format!("p-{i}"),
                Resources::new(rng.range_i64(150, 650), rng.range_i64(150, 650)),
                Priority(rng.range_usize(0, tiers as usize - 1) as u32),
            )
        })
        .collect();
    (ClusterState::new(node_list, pod_list), tiers - 1)
}

/// The determinism tentpole: (telemetry off, recording) × threads
/// {1, 8} all produce the identical plan, placement vector, and
/// certificate. Recording must be a pure observer.
#[test]
fn prop_results_identical_with_telemetry_on_or_off_at_threads_1_and_8() {
    check(
        "telemetry_observer_identity",
        0x7E1E,
        8,
        random_cluster,
        |(state, p_max)| {
            let mut runs = Vec::new();
            for threads in [1usize, 8] {
                for recording in [false, true] {
                    let tel = if recording {
                        Telemetry::recording()
                    } else {
                        Telemetry::off()
                    };
                    let cfg = OptimizerConfig::with_timeout(30.0).with_threads(threads);
                    let res = optimize_traced(state, *p_max, &cfg, None, &tel);
                    runs.push((threads, recording, res));
                }
            }
            let (_, _, first) = &runs[0];
            for (threads, recording, res) in &runs[1..] {
                let same = match (first, res) {
                    (None, None) => true,
                    (Some(a), Some(b)) => {
                        a.target == b.target
                            && a.placed_per_priority == b.placed_per_priority
                            && a.proved_optimal == b.proved_optimal
                    }
                    _ => false,
                };
                if !same {
                    return Err(format!(
                        "threads={threads} recording={recording} diverged from threads=1 off"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Figure-1 fragmentation: two big nodes, two small pods spread, one
/// stranded — the canonical state where the optimiser has work to do.
fn fragmented_figure1() -> ClusterState {
    use kube_packd::cluster::{NodeId, PodId};
    let nodes = identical_nodes(2, Resources::new(4000, 4096));
    let pods = vec![
        Pod::new(0, "pod-1", Resources::new(10, 2048), Priority(0)),
        Pod::new(1, "pod-2", Resources::new(10, 2048), Priority(0)),
        Pod::new(2, "pod-3", Resources::new(10, 3072), Priority(0)),
    ];
    let mut st = ClusterState::new(nodes, pods);
    st.bind(PodId(0), NodeId(0)).unwrap();
    st.bind(PodId(1), NodeId(1)).unwrap();
    st
}

/// A recorded session solve covers the whole advertised span vocabulary
/// and the Chrome export is well-formed: per lane, every `B` has a
/// matching same-name `E` and timestamps never go backwards.
#[test]
fn chrome_trace_is_well_formed_and_covers_the_pipeline() {
    let tel = Telemetry::recording();
    let state = fragmented_figure1();
    let cfg = OptimizerConfig::with_timeout(10.0).with_threads(2);
    let mut session = SolveSession::new();
    let res = session.solve_traced(&state, 0, &cfg, &tel);
    assert!(res.is_some(), "figure 1 must solve");

    let trace = tel.export_chrome();
    let doc = json::parse(&trace).expect("chrome trace must be valid JSON");
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array");

    // The exporter writes the B/E duration stream first and then the
    // instant events, so each stream gets its own per-lane clock.
    let mut stacks: BTreeMap<i64, Vec<String>> = BTreeMap::new();
    let mut span_ts: BTreeMap<i64, f64> = BTreeMap::new();
    let mut inst_ts: BTreeMap<i64, f64> = BTreeMap::new();
    let mut names: BTreeSet<String> = BTreeSet::new();
    let mut begins = 0usize;
    let mut ends = 0usize;
    for ev in events {
        let ph = ev.get("ph").and_then(|p| p.as_str()).expect("ph");
        if ph == "M" {
            continue; // lane-name metadata carries no timestamp
        }
        let tid = ev.get("tid").and_then(|t| t.as_i64()).expect("tid");
        let ts = ev.get("ts").and_then(|t| t.as_f64()).expect("ts");
        let name = ev.get("name").and_then(|n| n.as_str()).expect("name");
        let clock = if ph == "i" { &mut inst_ts } else { &mut span_ts };
        let prev = clock.entry(tid).or_insert(0.0);
        assert!(
            ts >= *prev,
            "timestamps must be monotone per lane: {name} at {ts} after {prev}"
        );
        *prev = ts;
        match ph {
            "B" => {
                begins += 1;
                names.insert(name.to_string());
                stacks.entry(tid).or_default().push(name.to_string());
            }
            "E" => {
                ends += 1;
                let open = stacks
                    .entry(tid)
                    .or_default()
                    .pop()
                    .unwrap_or_else(|| panic!("E '{name}' with no open span on lane {tid}"));
                assert_eq!(open, name, "E must close the innermost open span");
            }
            "i" => {}
            other => panic!("unexpected event phase {other:?}"),
        }
    }
    assert_eq!(begins, ends, "every B needs a matching E");
    for (tid, stack) in &stacks {
        assert!(stack.is_empty(), "lane {tid} left spans open: {stack:?}");
    }
    for expected in [
        "session",
        "phase1",
        "phase2",
        "cache",
        "decompose",
        "strategy-race",
        "race-task",
    ] {
        assert!(
            names.contains(expected),
            "span vocabulary missing {expected:?}; got {names:?}"
        );
    }
}

/// Both exports are byte-stable for a fixed recorded run: exporting the
/// same handle twice yields identical bytes (the snapshot property).
#[test]
fn exports_are_byte_stable_for_a_fixed_run() {
    let tel = Telemetry::recording();
    let state = fragmented_figure1();
    let cfg = OptimizerConfig::with_timeout(10.0).with_threads(2);
    optimize_traced(&state, 0, &cfg, None, &tel).expect("figure 1 must solve");
    assert_eq!(tel.export_chrome(), tel.export_chrome());
    assert_eq!(tel.export_prometheus(), tel.export_prometheus());
}

/// The Prometheus dump follows the text exposition format and carries
/// the layered counter families: solver, portfolio, optimizer, session.
#[test]
fn prometheus_export_is_schema_valid_and_layered() {
    let tel = Telemetry::recording();
    let state = fragmented_figure1();
    let cfg = OptimizerConfig::with_timeout(10.0).with_threads(2);
    let mut session = SolveSession::new();
    session
        .solve_traced(&state, 0, &cfg, &tel)
        .expect("figure 1 must solve");

    let text = tel.export_prometheus();
    assert!(!text.is_empty());
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            assert!(
                rest.starts_with("kube_packd_"),
                "TYPE line without namespace: {line}"
            );
            let kind = rest.rsplit(' ').next().unwrap();
            assert!(
                kind == "counter" || kind == "gauge" || kind == "histogram",
                "bad kind: {line}"
            );
        } else {
            assert!(
                line.starts_with("kube_packd_"),
                "sample line without namespace: {line}"
            );
            // Counter/gauge/bucket samples are integers; histogram
            // `_sum` series are seconds, so floats are legal too.
            let value = line.rsplit(' ').next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "non-numeric sample: {line}");
        }
    }
    for family in [
        "kube_packd_solver_decisions_total",
        "kube_packd_portfolio_solves_total",
        "kube_packd_optimizer_runs_total",
        "kube_packd_session_solves_total",
    ] {
        assert!(text.contains(family), "missing {family} in:\n{text}");
    }
}

/// A recorded portfolio solve emits valid Prometheus histogram series:
/// per label set, `_bucket` counts are cumulative and monotone, the
/// series ends at `le="+Inf"` whose count equals `_count`, and a `_sum`
/// series exists alongside.
#[test]
fn prometheus_histograms_are_well_formed_for_a_recorded_solve() {
    let tel = Telemetry::recording();
    let state = fragmented_figure1();
    let cfg = OptimizerConfig::with_timeout(10.0).with_threads(2);
    optimize_traced(&state, 0, &cfg, None, &tel).expect("figure 1 must solve");

    let text = tel.export_prometheus();
    assert!(
        text.contains("# TYPE kube_packd_race_task_seconds histogram"),
        "race-task latency histogram missing:\n{text}"
    );
    // Group bucket samples by everything before the `le` label — that
    // prefix is one series; file order is the exporter's bound order.
    let mut series: BTreeMap<String, Vec<u64>> = BTreeMap::new();
    let mut last_le: BTreeMap<String, String> = BTreeMap::new();
    for line in text.lines() {
        let Some((name_labels, value)) = line.rsplit_once(' ') else {
            continue;
        };
        let Some((prefix, le_part)) = name_labels.split_once("le=\"") else {
            continue;
        };
        let le = le_part.trim_end_matches('}').trim_end_matches('"');
        series
            .entry(prefix.to_string())
            .or_default()
            .push(value.parse().expect("bucket counts are integers"));
        last_le.insert(prefix.to_string(), le.to_string());
    }
    assert!(!series.is_empty(), "no histogram buckets in:\n{text}");
    for (key, vals) in &series {
        assert!(
            vals.windows(2).all(|w| w[0] <= w[1]),
            "buckets must be cumulative and monotone for {key}: {vals:?}"
        );
        assert_eq!(
            last_le.get(key).map(String::as_str),
            Some("+Inf"),
            "{key} must end at le=\"+Inf\""
        );
        // `key` is `<metric>_bucket{` or `<metric>_bucket{<labels>,` —
        // recover the sibling `_count` and `_sum` sample lines.
        let base = key.trim_end_matches(['{', ',']);
        let (count_needle, sum_needle) = if base.contains('{') {
            (
                base.replace("_bucket{", "_count{") + "} ",
                base.replace("_bucket{", "_sum{") + "} ",
            )
        } else {
            (
                base.replace("_bucket", "_count") + " ",
                base.replace("_bucket", "_sum") + " ",
            )
        };
        let count: u64 = text
            .lines()
            .find_map(|l| l.strip_prefix(&count_needle))
            .unwrap_or_else(|| panic!("no _count series for {base}"))
            .parse()
            .expect("count is an integer");
        assert_eq!(
            *vals.last().unwrap(),
            count,
            "+Inf bucket must equal _count for {base}"
        );
        let sum: f64 = text
            .lines()
            .find_map(|l| l.strip_prefix(&sum_needle))
            .unwrap_or_else(|| panic!("no _sum series for {base}"))
            .parse()
            .expect("sum is numeric");
        assert!(sum >= 0.0 && sum.is_finite());
    }
}

/// Churn replay digests are identical with telemetry recording or off —
/// the lifecycle layer inherits the observer property — and a recorded
/// run surfaces churn-level counters.
#[test]
fn churn_digests_identical_with_recording_on() {
    let trace = ChurnTraceGenerator::new(
        ChurnParams {
            horizon_ms: 3_000,
            mean_arrival_ms: 500,
            mean_lifetime_ms: 1_200,
            ..ChurnParams::for_cluster(GenParams {
                nodes: 3,
                pods_per_node: 3,
                priority_tiers: 2,
                usage: 0.9,
            })
        },
        17,
    )
    .generate();
    let mut cfg = ChurnConfig::for_policy(Policy::FallbackSweep);
    cfg.sweep_every_ms = 1_000; // several sweep ticks inside the horizon
    cfg.fallback_timeout = std::time::Duration::from_secs(5);

    let off = run_churn(&trace, &cfg);
    let tel = Telemetry::recording();
    let on = run_churn_traced(&trace, &cfg, &tel);

    assert_eq!(off.log.digest(), on.log.digest());
    assert_eq!(off.log.render(), on.log.render());
    assert_eq!(off.served_per_priority, on.served_per_priority);
    assert_eq!(off.final_placed, on.final_placed);

    let counters = tel.counters();
    assert!(counters.get("churn_events_total", "").unwrap_or(0) > 0);
    assert!(counters.get("sweep_runs_total", "").unwrap_or(0) > 0);
}

#[test]
fn verbosity_parses_all_levels_and_rejects_garbage() {
    assert_eq!(Verbosity::parse("off"), Some(Verbosity::Off));
    assert_eq!(Verbosity::parse("info"), Some(Verbosity::Info));
    assert_eq!(Verbosity::parse("debug"), Some(Verbosity::Debug));
    assert_eq!(Verbosity::parse("trace"), Some(Verbosity::Trace));
    assert_eq!(Verbosity::parse("loud"), None);
    // Off must mean disabled — the zero-overhead contract.
    assert!(!Telemetry::from_verbosity(Verbosity::Off).enabled());
    assert!(Telemetry::from_verbosity(Verbosity::Info).enabled());
}
