//! Cross-module integration tests: workload → simulator → optimizer →
//! plan → plugin, plus failure injection on the plan path.

use std::time::Duration;

use kube_packd::cluster::{identical_nodes, ClusterState, Event, NodeId, Pod, PodId, Priority, Resources};
use kube_packd::harness::figures::tiny_grid;
use kube_packd::harness::grid::run_grid;
use kube_packd::metrics::categories::Outcome;
use kube_packd::optimizer::algorithm::{optimize, OptimizerConfig};
use kube_packd::optimizer::{MovePlan, OptimizingScheduler};
use kube_packd::simulator::KwokSimulator;
use kube_packd::solver::SolverConfig;
use kube_packd::workload::{dataset, GenParams, Instance};

/// Full pipeline on a known-fragmenting workload.
#[test]
fn pipeline_workload_to_optimised_cluster() {
    let params = GenParams {
        nodes: 4,
        pods_per_node: 4,
        priority_tiers: 2,
        usage: 1.0,
    };
    let insts = Instance::generate_challenging(params, 3, 2024, 300);
    assert!(!insts.is_empty(), "no challenging instances found");
    for inst in &insts {
        let mut state = ClusterState::new(inst.nodes.clone(), inst.pods.clone());
        let mut sched = OptimizingScheduler::new(
            inst.params.p_max(),
            OptimizerConfig::with_timeout(1.0),
        );
        let report = sched.run(&mut state);
        assert!(report.solver_invoked);
        state.check_invariants().unwrap();
        // event log is consistent with the report
        let solver_events = state
            .events
            .count(|e| matches!(e, Event::SolverInvoked { .. }));
        assert_eq!(solver_events, 1);
        if report.improved {
            assert!(kube_packd::metrics::lex_better(
                &report.placed_after,
                &report.placed_before
            ));
        }
    }
}

/// The optimiser's plan must survive a dataset round-trip (generate →
/// save → load → solve) with identical results.
#[test]
fn dataset_roundtrip_stability() {
    let params = GenParams {
        nodes: 4,
        pods_per_node: 4,
        priority_tiers: 2,
        usage: 1.05,
    };
    let insts = Instance::generate_challenging(params, 2, 555, 200);
    let path = std::env::temp_dir().join("kp-integration-ds.json");
    dataset::save(&insts, &path).unwrap();
    let loaded = dataset::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    for (a, b) in insts.iter().zip(&loaded) {
        let run_a = kube_packd::harness::run_instance(a, 0.5, &SolverConfig::default());
        let run_b = kube_packd::harness::run_instance(b, 0.5, &SolverConfig::default());
        assert_eq!(run_a.kwok_placed, run_b.kwok_placed);
        // outcomes may differ between Better and Better&Optimal under
        // timing jitter, but the baseline and improvement direction agree
        assert_eq!(
            run_a.outcome == Outcome::Failure,
            run_b.outcome == Outcome::Failure
        );
    }
}

/// Failure injection: a plan built against a *stale* state (capacity
/// stolen between solve and execution) must fail loudly, not corrupt.
#[test]
fn stale_plan_execution_fails_cleanly() {
    let nodes = identical_nodes(2, Resources::new(4000, 4096));
    let pods = vec![
        Pod::new(0, "a", Resources::new(100, 2048), Priority(0)),
        Pod::new(1, "b", Resources::new(100, 2048), Priority(0)),
        Pod::new(2, "c", Resources::new(100, 3072), Priority(0)),
    ];
    let mut state = ClusterState::new(nodes, pods);
    state.bind(PodId(0), NodeId(0)).unwrap();
    state.bind(PodId(1), NodeId(1)).unwrap();

    let res = optimize(&state, 0, &OptimizerConfig::with_timeout(1.0)).unwrap();
    let plan = MovePlan::build(&state, &res.target);
    assert!(!plan.is_empty());

    // Interloper pod grabs capacity after the solve: small enough to bind
    // into the residual, big enough to break the planned 3072-MiB bind.
    let thief = state.add_pod(Pod::new(0, "thief", Resources::new(500, 2000), Priority(0)));
    let home = res.target[2].unwrap(); // where the big pod should go
    state.bind(thief, home).unwrap();

    let snapshot = state.clone();
    let err = plan.execute(&mut state);
    assert!(err.is_err(), "stale plan must not apply");
    // state may be partially mutated but never inconsistent
    state.check_invariants().unwrap();
    // ... and validate() on the snapshot reports the same problem upfront
    assert!(plan.validate(&snapshot).is_err());
}

/// Unschedulable pods flushed after optimisation must not loop forever.
#[test]
fn optimizing_scheduler_terminates_when_nothing_fits() {
    let nodes = identical_nodes(1, Resources::new(100, 100));
    let pods = vec![
        Pod::new(0, "xl-1", Resources::new(1000, 1000), Priority(0)),
        Pod::new(1, "xl-2", Resources::new(1000, 1000), Priority(0)),
    ];
    let mut state = ClusterState::new(nodes, pods);
    let mut sched = OptimizingScheduler::new(0, OptimizerConfig::with_timeout(0.5));
    let report = sched.run(&mut state);
    assert!(report.solver_invoked);
    assert!(!report.improved);
    assert_eq!(report.placed_after, vec![0]);
}

/// Tiny end-to-end sweep through the harness grid machinery.
#[test]
fn harness_grid_end_to_end() {
    let cells = run_grid(&tiny_grid());
    assert!(!cells.is_empty());
    for cell in &cells {
        assert_eq!(cell.counts.iter().sum::<usize>(), cell.instances);
        // challenging instances ⇒ solver invoked ⇒ NoCalls is impossible
        assert_eq!(cell.pct(Outcome::NoCalls), 0.0);
    }
}

/// α-budget accounting: a larger p_max must not blow the total timeout.
#[test]
fn total_timeout_respected_across_tiers() {
    let params = GenParams {
        nodes: 8,
        pods_per_node: 8,
        priority_tiers: 4,
        usage: 1.05,
    };
    let insts = Instance::generate_challenging(params, 1, 9, 100);
    if let Some(inst) = insts.first() {
        let mut sim = KwokSimulator::new(inst.params.p_max());
        let (state, _) = sim.run(inst.nodes.clone(), inst.pods.clone());
        let t0 = std::time::Instant::now();
        let _ = optimize(
            &state,
            inst.params.p_max(),
            &OptimizerConfig {
                total_timeout: Duration::from_millis(600),
                ..Default::default()
            },
        );
        let wall = t0.elapsed();
        // generous envelope: T_total + per-phase minimum grants + overhead
        assert!(
            wall < Duration::from_millis(600 * 3),
            "optimize ran {wall:?} against a 600ms budget"
        );
    }
}

/// The XLA-scored scheduler must produce the same placements as the
/// plugin-scored one (full determinism parity), when artifacts exist.
#[test]
fn xla_and_native_schedulers_agree_on_placements() {
    let Ok(scorer) = kube_packd::runtime::XlaScorer::from_artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let params = GenParams {
        nodes: 6,
        pods_per_node: 5,
        priority_tiers: 2,
        usage: 0.95,
    };
    let inst = Instance::generate(params, 31337);

    let mut plain = KwokSimulator::new(params.p_max());
    let (s1, _) = plain.run(inst.nodes.clone(), inst.pods.clone());

    let mut xla = KwokSimulator::new(params.p_max()).with_batch_scorer(Box::new(scorer));
    let (s2, _) = xla.run(inst.nodes.clone(), inst.pods.clone());

    assert_eq!(s1.assignment(), s2.assignment(), "scorer backends diverged");
}
