//! Property-based tests over solver / scheduler / optimizer invariants,
//! using the in-house `util::prop` mini-framework (proptest substitute;
//! see DESIGN.md "Substitutions"). Each property runs against dozens of
//! seeded random cases; failures report the reproducing seed.

use kube_packd::cluster::{ClusterState, NodeId, Pod, PodId, Priority, Resources};
use kube_packd::lifecycle::{run_churn, ChurnConfig, Policy};
use kube_packd::metrics::lex_better;
use kube_packd::optimizer::algorithm::{optimize, optimize_probed, OptimizerConfig};
use kube_packd::optimizer::plan::MovePlan;
use kube_packd::portfolio::PortfolioConfig;
use kube_packd::simulator::KwokSimulator;
use kube_packd::solver::{
    solve_max, solve_max_probed, LinearExpr, Model, Probe, SolveStatus, SolverConfig,
};
use kube_packd::telemetry::{Deadline, Telemetry};
use kube_packd::util::prop::check;
use kube_packd::util::rng::Rng;
use kube_packd::workload::churn::{ChurnParams, ChurnTraceGenerator};
use kube_packd::workload::{GenParams, Instance};

/// Random small packing model: `pods` groups × `nodes` options with
/// random demands and capacities. Returns (model, objective).
fn random_packing(rng: &mut Rng) -> (Model, LinearExpr, usize, usize) {
    let pods = rng.range_usize(2, 12);
    let nodes = rng.range_usize(1, 4);
    let mut m = Model::new();
    let mut vars = Vec::new();
    let demands: Vec<(i64, i64)> = (0..pods)
        .map(|_| (rng.range_i64(50, 600), rng.range_i64(50, 600)))
        .collect();
    for _ in 0..pods {
        let xs = m.new_vars(nodes);
        let ci = m.next_constraint_index();
        m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
        m.tag_constraint(ci, "placement");
        vars.push(xs);
    }
    let cap = rng.range_i64(300, 1500);
    let mut cpu_class = Vec::new();
    let mut ram_class = Vec::new();
    for j in 0..nodes {
        let ci = m.next_constraint_index();
        cpu_class.push(ci);
        m.add_le(
            LinearExpr::of(vars.iter().zip(&demands).map(|(xs, &(c, _))| (xs[j], c))),
            cap,
        );
        m.tag_constraint(ci, "capacity:cpu");
        let ci = m.next_constraint_index();
        ram_class.push(ci);
        m.add_le(
            LinearExpr::of(vars.iter().zip(&demands).map(|(xs, &(_, r))| (xs[j], r))),
            cap,
        );
        m.tag_constraint(ci, "capacity:ram");
    }
    m.add_resource_class(cpu_class);
    m.add_resource_class(ram_class);
    let obj = LinearExpr::of(vars.iter().flatten().map(|&v| (v, 1)));
    (m, obj, pods, nodes)
}

/// Exhaustive optimum by brute force (assignments as base-(nodes+1)
/// counters) — only for tiny models.
fn brute_force_max(m: &Model, obj: &LinearExpr, pods: usize, nodes: usize) -> i64 {
    let nv = m.num_vars();
    let mut best = i64::MIN;
    let mut assign = vec![0usize; pods]; // 0 = none, 1..=nodes = node
    loop {
        let mut values = vec![false; nv];
        for (i, &a) in assign.iter().enumerate() {
            if a > 0 {
                values[i * nodes + (a - 1)] = true;
            }
        }
        if m.feasible(&values) {
            best = best.max(obj.eval(&values));
        }
        // increment counter
        let mut k = 0;
        loop {
            if k == pods {
                return best;
            }
            assign[k] += 1;
            if assign[k] <= nodes {
                break;
            }
            assign[k] = 0;
            k += 1;
        }
    }
}

#[test]
fn prop_solver_matches_brute_force_on_tiny_models() {
    check(
        "solver_matches_brute_force",
        0xBF01,
        40,
        |rng| {
            // keep models tiny enough for brute force: <= 4^7 states
            let mut r2 = rng.fork();
            loop {
                let (m, obj, pods, nodes) = random_packing(&mut r2);
                if pods <= 7 && nodes <= 3 {
                    return (m, obj, pods, nodes);
                }
            }
        },
        |(m, obj, pods, nodes)| {
            let sol = solve_max(m, obj, Deadline::unlimited(), &SolverConfig::default());
            if sol.status != SolveStatus::Optimal {
                return Err(format!("expected Optimal, got {:?}", sol.status));
            }
            let want = brute_force_max(m, obj, *pods, *nodes);
            if sol.objective != want {
                return Err(format!("solver {} != brute force {}", sol.objective, want));
            }
            if !m.feasible(&sol.values) {
                return Err("solution violates constraints".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_solver_feature_toggles_agree_on_optimum() {
    // bound / best-fit / symmetry / hints must never change the OPTIMAL
    // objective value — only how fast it is reached.
    check(
        "feature_toggles_agree",
        0xF0661,
        25,
        random_packing,
        |(m, obj, _, _)| {
            let base = solve_max(m, obj, Deadline::unlimited(), &SolverConfig::default());
            for cfg in [
                SolverConfig {
                    use_bound: false,
                    use_capacity_bound: false,
                    ..Default::default()
                },
                SolverConfig {
                    use_symmetry: false,
                    ..Default::default()
                },
                SolverConfig {
                    use_best_fit: false,
                    use_hints: false,
                    ..Default::default()
                },
                SolverConfig {
                    branch_easiest_first: true,
                    ..Default::default()
                },
            ] {
                let alt = solve_max(m, obj, Deadline::unlimited(), &cfg);
                if alt.status != SolveStatus::Optimal || base.status != SolveStatus::Optimal {
                    return Err(format!("non-optimal: {:?}/{:?}", base.status, alt.status));
                }
                if alt.objective != base.objective {
                    return Err(format!(
                        "toggle changed optimum: {} vs {} ({cfg:?})",
                        base.objective, alt.objective
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scheduler_never_overcommits_and_is_deterministic() {
    check(
        "scheduler_invariants",
        0x5CED,
        40,
        |rng| {
            let params = GenParams {
                nodes: rng.range_usize(2, 8),
                pods_per_node: rng.range_usize(2, 8),
                priority_tiers: rng.range_usize(1, 4) as u32,
                usage: 0.85 + rng.f64() * 0.25,
            };
            Instance::generate(params, rng.next_u64())
        },
        |inst| {
            let mut sim1 = KwokSimulator::new(inst.params.p_max());
            let (s1, r1) = sim1.run(inst.nodes.clone(), inst.pods.clone());
            s1.check_invariants()?;
            let mut sim2 = KwokSimulator::new(inst.params.p_max());
            let (s2, _) = sim2.run(inst.nodes.clone(), inst.pods.clone());
            if s1.assignment() != s2.assignment() {
                return Err("nondeterministic placement".into());
            }
            let placed: usize = r1.placed_per_priority.iter().sum();
            if placed + r1.pending.len() != inst.pods.len() {
                return Err("pod accounting broken".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_optimizer_never_worse_than_kwok_and_plan_executes() {
    check(
        "optimizer_dominates_kwok",
        0x0D0C,
        12,
        |rng| {
            let params = GenParams {
                nodes: rng.range_usize(2, 6),
                pods_per_node: rng.range_usize(3, 6),
                priority_tiers: rng.range_usize(1, 3) as u32,
                usage: 0.95 + rng.f64() * 0.10,
            };
            Instance::generate(params, rng.next_u64())
        },
        |inst| {
            let p_max = inst.params.p_max();
            let mut sim = KwokSimulator::new(p_max);
            let (state, base) = sim.run(inst.nodes.clone(), inst.pods.clone());
            let Some(res) = optimize(&state, p_max, &OptimizerConfig::with_timeout(1.0)) else {
                return Ok(()); // a Failure is allowed, just not a regression
            };
            if lex_better(&base.placed_per_priority, &res.placed_per_priority) {
                return Err(format!(
                    "optimizer strictly worse: kwok {:?} vs opt {:?}",
                    base.placed_per_priority, res.placed_per_priority
                ));
            }
            // the plan derived from the target must execute cleanly
            let plan = MovePlan::build(&state, &res.target);
            let mut live = state.clone();
            plan.execute(&mut live).map_err(|e| format!("plan: {e}"))?;
            live.check_invariants()?;
            // and realise exactly the target
            if live.assignment() != &res.target[..] {
                return Err("plan did not realise the solver target".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_move_plan_roundtrip_arbitrary_targets() {
    // For arbitrary feasible targets (not just solver output), the plan
    // builder must produce an executable evict-then-place sequence.
    check(
        "move_plan_roundtrip",
        0x9142,
        40,
        |rng| {
            let params = GenParams {
                nodes: rng.range_usize(2, 6),
                pods_per_node: 3,
                priority_tiers: 1,
                usage: 0.7 + rng.f64() * 0.2,
            };
            let inst = Instance::generate(params, rng.next_u64());
            let seed = rng.next_u64();
            (inst, seed)
        },
        |(inst, seed)| {
            let mut state = ClusterState::new(inst.nodes.clone(), inst.pods.clone());
            // random initial placement via first-fit on a shuffled order
            let mut rng = Rng::new(*seed);
            let mut order: Vec<usize> = (0..inst.pods.len()).collect();
            rng.shuffle(&mut order);
            for &i in &order {
                for j in 0..inst.nodes.len() {
                    if state.bind(PodId(i as u32), NodeId(j as u32)).is_ok() {
                        break;
                    }
                }
            }
            // random feasible target: replay first-fit with another order
            let mut target_state = ClusterState::new(inst.nodes.clone(), inst.pods.clone());
            rng.shuffle(&mut order);
            for &i in &order {
                for j in (0..inst.nodes.len()).rev() {
                    if target_state.bind(PodId(i as u32), NodeId(j as u32)).is_ok() {
                        break;
                    }
                }
            }
            let target: Vec<_> = target_state.assignment().to_vec();
            let plan = MovePlan::build(&state, &target);
            let mut live = state.clone();
            plan.execute(&mut live).map_err(|e| format!("{e}"))?;
            if live.assignment() != &target[..] {
                return Err("plan did not reach target".into());
            }
            live.check_invariants()?;
            Ok(())
        },
    );
}

#[test]
fn prop_invariants_hold_under_arbitrary_lifecycle_interleavings() {
    // Random interleavings of bind / evict / terminate / drain / cordon /
    // uncordon / join / add_pod must never corrupt the residual-capacity
    // invariant, never leave a retired pod bound, and never host pods on
    // removed nodes. Individual operations may fail (Err) — that is part
    // of the contract; corruption is not.
    check(
        "lifecycle_interleavings",
        0x11FE,
        30,
        |rng| {
            let params = GenParams {
                nodes: rng.range_usize(2, 5),
                pods_per_node: rng.range_usize(2, 5),
                priority_tiers: rng.range_usize(1, 3) as u32,
                usage: 0.8 + rng.f64() * 0.3,
            };
            (Instance::generate(params, rng.next_u64()), rng.next_u64())
        },
        |(inst, op_seed)| {
            let mut state = ClusterState::new(inst.nodes.clone(), inst.pods.clone());
            let mut rng = Rng::new(*op_seed);
            for step in 0..150 {
                let n_pods = state.pods().len() as u64;
                let n_nodes = state.nodes().len() as u64;
                let pod = PodId(rng.below(n_pods) as u32);
                let node = NodeId(rng.below(n_nodes) as u32);
                match rng.below(8) {
                    0 | 1 => {
                        let _ = state.bind(pod, node);
                    }
                    2 => {
                        let _ = state.evict(pod);
                    }
                    3 => {
                        let _ = state.terminate(pod);
                    }
                    4 => {
                        state.drain(node);
                    }
                    5 => {
                        if rng.chance(0.5) {
                            state.cordon(node);
                        } else {
                            state.uncordon(node);
                        }
                    }
                    6 => {
                        // keep the cluster from growing unboundedly
                        if state.nodes().len() < 8 {
                            state.join_node(inst.nodes[0].capacity);
                        }
                    }
                    _ => {
                        if state.pods().len() < 64 {
                            let req = Resources::new(
                                rng.range_i64(100, 1000),
                                rng.range_i64(100, 1000),
                            );
                            let prio =
                                Priority(rng.below(inst.params.priority_tiers as u64) as u32);
                            state.add_pod(Pod::new(0, format!("extra-{step}"), req, prio));
                        }
                    }
                }
                state.check_invariants()?;
            }
            // terminal spot-checks on the lifecycle bookkeeping
            for pod in state.pods() {
                if state.is_retired(pod.id) && state.assignment_of(pod.id).is_some() {
                    return Err(format!("retired pod {} still bound", pod.name));
                }
            }
            for p in state.pending_pods() {
                if state.is_retired(p) {
                    return Err("pending list contains a retired pod".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_churn_timeline_replay_is_byte_identical() {
    // Same seed => same trace ops => byte-identical event logs and
    // identical end metrics, across independent simulator instances.
    check(
        "churn_replay_determinism",
        0xC4AB,
        8,
        |rng| {
            let params = ChurnParams {
                horizon_ms: 3_000 + rng.below(3_000),
                mean_arrival_ms: 300 + rng.below(400),
                mean_lifetime_ms: 1_000 + rng.below(2_000),
                ..ChurnParams::for_cluster(GenParams {
                    nodes: rng.range_usize(2, 4),
                    pods_per_node: rng.range_usize(2, 4),
                    priority_tiers: rng.range_usize(1, 3) as u32,
                    usage: 0.85 + rng.f64() * 0.2,
                })
            };
            (params, rng.next_u64())
        },
        |(params, seed)| {
            let t1 = ChurnTraceGenerator::new(*params, *seed).generate();
            let t2 = ChurnTraceGenerator::new(*params, *seed).generate();
            if format!("{:?}", t1.ops) != format!("{:?}", t2.ops) {
                return Err("trace generation not deterministic".into());
            }
            let cfg = ChurnConfig::for_policy(Policy::DefaultOnly);
            let r1 = run_churn(&t1, &cfg);
            let r2 = run_churn(&t2, &cfg);
            if r1.log.render() != r2.log.render() {
                return Err("event logs diverged on replay".into());
            }
            if r1.log.digest() != r2.log.digest() {
                return Err("log digests diverged".into());
            }
            if r1.final_placed != r2.final_placed || r1.evictions != r2.evictions {
                return Err("end metrics diverged on replay".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_armed_probe_is_invisible_and_attributes_all_effort() {
    // Arming the forensics probe must never change a solution, and every
    // unit of recorded effort must land on a provenance slug — conflicts
    // and propagations sum exactly to the search counters, with nothing
    // outside the tagged modules and the explicit `search:*` buckets.
    check(
        "probe_invisible_and_attributed",
        0x9B0E,
        25,
        random_packing,
        |(m, obj, _, _)| {
            let cfg = SolverConfig::default();
            let off = solve_max(m, obj, Deadline::unlimited(), &cfg);
            let prof = Probe::armed();
            let armed = solve_max_probed(m, obj, Deadline::unlimited(), &cfg, None, &prof);
            if (&armed.values, armed.objective, armed.status, armed.bound)
                != (&off.values, off.objective, off.status, off.bound)
            {
                return Err(format!(
                    "arming the probe changed the answer: {:?}/{} vs {:?}/{}",
                    armed.status, armed.objective, off.status, off.objective
                ));
            }
            let eff = prof.module_effort();
            let total = |kind: &str| -> u64 {
                eff.iter()
                    .filter(|(_, k, _)| *k == kind)
                    .map(|&(_, _, n)| n)
                    .sum()
            };
            let bucket = |slug: &str, kind: &str| -> u64 {
                eff.iter()
                    .find(|(s, k, _)| s == slug && *k == kind)
                    .map(|&(_, _, n)| n)
                    .unwrap_or(0)
            };
            if total("conflicts") != armed.stats.conflicts {
                return Err(format!(
                    "conflicts escaped attribution: {} profiled vs {} counted",
                    total("conflicts"),
                    armed.stats.conflicts
                ));
            }
            if total("propagations") != armed.stats.propagations {
                return Err(format!(
                    "propagations escaped attribution: {} profiled vs {} counted",
                    total("propagations"),
                    armed.stats.propagations
                ));
            }
            for (slug, kind, want) in [
                ("search", "decisions", armed.stats.decisions),
                ("search:bound", "prunes", armed.stats.bound_prunes),
                ("search:floor", "prunes", armed.stats.floor_prunes),
                ("search:symmetry", "skips", armed.stats.symmetry_skips),
            ] {
                if bucket(slug, kind) != want {
                    return Err(format!(
                        "{slug}/{kind}: profiled {} vs counted {want}",
                        bucket(slug, kind)
                    ));
                }
            }
            // Every slug is either a tagged module or an explicit
            // search-level bucket — nothing anonymous.
            for (slug, _, _) in &eff {
                let known = slug == "placement"
                    || slug.starts_with("capacity:")
                    || slug == "search"
                    || slug.starts_with("search:");
                if !known {
                    return Err(format!("effort on unknown provenance slug {slug:?}"));
                }
            }
            // Gap samples stay admissible and decision-indexed.
            let gaps = prof.gap_samples();
            for w in gaps.windows(2) {
                if w[1].decisions < w[0].decisions {
                    return Err("gap timeline not decision-monotone".into());
                }
            }
            for g in &gaps {
                if g.bound < g.incumbent {
                    return Err(format!(
                        "inadmissible gap sample: incumbent {} above bound {}",
                        g.incumbent, g.bound
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_probe_is_invisible_through_the_optimizer_across_threads() {
    // End to end: plans (targets), objective vectors, and certificates
    // are byte-identical with the probe armed vs off at 1 and 8 threads,
    // and the armed profile itself is identical across thread counts.
    // Deadline-truncated (uncertified) solves are skipped — truncation
    // points are wall-clock artifacts, which is exactly why the probe
    // only pins profiles for completing solves.
    check(
        "probe_invisible_through_optimizer",
        0x9B0F,
        6,
        |rng| {
            let params = GenParams {
                nodes: rng.range_usize(2, 5),
                pods_per_node: rng.range_usize(2, 4),
                priority_tiers: rng.range_usize(1, 3) as u32,
                usage: 0.95 + rng.f64() * 0.10,
            };
            Instance::generate(params, rng.next_u64())
        },
        |inst| {
            let p_max = inst.params.p_max();
            let mut sim = KwokSimulator::new(p_max);
            let (state, _) = sim.run(inst.nodes.clone(), inst.pods.clone());
            let cfg = |threads: usize| OptimizerConfig {
                portfolio: PortfolioConfig::with_threads(threads),
                ..OptimizerConfig::with_timeout(5.0)
            };
            let Some(off) = optimize(&state, p_max, &cfg(1)) else {
                return Ok(());
            };
            if !off.proved_optimal {
                return Ok(()); // truncated — not the property under test
            }
            let mut profiles = Vec::new();
            for threads in [1usize, 8] {
                let prof = Probe::armed();
                let Some(armed) = optimize_probed(
                    &state,
                    p_max,
                    &cfg(threads),
                    None,
                    &Telemetry::off(),
                    &prof,
                ) else {
                    return Err(format!("armed solve at {threads} threads failed"));
                };
                if armed.target != off.target {
                    return Err(format!("plan drifted at {threads} threads (armed vs off)"));
                }
                if armed.placed_per_priority != off.placed_per_priority {
                    return Err(format!("objective vector drifted at {threads} threads"));
                }
                if armed.proved_optimal != off.proved_optimal {
                    return Err(format!("certificate drifted at {threads} threads"));
                }
                for (a, o) in armed.tiers.iter().zip(&off.tiers) {
                    if (a.phase1_status, a.phase1_placed, a.phase1_bound)
                        != (o.phase1_status, o.phase1_placed, o.phase1_bound)
                        || (a.phase2_status, a.phase2_metric, a.phase2_bound)
                            != (o.phase2_status, o.phase2_metric, o.phase2_bound)
                    {
                        return Err(format!(
                            "tier certificate drifted at {threads} threads (tier {})",
                            a.priority
                        ));
                    }
                }
                profiles.push(prof.export_profile_json());
            }
            if profiles[0] != profiles[1] {
                return Err("profile differs across thread counts".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_anytime_never_regresses_with_more_time() {
    // More budget can only improve (or keep) the placed vector.
    check(
        "anytime_monotone",
        0xA11E,
        6,
        |rng| {
            let params = GenParams {
                nodes: 8,
                pods_per_node: 6,
                priority_tiers: 2,
                usage: 1.0,
            };
            Instance::generate(params, rng.next_u64())
        },
        |inst| {
            let p_max = inst.params.p_max();
            let mut sim = KwokSimulator::new(p_max);
            let (state, _) = sim.run(inst.nodes.clone(), inst.pods.clone());
            let short = optimize(&state, p_max, &OptimizerConfig::with_timeout(0.1));
            let long = optimize(&state, p_max, &OptimizerConfig::with_timeout(1.0));
            if let (Some(s), Some(l)) = (short, long) {
                if lex_better(&s.placed_per_priority, &l.placed_per_priority) {
                    return Err(format!(
                        "long run worse: {:?} < {:?}",
                        l.placed_per_priority, s.placed_per_priority
                    ));
                }
            }
            Ok(())
        },
    );
}
