//! End-to-end lifecycle tests: deterministic timeline replay and the
//! policy-dominance claim of the churn report, over real traces.

use kube_packd::harness::churn::{churn_report, dominates_per_tier};
use kube_packd::lifecycle::{
    compare_policies, run_churn, ChurnConfig, Policy, SweepConfig,
};
use kube_packd::metrics::lex_better;
use kube_packd::optimizer::algorithm::OptimizerConfig;
use kube_packd::portfolio::PortfolioConfig;
use kube_packd::workload::churn::{ChurnParams, ChurnTraceGenerator};
use kube_packd::workload::GenParams;

fn small_params() -> ChurnParams {
    ChurnParams {
        horizon_ms: 6_000,
        mean_arrival_ms: 500,
        mean_lifetime_ms: 2_000,
        ..ChurnParams::for_cluster(GenParams {
            nodes: 4,
            pods_per_node: 4,
            priority_tiers: 2,
            usage: 0.95,
        })
    }
}

/// Generous per-solve budget so every optimisation on these tiny models
/// is proven optimal — which makes even the solver-backed policies
/// deterministic across replays.
fn solver_cfg(policy: Policy) -> ChurnConfig {
    ChurnConfig {
        policy,
        sweep_every_ms: 2_000,
        sweep: SweepConfig {
            optimizer: OptimizerConfig::with_timeout(5.0),
            eviction_budget: 8,
        },
        fallback_timeout: std::time::Duration::from_secs(5),
        fallback_portfolio: PortfolioConfig::default(),
        incremental: false,
        autoscale: None,
    }
}

#[test]
fn default_only_replay_is_byte_identical() {
    let trace = ChurnTraceGenerator::new(small_params(), 42).generate();
    let a = run_churn(&trace, &ChurnConfig::for_policy(Policy::DefaultOnly));
    let b = run_churn(&trace, &ChurnConfig::for_policy(Policy::DefaultOnly));
    assert_eq!(a.log.digest(), b.log.digest());
    assert_eq!(a.log.render(), b.log.render());
    assert_eq!(a.final_placed, b.final_placed);
    assert_eq!(a.evictions, b.evictions);
}

#[test]
fn fallback_sweep_replay_is_byte_identical() {
    let trace = ChurnTraceGenerator::new(small_params(), 42).generate();
    let a = run_churn(&trace, &solver_cfg(Policy::FallbackSweep));
    let b = run_churn(&trace, &solver_cfg(Policy::FallbackSweep));
    assert_eq!(a.log.render(), b.log.render());
    assert_eq!(a.served_per_priority, b.served_per_priority);
    assert_eq!(a.sweeps_applied, b.sweeps_applied);
}

#[test]
fn optimised_policies_never_serve_lexicographically_fewer_pods() {
    for seed in [1u64, 7, 42] {
        let trace = ChurnTraceGenerator::new(small_params(), seed).generate();
        let results = compare_policies(&trace, &solver_cfg(Policy::FallbackSweep));
        let base = &results[0];
        assert_eq!(base.policy, Policy::DefaultOnly);
        for opt in &results[1..] {
            assert!(
                !lex_better(&base.served_per_priority, &opt.served_per_priority),
                "seed {seed}: {} served {:?} < default-only {:?}",
                opt.policy.label(),
                opt.served_per_priority,
                base.served_per_priority
            );
        }
    }
}

#[test]
fn report_carries_the_dominance_verdict() {
    let trace = ChurnTraceGenerator::new(small_params(), 42).generate();
    let results = compare_policies(&trace, &solver_cfg(Policy::FallbackSweep));
    let report = churn_report(&trace, &results);
    assert!(report.contains("fallback+sweep serves >= default-only"));
    // and on this workload the claim actually holds per tier
    let base = &results[0].served_per_priority;
    let sweep = &results[2].served_per_priority;
    assert!(
        dominates_per_tier(sweep, base),
        "sweep {sweep:?} vs default {base:?}"
    );
}

#[test]
fn node_churn_is_survivable_under_every_policy() {
    // Crank node churn way up; the simulator must stay consistent.
    let params = ChurnParams {
        drain_chance: 0.2,
        join_chance: 0.2,
        ..small_params()
    };
    let trace = ChurnTraceGenerator::new(params, 9).generate();
    for policy in [Policy::DefaultOnly, Policy::Fallback, Policy::FallbackSweep] {
        let res = run_churn(&trace, &solver_cfg(policy));
        assert!(res.events_processed >= trace.ops.len());
        // sanity: service metric bounded by arrivals in each tier
        for (s, a) in res
            .served_per_priority
            .iter()
            .zip(&res.arrivals_per_priority)
        {
            assert!(s <= a);
        }
    }
}
