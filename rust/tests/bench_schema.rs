//! Schema guard for committed `BENCH_*.json` artefacts.
//!
//! Every bench binary emits a machine-readable JSON report in the repo
//! root; CI runs this test to validate each committed artefact:
//!
//! * it parses as JSON at all (the writer renders non-finite `f64`s as
//!   `inf` / `NaN`, which are *not* JSON — so a parse failure is exactly
//!   the regression this guards: `stats::min`/`max` leaking ±INFINITY on
//!   empty inputs, or a NaN timing cell surviving `percentile`);
//! * every number in the document is finite;
//! * the shared envelope holds: `bench` (string), `schema` (integer
//!   ≥ 1), `cells` (array of objects).

use std::path::Path;

use kube_packd::util::json::{parse, Json};

/// Recursively assert every number in the tree is finite.
fn assert_finite(value: &Json, path: &str, file: &str) {
    match value {
        Json::Num(n) => assert!(
            n.is_finite(),
            "{file}: non-finite number {n} at {path} — a stats helper leaked inf/NaN"
        ),
        Json::Arr(items) => {
            for (i, item) in items.iter().enumerate() {
                assert_finite(item, &format!("{path}[{i}]"), file);
            }
        }
        Json::Obj(map) => {
            for (k, v) in map {
                assert_finite(v, &format!("{path}.{k}"), file);
            }
        }
        _ => {}
    }
}

#[test]
fn committed_bench_artefacts_match_their_schema() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut checked = 0usize;
    for entry in std::fs::read_dir(root).expect("repo root readable") {
        let entry = entry.expect("dir entry");
        let name = entry.file_name().to_string_lossy().into_owned();
        if !(name.starts_with("BENCH_") && name.ends_with(".json")) {
            continue;
        }
        let text = std::fs::read_to_string(entry.path())
            .unwrap_or_else(|e| panic!("{name}: unreadable: {e}"));
        let doc = parse(&text).unwrap_or_else(|e| {
            panic!("{name}: not valid JSON ({e:?}) — non-finite numbers render as inf/NaN")
        });

        // Shared envelope.
        let bench = doc
            .get("bench")
            .and_then(Json::as_str)
            .unwrap_or_else(|| panic!("{name}: missing string field 'bench'"));
        assert!(!bench.is_empty(), "{name}: empty 'bench' label");
        let schema = doc
            .get("schema")
            .and_then(Json::as_i64)
            .unwrap_or_else(|| panic!("{name}: missing integer field 'schema'"));
        assert!(schema >= 1, "{name}: schema version must be >= 1");
        let cells = doc
            .get("cells")
            .and_then(Json::as_arr)
            .unwrap_or_else(|| panic!("{name}: missing array field 'cells'"));
        for (i, cell) in cells.iter().enumerate() {
            assert!(
                matches!(cell, Json::Obj(_)),
                "{name}: cells[{i}] is not an object"
            );
        }

        // Finite numbers only, everywhere.
        assert_finite(&doc, "$", &name);
        checked += 1;
    }
    assert!(
        checked >= 1,
        "no BENCH_*.json artefacts found in the repo root — the bench trajectory regressed"
    );
}

#[test]
fn schema_guard_rejects_non_finite_payloads() {
    // The JSON writer renders f64::INFINITY as `inf`, which the parser
    // refuses — proving the guard actually bites on the stats regression
    // it exists for.
    let mut doc = Json::obj();
    doc.set("bench", "broken")
        .set("schema", 1u64)
        .set("min_s", f64::INFINITY);
    let rendered = doc.to_string_pretty();
    assert!(
        parse(&rendered).is_err(),
        "a non-finite number must not round-trip: {rendered}"
    );
}
