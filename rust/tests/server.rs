//! Serve-daemon integration tests.
//!
//! Four contracts from the serve subsystem, each pinned here:
//!
//! 1. **Wire round-trips** — every [`WireOp`] serialises and parses
//!    back byte-identically (the protocol's canonical-form claim), and
//!    malformed frames map to the stable error taxonomy without ever
//!    panicking (fuzz-ish proptest over garbage lines).
//! 2. **Live hardening** — a real daemon over loopback survives bad
//!    JSON, unknown ops, oversized lines, and garbage bursts with one
//!    structured error reply per frame and the connection intact.
//! 3. **Drain** — `shutdown` (and SIGINT) stop admission, the in-flight
//!    window closes, every already-enqueued reply is delivered, and the
//!    daemon exits cleanly. No reply lost, no request accepted after
//!    the drain begins.
//! 4. **Determinism & equivalence** — the replay surface is
//!    byte-identical at 1 and 8 portfolio threads, and a churn trace
//!    converted through [`trace_to_windows`] leaves the engine in the
//!    same fingerprinted state as `run_churn` on the original trace.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use kube_packd::cluster::{identical_nodes, Node, Resources, Taint, Toleration};
use kube_packd::lifecycle::{run_churn, ChurnConfig, Policy, SweepConfig};
use kube_packd::optimizer::OptimizerConfig;
use kube_packd::portfolio::PortfolioConfig;
use kube_packd::server::engine::{Engine, EngineConfig};
use kube_packd::server::loadgen::{
    engine_for_trace, replay_observed, replay_reply_stream, stream_fingerprint,
};
use kube_packd::server::protocol::{
    parse_request, trace_to_windows, SubmitSpec, WireOp, WireRequest, MAX_LINE_BYTES,
};
use kube_packd::server::{ServeConfig, ServeHandle};
use kube_packd::util::json::{parse, Json};
use kube_packd::util::prop;
use kube_packd::util::rng::Rng;
use kube_packd::workload::{ChurnParams, ChurnTraceGenerator, ConstraintProfile, GenParams};

// ---- helpers --------------------------------------------------------------

/// The paper's figure-1 cluster: two 4Gi nodes, one priority tier.
fn fig1_engine(window_ms: u64) -> EngineConfig {
    EngineConfig {
        p_max: 0,
        nodes: identical_nodes(2, Resources::new(4000, 4096)),
        reference_capacity: Resources::new(4000, 4096),
        solve_timeout: Duration::from_secs(5),
        window_ms,
        ..EngineConfig::default()
    }
}

fn spawn_daemon(engine: EngineConfig, max_batch: usize, max_line_bytes: usize) -> ServeHandle {
    ServeHandle::spawn(ServeConfig {
        max_batch,
        max_line_bytes,
        engine,
        ..ServeConfig::default()
    })
    .expect("daemon binds on loopback")
}

/// Minimal blocking newline-JSON client (tests drive ordering
/// explicitly, so no tag matching here — replies are read in order).
struct Client {
    w: TcpStream,
    r: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let s = TcpStream::connect(addr).expect("connect to daemon");
        s.set_nodelay(true).ok();
        Client {
            r: BufReader::new(s.try_clone().expect("clone stream")),
            w: s,
        }
    }

    fn send_raw(&mut self, line: &str) {
        self.w.write_all(line.as_bytes()).expect("send line");
        self.w.write_all(b"\n").expect("send newline");
    }

    fn recv(&mut self) -> Json {
        let mut line = String::new();
        let n = self.r.read_line(&mut line).expect("read reply");
        assert!(n > 0, "daemon closed the connection unexpectedly");
        parse(line.trim_end()).expect("reply is valid JSON")
    }

    fn request(&mut self, req: &WireRequest) -> Json {
        self.send_raw(&req.to_line());
        self.recv()
    }
}

fn error_code(reply: &Json) -> Option<&str> {
    reply.get("error")?.get("code")?.as_str()
}

fn tag_of(reply: &Json) -> Option<i64> {
    reply.get("tag").and_then(Json::as_i64)
}

// ---- 1. wire round-trips --------------------------------------------------

/// A submit exercising every optional constraint field at once.
fn full_spec() -> SubmitSpec {
    SubmitSpec {
        rs_id: Some(7),
        name: "etl".to_string(),
        replicas: 3,
        cpu_milli: 250,
        ram_mib: 512,
        priority: 2,
        labels: vec![("app".to_string(), "etl".to_string())],
        tolerations: vec![
            Toleration::equal("dedicated", "batch"),
            Toleration {
                key: "spot".to_string(),
                value: None,
            },
        ],
        anti_affinity: vec![("app".to_string(), "etl".to_string())],
        spread_max_skew: Some(1),
        extended: vec![("gpu".to_string(), 2)],
    }
}

fn every_op() -> Vec<WireOp> {
    vec![
        WireOp::Submit(SubmitSpec::basic("web", 2, 100, 2048, 0)),
        WireOp::Submit(full_spec()),
        WireOp::Delete {
            pod: "web-0".to_string(),
        },
        WireOp::Join {
            pool: None,
            cpu_milli: Some(4000),
            ram_mib: Some(4096),
        },
        WireOp::Join {
            pool: Some("large".to_string()),
            cpu_milli: None,
            ram_mib: None,
        },
        WireOp::Join {
            pool: Some("small".to_string()),
            cpu_milli: Some(2000),
            ram_mib: Some(2048),
        },
        WireOp::Drain { node: 3 },
        WireOp::Remove { node: 0 },
        WireOp::Query { latency: false },
        WireOp::Query { latency: true },
        WireOp::Health { latency: false },
        WireOp::Health { latency: true },
        WireOp::Metrics,
        WireOp::TraceExport,
        WireOp::Journal {
            since: None,
            limit: None,
            wall: false,
        },
        WireOp::Journal {
            since: Some(12),
            limit: Some(8),
            wall: true,
        },
        WireOp::Watch,
        WireOp::Explain {
            pod: "web-0".to_string(),
        },
        WireOp::Profile,
        WireOp::Shutdown,
    ]
}

#[test]
fn every_wire_op_round_trips_byte_identically() {
    for op in every_op() {
        for req in [
            WireRequest::new(op.clone()),
            WireRequest::tagged(op.clone(), 42),
        ] {
            let line = req.to_line();
            let parsed = parse_request(&line, MAX_LINE_BYTES)
                .unwrap_or_else(|(e, _)| panic!("{op:?} failed to re-parse: {}", e.message()));
            assert_eq!(parsed, req, "structural round-trip for {op:?}");
            assert_eq!(parsed.to_line(), line, "byte-identical reserialisation for {op:?}");
        }
    }
}

#[test]
fn malformed_frames_map_to_the_stable_error_taxonomy() {
    let code = |line: &str, max: usize| -> (&'static str, Option<u64>) {
        match parse_request(line, max) {
            Ok(req) => panic!("{line:?} unexpectedly parsed as {req:?}"),
            Err((e, tag)) => (e.code(), tag),
        }
    };
    assert_eq!(code("{not json", MAX_LINE_BYTES).0, "bad-json");
    assert_eq!(code("[1,2]", MAX_LINE_BYTES).0, "bad-request");
    assert_eq!(code("{\"op\":\"fly\"}", MAX_LINE_BYTES).0, "unknown-op");
    assert_eq!(code("{\"op\":\"submit\"}", MAX_LINE_BYTES).0, "bad-request");
    assert_eq!(code("{\"op\":\"drain\"}", MAX_LINE_BYTES).0, "bad-request");
    assert_eq!(code("{\"op\":\"join\"}", MAX_LINE_BYTES).0, "bad-request");
    assert_eq!(code(&"x".repeat(300), 256).0, "oversized");
    // The correlation tag survives op-level failures so the error reply
    // can carry it back.
    assert_eq!(code("{\"op\":\"fly\",\"tag\":9}", MAX_LINE_BYTES), ("unknown-op", Some(9)));
}

#[test]
fn garbage_frames_never_panic_and_errors_stay_structured() {
    let alphabet: &[u8] = b"{}[]\",:0123456789abcdefgh \t\\truefalsnu-+.eE";
    prop::check(
        "serve-garbage-frames",
        0x6A5B,
        400,
        |rng: &mut Rng| {
            let len = 1 + rng.below(100) as usize;
            (0..len)
                .map(|_| alphabet[rng.below(alphabet.len() as u64) as usize] as char)
                .collect::<String>()
        },
        |line| match parse_request(line, 256) {
            // The rare frame that happens to spell a valid request is
            // fine — the contract is "no panic, errors structured".
            Ok(_) => Ok(()),
            Err((err, tag)) => {
                let reply = err.reply(None, tag);
                match error_code(&reply) {
                    Some(c) if !c.is_empty() => Ok(()),
                    _ => Err(format!("unstructured error reply for {line:?}: {reply}")),
                }
            }
        },
    );
}

// ---- 2. live hardening ----------------------------------------------------

#[test]
fn daemon_survives_garbage_and_keeps_answering() {
    let handle = spawn_daemon(fig1_engine(50), 64, 512);
    let mut c = Client::connect(handle.addr);

    c.send_raw("{definitely not json");
    assert_eq!(error_code(&c.recv()), Some("bad-json"));

    c.send_raw("{\"op\":\"fly\",\"tag\":9}");
    let r = c.recv();
    assert_eq!(error_code(&r), Some("unknown-op"));
    assert_eq!(tag_of(&r), Some(9), "tag recovered onto the error reply");

    // Oversized: the frame reader caps buffering, discards the rest of
    // the line, and the connection must stay usable.
    c.send_raw(&format!("{{\"op\":\"health\",\"pad\":\"{}\"}}", "x".repeat(1024)));
    assert_eq!(error_code(&c.recv()), Some("oversized"));

    // Garbage burst: every frame opens with '[' so it can never spell a
    // valid request (requests are objects) and never reads as an empty
    // line — exactly one structured error reply per frame.
    let mut rng = Rng::new(0xF00D);
    let alphabet: &[u8] = b"{}[]\",:0123456789abcdef \\truefalsnu-+.eE";
    for i in 0..50 {
        let len = rng.below(80) as usize;
        let line: String = std::iter::once('[')
            .chain((0..len).map(|_| alphabet[rng.below(alphabet.len() as u64) as usize] as char))
            .collect();
        c.send_raw(&line);
        let r = c.recv();
        assert!(
            error_code(&r).is_some_and(|c| c == "bad-json" || c == "bad-request"),
            "garbage frame {i} got a non-error reply: {r}"
        );
    }

    // The same connection still serves valid requests.
    let r = c.request(&WireRequest::tagged(WireOp::Health { latency: false }, 1));
    assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(tag_of(&r), Some(1));

    let _ = c.request(&WireRequest::new(WireOp::Shutdown));
    handle.join().expect("daemon exits cleanly");
}

#[test]
fn live_metrics_and_trace_export_have_substance() {
    let handle = spawn_daemon(fig1_engine(50), 64, MAX_LINE_BYTES);
    let mut c = Client::connect(handle.addr);

    // Figure-1 batch: 2Gi + 2Gi + 3Gi over two 4Gi nodes. LeastAllocated
    // spreading strands the 3Gi pod; the window solve re-packs and
    // proves it.
    c.send_raw(&WireRequest::tagged(WireOp::Submit(SubmitSpec::basic("web", 2, 100, 2048, 0)), 1).to_line());
    c.send_raw(&WireRequest::tagged(WireOp::Submit(SubmitSpec::basic("db", 1, 100, 3072, 0)), 2).to_line());
    for expect_tag in [1, 2] {
        let r = c.recv();
        assert_eq!(r.get("op").and_then(Json::as_str), Some("submit"));
        assert_eq!(tag_of(&r), Some(expect_tag));
        assert_eq!(
            r.get("certificate").and_then(Json::as_str),
            Some("proven-optimal"),
            "figure-1 repack must carry the optimality certificate: {r}"
        );
        for p in r.get("placements").and_then(Json::as_arr).expect("placements array") {
            assert!(p.get("node").and_then(Json::as_str).is_some(), "unplaced pod in {r}");
        }
    }

    let m = c.request(&WireRequest::tagged(WireOp::Metrics, 3));
    let body = m.get("body").and_then(Json::as_str).expect("metrics body");
    assert!(
        m.get("content_type").and_then(Json::as_str).is_some_and(|t| t.starts_with("text/plain")),
        "Prometheus exposition content type: {m}"
    );
    for metric in [
        "# TYPE kube_packd_server_requests_total counter",
        "kube_packd_server_windows_total",
        "kube_packd_server_solver_invocations_total",
    ] {
        assert!(body.contains(metric), "metrics body missing {metric:?}:\n{body}");
    }

    let t = c.request(&WireRequest::tagged(WireOp::TraceExport, 4));
    let body = t.get("body").and_then(Json::as_str).expect("trace body");
    let chrome = parse(body).expect("Chrome trace export is valid JSON");
    assert!(chrome.get("traceEvents").is_some() || body.starts_with('['), "unexpected trace shape");
    assert!(body.contains("serve_window"), "window span missing from the live trace export");

    let _ = c.request(&WireRequest::new(WireOp::Shutdown));
    handle.join().expect("daemon exits cleanly");
}

// ---- 3. drain -------------------------------------------------------------

#[test]
fn shutdown_drains_the_window_without_losing_replies() {
    // A huge window: only the drain may close it. If drain failed to
    // flush, the deferred replies below would never arrive (the test
    // would hang rather than pass vacuously).
    let handle = spawn_daemon(fig1_engine(600_000), 1_000, MAX_LINE_BYTES);
    let mut a = Client::connect(handle.addr);
    for (tag, name, replicas, ram) in [(1, "web", 2, 2048), (2, "db", 1, 3072)] {
        a.send_raw(&WireRequest::tagged(WireOp::Submit(SubmitSpec::basic(name, replicas, 100, ram, 0)), tag).to_line());
    }
    // Same-connection barrier: once the query answers, both submits are
    // sequenced and applied — the shutdown below cannot overtake them.
    let q = a.request(&WireRequest::tagged(WireOp::Query { latency: false }, 3));
    assert_eq!(q.get("pending").and_then(Json::as_i64), Some(3), "submits deferred, unplaced: {q}");

    let mut b = Client::connect(handle.addr);
    let ack = b.request(&WireRequest::tagged(WireOp::Shutdown, 9));
    assert_eq!(ack.get("draining").and_then(Json::as_bool), Some(true), "shutdown ack: {ack}");

    // No enqueued reply lost: the drain closes the in-flight window and
    // both deferred submits answer, in seq order, with placements.
    for expect_tag in [1, 2] {
        let r = a.recv();
        assert_eq!(r.get("op").and_then(Json::as_str), Some("submit"), "lost or reordered reply: {r}");
        assert_eq!(tag_of(&r), Some(expect_tag));
        assert_eq!(r.get("certificate").and_then(Json::as_str), Some("proven-optimal"));
        for p in r.get("placements").and_then(Json::as_arr).expect("placements array") {
            assert!(p.get("node").and_then(Json::as_str).is_some(), "unplaced pod in {r}");
        }
    }

    // No request accepted once the drain begins. The flag propagates a
    // beat after the ack, so poll until the structured rejection
    // appears; every probe still gets exactly one reply either way.
    let mut saw_draining = false;
    for i in 0..200u64 {
        b.send_raw(&WireRequest::tagged(WireOp::Health { latency: false }, 100 + i).to_line());
        let r = b.recv();
        if error_code(&r) == Some("draining") {
            assert_eq!(r.get("seq"), None, "drain-time rejections never join the interleaving");
            saw_draining = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(saw_draining, "daemon never began refusing requests after shutdown");
    handle.join().expect("daemon drains and exits cleanly");
}

#[cfg(unix)]
#[test]
fn sigint_drains_like_shutdown() {
    extern "C" {
        fn raise(signum: i32) -> i32;
    }
    const SIGINT: i32 = 2;

    let handle = ServeHandle::spawn(ServeConfig {
        engine: fig1_engine(600_000),
        install_sigint: true,
        ..ServeConfig::default()
    })
    .expect("daemon binds on loopback");
    let mut c = Client::connect(handle.addr);
    // A served health round-trip proves the serve loop is running, and
    // the loop installs the handler before serving — so the raise below
    // cannot kill the test process.
    let h = c.request(&WireRequest::tagged(WireOp::Health { latency: false }, 0));
    assert_eq!(h.get("ok").and_then(Json::as_bool), Some(true));

    c.send_raw(&WireRequest::tagged(WireOp::Submit(SubmitSpec::basic("web", 1, 100, 1024, 0)), 1).to_line());
    let _ = c.request(&WireRequest::tagged(WireOp::Query { latency: false }, 2)); // barrier: submit applied
    unsafe {
        raise(SIGINT);
    }
    // SIGINT must drain exactly like shutdown: close the window, answer
    // the deferred submit, exit 0.
    let r = c.recv();
    assert_eq!(r.get("op").and_then(Json::as_str), Some("submit"));
    assert_eq!(tag_of(&r), Some(1));
    handle.join().expect("daemon exits cleanly after SIGINT");
}

// ---- 4. determinism & equivalence ----------------------------------------

fn small_churn_params() -> ChurnParams {
    ChurnParams {
        horizon_ms: 3_000,
        mean_arrival_ms: 350,
        mean_lifetime_ms: 1_400,
        ..ChurnParams::for_cluster(GenParams {
            nodes: 3,
            pods_per_node: 3,
            priority_tiers: 2,
            usage: 0.9,
        })
    }
}

#[test]
fn replay_reply_streams_are_identical_at_1_and_8_threads() {
    prop::check(
        "serve-thread-determinism",
        0x7D17,
        3,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let trace = ChurnTraceGenerator::new(small_churn_params(), seed).generate();
            let timeout = Duration::from_secs(2);
            let (s1, d1) = replay_reply_stream(&trace, 1, timeout);
            let (s8, d8) = replay_reply_stream(&trace, 8, timeout);
            if s1 != s8 {
                let diverge = s1.iter().zip(&s8).position(|(a, b)| a != b);
                return Err(format!(
                    "reply streams diverge at line {diverge:?} ({} vs {} lines)",
                    s1.len(),
                    s8.len()
                ));
            }
            if d1 != d8 {
                return Err(format!("state digests diverge: {d1:016x} vs {d8:016x}"));
            }
            if stream_fingerprint(&s1) != stream_fingerprint(&s8) {
                return Err("fingerprint disagrees with line equality".to_string());
            }
            Ok(())
        },
    );
}

/// The observability plane must observe, never feed back: arming
/// telemetry, reading the journal, or building watch frames cannot
/// change a single reply byte, and the journal/frame streams themselves
/// are byte-identical across thread counts.
#[test]
fn observability_is_inert_and_thread_deterministic() {
    prop::check(
        "serve-observability-identity",
        0x0B5E7,
        3,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let trace = ChurnTraceGenerator::new(small_churn_params(), seed).generate();
            let timeout = Duration::from_secs(2);
            let off = replay_observed(&trace, 1, timeout, false);
            let armed = replay_observed(&trace, 1, timeout, true);
            let t8 = replay_observed(&trace, 8, timeout, true);
            if off.lines != armed.lines {
                return Err("arming telemetry changed the reply stream".to_string());
            }
            if off.journal != armed.journal {
                return Err("arming telemetry changed the journal".to_string());
            }
            if off.frames != armed.frames {
                return Err("arming telemetry changed the watch frames".to_string());
            }
            if off.digest != armed.digest {
                return Err("arming telemetry changed the end-state digest".to_string());
            }
            if armed.lines != t8.lines {
                return Err("reply stream not thread-deterministic".to_string());
            }
            if armed.journal != t8.journal {
                let diverge = armed.journal.iter().zip(&t8.journal).position(|(a, b)| a != b);
                return Err(format!("journal diverges across threads at entry {diverge:?}"));
            }
            if armed.frames != t8.frames {
                return Err("watch frames not thread-deterministic".to_string());
            }
            if armed.digest != t8.digest {
                return Err("digest not thread-deterministic".to_string());
            }
            if armed.journal.is_empty() || armed.frames.len() != armed.journal.len() {
                return Err(format!(
                    "one frame per journal entry expected: {} frames, {} entries",
                    armed.frames.len(),
                    armed.journal.len()
                ));
            }
            Ok(())
        },
    );
}

// ---- 5. observability plane (live) ---------------------------------------

#[test]
fn watch_subscribers_see_the_same_close_a_polling_client_reconstructs() {
    let handle = spawn_daemon(fig1_engine(50), 64, MAX_LINE_BYTES);
    let mut watcher = Client::connect(handle.addr);
    let ack = watcher.request(&WireRequest::tagged(WireOp::Watch, 1));
    assert_eq!(ack.get("subscribed").and_then(Json::as_bool), Some(true), "{ack}");
    assert_eq!(ack.get("window").and_then(Json::as_i64), Some(0), "stream starts at window 0");

    let mut submitter = Client::connect(handle.addr);
    let r = submitter.request(&WireRequest::tagged(
        WireOp::Submit(SubmitSpec::basic("web", 2, 100, 2048, 0)),
        7,
    ));
    assert_eq!(r.get("op").and_then(Json::as_str), Some("submit"), "{r}");
    let window = r.get("window").and_then(Json::as_i64).expect("window id");

    // The push-mode delta frame for that close arrives on the watch
    // connection, untagged, carrying the journal entry and the digest.
    let frame = watcher.recv();
    assert_eq!(frame.get("frame").and_then(Json::as_str), Some("delta"), "{frame}");
    assert_eq!(frame.get("window").and_then(Json::as_i64), Some(window));
    assert!(tag_of(&frame).is_none(), "frames are push traffic, never tagged");
    let entry = frame.get("entry").expect("journal entry embedded in frame");
    assert_eq!(entry.get("submits").and_then(Json::as_i64), Some(1));
    assert_eq!(entry.get("window").and_then(Json::as_i64), Some(window));

    // A polling client lands on the same digest the frame carried...
    let q = submitter.request(&WireRequest::tagged(WireOp::Query { latency: false }, 8));
    assert_eq!(
        frame.get("digest").and_then(Json::as_str),
        q.get("digest").and_then(Json::as_str),
        "watch and query disagree on the state digest"
    );
    // ...and the journal op returns the exact entry the frame embedded.
    let j = submitter.request(&WireRequest::tagged(
        WireOp::Journal {
            since: None,
            limit: None,
            wall: false,
        },
        9,
    ));
    let entries = j.get("entries").and_then(Json::as_arr).expect("entries");
    assert_eq!(entries.last().expect("at least one entry"), entry);
    assert_eq!(
        j.get("next").and_then(Json::as_i64),
        Some(window + 1),
        "resume cursor points past the newest window: {j}"
    );

    let _ = submitter.request(&WireRequest::new(WireOp::Shutdown));
    handle.join().expect("daemon exits cleanly");
}

#[test]
fn full_admission_queue_sheds_with_structured_overloaded_errors() {
    // max_pending = 0: every request is shed, deterministically — the
    // pure backpressure path with no timing dependence.
    let handle = ServeHandle::spawn(ServeConfig {
        engine: fig1_engine(50),
        max_pending: 0,
        ..ServeConfig::default()
    })
    .expect("daemon binds on loopback");
    let mut c = Client::connect(handle.addr);
    let r = c.request(&WireRequest::tagged(WireOp::Health { latency: false }, 1));
    assert_eq!(error_code(&r), Some("overloaded"), "{r}");
    assert_eq!(r.get("seq"), None, "shed requests never join the interleaving");
    assert_eq!(tag_of(&r), Some(1), "tag still echoed on the rejection");
    // The connection survives shedding; the next probe is also answered.
    let r2 = c.request(&WireRequest::tagged(WireOp::Query { latency: false }, 2));
    assert_eq!(error_code(&r2), Some("overloaded"), "{r2}");
    // A shutdown op would be shed too, so the daemon cannot be drained
    // over the wire here — drop the handle and let the thread die with
    // the test process.
    drop(handle);
}

#[test]
fn explain_covers_every_ready_node_for_an_unplaceable_pod() {
    // Figure-1 variant: node-0 tainted, node-2 RAM-starved, node-1
    // filled by the first window — the victim pod then fits nowhere,
    // each node rejecting for a different reason.
    let mut nodes = identical_nodes(3, Resources::new(4000, 4096));
    nodes[0].taints.push(Taint::no_schedule("dedicated", "infra"));
    nodes[2] = Node::new(2, "node-2", Resources::new(4000, 512));
    let mut engine = Engine::new(EngineConfig {
        p_max: 0,
        nodes,
        reference_capacity: Resources::new(4000, 4096),
        solve_timeout: Duration::from_secs(5),
        ..EngineConfig::default()
    });
    engine.run_window(
        1_000,
        &[WireOp::Submit(SubmitSpec::basic("filler", 1, 100, 3584, 0))],
    );
    let lines = engine.run_window(
        2_000,
        &[WireOp::Submit(SubmitSpec::basic("victim", 1, 100, 3072, 0))],
    );
    let reply = parse(&lines[0]).expect("submit reply parses");
    let placement = reply.get("placements").and_then(Json::as_arr).expect("placements");
    assert!(
        placement[0].get("node").map(|n| *n == Json::Null).unwrap_or(false),
        "victim must be certified unplaceable: {reply}"
    );

    let ex = engine
        .apply(
            50,
            None,
            &WireOp::Explain {
                pod: "victim-0".to_string(),
            },
        )
        .expect("immediate reply");
    assert_eq!(ex.get("status").and_then(Json::as_str), Some("pending"), "{ex}");
    assert_eq!(ex.get("ready_nodes").and_then(Json::as_i64), Some(3));
    assert_eq!(ex.get("feasible").and_then(Json::as_i64), Some(0));
    let reasons = ex.get("reasons").expect("per-module tally");
    assert_eq!(reasons.get("taint").and_then(Json::as_i64), Some(1), "{ex}");
    assert_eq!(
        reasons.get("insufficient-ram").and_then(Json::as_i64),
        Some(2),
        "{ex}"
    );
    assert!(
        ex.get("certificate").and_then(Json::as_str).is_some(),
        "explain must report the window certificate: {ex}"
    );
}

/// The churn config whose Fallback arm the engine window mirrors.
fn equivalence_cfg(threads: usize, timeout: Duration) -> ChurnConfig {
    ChurnConfig {
        policy: Policy::Fallback,
        sweep_every_ms: 0,
        sweep: SweepConfig {
            optimizer: OptimizerConfig::with_timeout(1.0),
            eviction_budget: 8,
        },
        fallback_timeout: timeout,
        fallback_portfolio: PortfolioConfig::with_threads(threads),
        incremental: true,
        autoscale: None,
    }
}

#[test]
fn daemon_engine_matches_run_churn_on_converted_traces() {
    for (seed, profile) in [
        (0xC0FFEE_u64, ConstraintProfile::None),
        (0x0BEE5, ConstraintProfile::AntiAffinity),
    ] {
        let trace = ChurnTraceGenerator::new(small_churn_params(), seed)
            .with_profile(profile)
            .generate();
        let timeout = Duration::from_secs(2);
        let churn = run_churn(&trace, &equivalence_cfg(1, timeout));

        let mut engine = Engine::new(engine_for_trace(&trace, 1, timeout, 1_000));
        for (t, ops) in trace_to_windows(&trace) {
            engine.run_window(t, &ops);
        }

        assert_eq!(
            engine.digest(),
            churn.final_state_digest,
            "daemon and simulator end states diverge (seed {seed:#x}, {profile:?})"
        );
        assert_eq!(engine.state().pending_pods().len(), churn.final_pending, "pending (seed {seed:#x})");
        assert_eq!(
            engine.state().placed_per_priority(trace.p_max),
            churn.final_placed,
            "placement vector (seed {seed:#x})"
        );
        let ready = engine
            .state()
            .nodes()
            .iter()
            .filter(|n| engine.state().node_ready(n.id))
            .count();
        assert_eq!(ready, churn.final_ready_nodes, "ready nodes (seed {seed:#x})");
    }
}
