//! Incremental solve-session integration tests: the PR 3
//! thread-determinism properties extended to sessions.
//!
//! Contract under test: a session re-solve is **byte-identical** to a
//! cold solve of the same state (plans and objective vectors, threads
//! ∈ {1, 8}), and a no-op delta returns the cached certificate without
//! invoking the solver (asserted via the session's solve counters).
//!
//! Same caveat as every determinism test in this repo: identity is
//! guaranteed when every solve completes inside its window, so cases
//! are tiny and deadlines generous.

use kube_packd::cluster::{Pod, PodId, Priority, Resources};
use kube_packd::optimizer::algorithm::{optimize, OptimizeResult, OptimizerConfig};
use kube_packd::optimizer::SolveSession;
use kube_packd::simulator::KwokSimulator;
use kube_packd::util::prop::check;
use kube_packd::workload::{GenParams, Instance};

/// Compare the determinism-relevant surface of two results: the plan,
/// the objective vector, the certificate, and the per-tier metrics.
fn assert_same_result(
    warm: &OptimizeResult,
    cold: &OptimizeResult,
    ctx: &str,
) -> Result<(), String> {
    if warm.target != cold.target {
        return Err(format!("{ctx}: plan diverged"));
    }
    if warm.placed_per_priority != cold.placed_per_priority {
        return Err(format!("{ctx}: objective vector diverged"));
    }
    if warm.proved_optimal != cold.proved_optimal {
        return Err(format!("{ctx}: certificate diverged"));
    }
    let tiers = |r: &OptimizeResult| -> Vec<(i64, i64, i64)> {
        r.tiers
            .iter()
            .map(|t| (t.phase1_placed, t.phase1_bound, t.phase2_metric))
            .collect()
    };
    if tiers(warm) != tiers(cold) {
        return Err(format!("{ctx}: per-tier metrics diverged"));
    }
    Ok(())
}

/// The tentpole property: run a session through (cold solve → churn
/// delta → re-solve) and pin the re-solve byte-identical to a fresh
/// cold solve of the mutated state, at 1 and 8 threads.
#[test]
fn prop_session_resolve_is_byte_identical_to_cold() {
    check(
        "session_resolve_cold_parity",
        0x5E55,
        6,
        |rng| {
            let params = GenParams {
                nodes: rng.range_usize(2, 4),
                pods_per_node: rng.range_usize(2, 3),
                priority_tiers: rng.range_usize(1, 3) as u32,
                usage: 0.9 + rng.f64() * 0.2,
            };
            // The churn delta applied between the two solves: a fresh
            // arrival, sized like the instance's own pods.
            let extra_cpu = rng.range_i64(100, 600);
            let extra_ram = rng.range_i64(100, 600);
            (Instance::generate(params, rng.next_u64()), extra_cpu, extra_ram)
        },
        |(inst, extra_cpu, extra_ram)| {
            let p_max = inst.params.p_max();
            let mut sim = KwokSimulator::new(p_max);
            let (mut state, _) = sim.run(inst.nodes.clone(), inst.pods.clone());

            for threads in [1usize, 8] {
                let cfg = OptimizerConfig::with_timeout(10.0).with_threads(threads);
                let mut session = SolveSession::new();

                // First solve through the session == plain cold solve.
                let first = session.solve(&state, p_max, &cfg);
                let cold_first = optimize(&state, p_max, &cfg);
                match (&first, &cold_first) {
                    (None, None) => {}
                    (Some(w), Some(c)) => {
                        assert_same_result(w, c, &format!("first solve, threads={threads}"))?
                    }
                    _ => return Err(format!("solvability diverged at threads={threads}")),
                }

                // Churn delta: one arrival (and, when possible, one
                // eviction) — then the warm re-solve must equal cold.
                let mut dirty = state.clone();
                dirty.add_pod(Pod::new(
                    0,
                    "arrival",
                    Resources::new(*extra_cpu, *extra_ram),
                    Priority(0),
                ));
                if let Some(pod) = dirty
                    .assignment()
                    .iter()
                    .position(|a| a.is_some())
                    .map(|i| PodId(i as u32))
                {
                    dirty.evict(pod).map_err(|e| e.to_string())?;
                }
                let warm = session.solve(&dirty, p_max, &cfg);
                let cold = optimize(&dirty, p_max, &cfg);
                match (&warm, &cold) {
                    (None, None) => {}
                    (Some(w), Some(c)) => {
                        assert_same_result(w, c, &format!("re-solve, threads={threads}"))?
                    }
                    _ => return Err(format!("re-solvability diverged at threads={threads}")),
                }
                if threads == 1 {
                    state = dirty; // vary the second thread-count's input
                }
            }
            Ok(())
        },
    );
}

/// A no-op delta replays the cached certificate with zero solver
/// invocations, counter-asserted — and the replay is byte-identical.
#[test]
fn noop_delta_returns_cached_certificate_without_solving() {
    use kube_packd::cluster::{identical_nodes, ClusterState, NodeId};

    // Figure 1: tiny, always fully certified under a generous window.
    let nodes = identical_nodes(2, Resources::new(4000, 4096));
    let pods = vec![
        Pod::new(0, "pod-1", Resources::new(10, 2048), Priority(0)),
        Pod::new(1, "pod-2", Resources::new(10, 2048), Priority(0)),
        Pod::new(2, "pod-3", Resources::new(10, 3072), Priority(0)),
    ];
    let mut state = ClusterState::new(nodes, pods);
    state.bind(PodId(0), NodeId(0)).unwrap();
    state.bind(PodId(1), NodeId(1)).unwrap();

    for threads in [1usize, 8] {
        let cfg = OptimizerConfig::with_timeout(10.0).with_threads(threads);
        let mut session = SolveSession::new();
        let first = session.solve(&state, 0, &cfg).expect("figure 1 solves");
        assert!(first.proved_optimal);
        assert_eq!(session.stats.solves, 1);
        assert_eq!(session.stats.optimizer_runs, 1);
        assert_eq!(session.stats.full_hits, 0);

        let replay = session.solve(&state, 0, &cfg).expect("replay");
        assert_eq!(
            session.stats.optimizer_runs, 1,
            "no-op delta must not invoke the solver (threads={threads})"
        );
        assert_eq!(session.stats.full_hits, 1);
        assert_eq!(replay.target, first.target);
        assert_eq!(replay.placed_per_priority, first.placed_per_priority);
        assert!(replay.proved_optimal, "certificate replayed");
        assert_eq!(
            replay.tiers.len(),
            first.tiers.len(),
            "tier reports replay with the certificate"
        );
    }
}

/// Warm-started dirty re-solves actually record reuse: unchanged tier
/// models hit the per-solve cache, and at least one warm-start floor is
/// seeded for the dirty work.
#[test]
fn dirty_resolve_records_cache_hits_and_warm_starts() {
    use kube_packd::cluster::{identical_nodes, ClusterState, NodeId};

    // Two tiers: tier 0 stays untouched across the delta, so its phase
    // solves replay from the per-solve cache even though the state (and
    // tier 1's models) changed.
    let nodes = identical_nodes(2, Resources::new(1000, 1000));
    let pods = vec![
        Pod::new(0, "hi", Resources::new(900, 900), Priority(0)),
        Pod::new(1, "lo-1", Resources::new(400, 400), Priority(1)),
    ];
    let mut state = ClusterState::new(nodes, pods);
    state.bind(PodId(0), NodeId(0)).unwrap();
    state.bind(PodId(1), NodeId(1)).unwrap();

    let cfg = OptimizerConfig::with_timeout(10.0);
    let mut session = SolveSession::new();
    session.solve(&state, 1, &cfg).expect("first solve");
    let hits_before = session.cache_stats().solve_hits;

    // Delta in tier 1 only: a new low-priority arrival.
    state.add_pod(Pod::new(0, "lo-2", Resources::new(400, 400), Priority(1)));
    let warm = session.solve(&state, 1, &cfg).expect("re-solve");
    assert_eq!(session.stats.optimizer_runs, 2, "dirty state re-solves");
    assert!(
        session.cache_stats().solve_hits > hits_before,
        "tier 0's unchanged phase solves must replay from cache"
    );
    assert!(
        session.cache_stats().warm_seeds > 0,
        "dirty solves must seed warm-start floors"
    );

    // And the reused result still matches cold bit for bit.
    let cold = optimize(&state, 1, &cfg).expect("cold solve");
    assert_eq!(warm.target, cold.target);
    assert_eq!(warm.placed_per_priority, cold.placed_per_priority);
}
