//! The admission queue between connection threads and the engine.
//!
//! Connection threads only parse and enqueue; the single engine thread
//! owns all cluster state and drains this queue. The global sequence
//! number is assigned *here*, under the queue lock, which is what makes
//! "a fixed request interleaving" a well-defined object: the seq order
//! IS the interleaving, and every reply downstream is a deterministic
//! function of it (the queue plays the same role the telemetry layer's
//! per-lane child/absorb trick plays for deterministic multi-worker
//! span merging — many producers, one pinned merge order).
//!
//! Malformed requests are enqueued too (as `Err(WireError)`), so error
//! replies flow through the same seq-ordered path as everything else
//! instead of racing it on the connection thread.

use std::collections::VecDeque;
use std::io::Write;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::protocol::{WireError, WireRequest};

/// Where a reply line goes: a shared writer (the connection's socket,
/// or an in-memory buffer in tests). The engine thread writes replies
/// directly through it before retiring the request, so a drained
/// daemon can never exit with an enqueued request unanswered.
pub type ReplySink = Arc<Mutex<dyn Write + Send>>;

/// Write one reply line (compact JSON + newline) to a sink. Write
/// failures are reported, not fatal — a vanished client must not take
/// the daemon down.
pub fn send_line(sink: &ReplySink, line: &str) -> bool {
    let mut w = sink.lock().expect("reply sink lock");
    w.write_all(line.as_bytes()).and_then(|_| w.write_all(b"\n")).and_then(|_| w.flush()).is_ok()
}

/// One enqueued admission operation (or a parse failure to answer).
pub struct Submission {
    /// Global arrival sequence number — assigned under the queue lock,
    /// echoed in the reply.
    pub seq: u64,
    /// Connection id (accept order); used for per-connection telemetry
    /// lanes.
    pub conn: u64,
    /// The parsed request, or the structured parse error to reply with.
    pub request: Result<WireRequest, (WireError, Option<u64>)>,
    pub reply: ReplySink,
}

struct Queue {
    items: VecDeque<Submission>,
    next_seq: u64,
    draining: bool,
}

/// What a blocking pop observed.
pub enum Drained {
    /// Items arrived (possibly after a wait).
    Items(Vec<Submission>),
    /// The wait timed out with the queue still empty.
    TimedOut,
    /// Drain has begun and the queue is empty: no submission will ever
    /// arrive again.
    Empty,
}

/// The outcome of [`Batcher::submit`]. Rejections never join the
/// interleaving, so they carry no seq — the caller answers them on its
/// own connection thread.
pub enum Admit {
    /// Enqueued under this global seq.
    Accepted(u64),
    /// The daemon is draining; answer with [`WireError::Draining`].
    Draining,
    /// The admission queue is at `--max-pending`; answer with
    /// [`WireError::Overloaded`] instead of buffering without bound.
    Overloaded { pending: usize, max: usize },
}

/// Deterministically-sequenced MPSC admission queue, bounded at
/// `max_pending` enqueued-but-undrained requests.
pub struct Batcher {
    q: Mutex<Queue>,
    cv: Condvar,
    max_pending: usize,
}

impl Batcher {
    /// An effectively-unbounded queue (tests, in-process replays).
    pub fn new() -> Arc<Batcher> {
        Batcher::with_max_pending(usize::MAX)
    }

    /// A queue that sheds load past `max_pending` enqueued requests.
    pub fn with_max_pending(max_pending: usize) -> Arc<Batcher> {
        Arc::new(Batcher {
            q: Mutex::new(Queue {
                items: VecDeque::new(),
                next_seq: 0,
                draining: false,
            }),
            cv: Condvar::new(),
            max_pending,
        })
    }

    /// Enqueue a request under the next global seq — or reject it
    /// without sequencing when the daemon is draining or the queue is
    /// full (backpressure: the client gets a structured error now
    /// rather than unbounded buffering under burst).
    pub fn submit(
        &self,
        conn: u64,
        request: Result<WireRequest, (WireError, Option<u64>)>,
        reply: ReplySink,
    ) -> Admit {
        let mut q = self.q.lock().expect("batcher lock");
        if q.draining {
            return Admit::Draining;
        }
        if q.items.len() >= self.max_pending {
            return Admit::Overloaded {
                pending: q.items.len(),
                max: self.max_pending,
            };
        }
        let seq = q.next_seq;
        q.next_seq += 1;
        q.items.push_back(Submission {
            seq,
            conn,
            request,
            reply,
        });
        self.cv.notify_all();
        Admit::Accepted(seq)
    }

    /// Stop accepting new submissions. Already-enqueued requests stay
    /// queued and will all be answered before the engine exits.
    pub fn begin_drain(&self) {
        let mut q = self.q.lock().expect("batcher lock");
        q.draining = true;
        self.cv.notify_all();
    }

    pub fn is_draining(&self) -> bool {
        self.q.lock().expect("batcher lock").draining
    }

    /// Take everything queued, waiting up to `timeout` for the first
    /// item when the queue is empty.
    pub fn pop_all(&self, timeout: Duration) -> Drained {
        let mut q = self.q.lock().expect("batcher lock");
        if q.items.is_empty() {
            if q.draining {
                return Drained::Empty;
            }
            let (guard, res) = self
                .cv
                .wait_timeout_while(q, timeout, |q| q.items.is_empty() && !q.draining)
                .expect("batcher wait");
            q = guard;
            if q.items.is_empty() {
                return if q.draining {
                    Drained::Empty
                } else {
                    debug_assert!(res.timed_out() || !q.items.is_empty());
                    Drained::TimedOut
                };
            }
        }
        Drained::Items(q.items.drain(..).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::protocol::WireOp;

    fn sink() -> ReplySink {
        Arc::new(Mutex::new(Vec::<u8>::new()))
    }

    fn accept(b: &Batcher, op: WireOp) -> u64 {
        match b.submit(0, Ok(WireRequest::new(op)), sink()) {
            Admit::Accepted(seq) => seq,
            _ => panic!("expected acceptance"),
        }
    }

    #[test]
    fn seqs_are_globally_monotonic_from_zero() {
        let b = Batcher::new();
        for want in 0..5u64 {
            assert_eq!(accept(&b, WireOp::Health { latency: false }), want);
        }
        match b.pop_all(Duration::from_millis(10)) {
            Drained::Items(items) => {
                let seqs: Vec<u64> = items.iter().map(|s| s.seq).collect();
                assert_eq!(seqs, vec![0, 1, 2, 3, 4]);
            }
            _ => panic!("expected items"),
        }
    }

    #[test]
    fn drain_rejects_new_but_keeps_queued() {
        let b = Batcher::new();
        accept(&b, WireOp::Query { latency: false });
        b.begin_drain();
        assert!(matches!(
            b.submit(
                0,
                Ok(WireRequest::new(WireOp::Query { latency: false })),
                sink()
            ),
            Admit::Draining
        ));
        // The queued item survives the drain flag...
        match b.pop_all(Duration::from_millis(10)) {
            Drained::Items(items) => assert_eq!(items.len(), 1),
            _ => panic!("queued item must still drain"),
        }
        // ...and once empty, the pop reports terminal emptiness.
        assert!(matches!(b.pop_all(Duration::from_millis(10)), Drained::Empty));
    }

    #[test]
    fn full_queue_sheds_load_and_drains_what_it_took() {
        let b = Batcher::with_max_pending(2);
        accept(&b, WireOp::Health { latency: false });
        accept(&b, WireOp::Health { latency: false });
        match b.submit(
            0,
            Ok(WireRequest::new(WireOp::Health { latency: false })),
            sink(),
        ) {
            Admit::Overloaded { pending, max } => {
                assert_eq!(pending, 2);
                assert_eq!(max, 2);
            }
            _ => panic!("third submit must be shed"),
        }
        // A rejected request never consumed a seq: the interleaving has
        // no gap, and a pop frees capacity again.
        match b.pop_all(Duration::from_millis(10)) {
            Drained::Items(items) => {
                assert_eq!(items.len(), 2);
                assert_eq!(items[1].seq, 1);
            }
            _ => panic!("expected items"),
        }
        assert_eq!(accept(&b, WireOp::Health { latency: false }), 2);
        // Drain still answers everything already enqueued, cap or not.
        b.begin_drain();
        match b.pop_all(Duration::from_millis(10)) {
            Drained::Items(items) => assert_eq!(items.len(), 1),
            _ => panic!("queued item must still drain"),
        }
    }

    #[test]
    fn empty_pop_times_out_when_not_draining() {
        let b = Batcher::new();
        assert!(matches!(
            b.pop_all(Duration::from_millis(5)),
            Drained::TimedOut
        ));
    }
}
