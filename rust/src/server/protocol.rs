//! Newline-delimited JSON wire protocol for `kube-packd serve`.
//!
//! One request per line, one JSON object per line; every reply is one
//! JSON object on one line. No tokio, no gRPC, no serde — the same
//! hand-rolled [`Json`] codec the `datasets` and `solve --json` paths
//! use, over std TCP. Serialisation is canonical (object keys are
//! BTreeMap-ordered, optional fields are omitted when absent), so
//! `op -> json -> text -> parse -> op -> json -> text` is
//! byte-identical — the round-trip contract `rust/tests/server.rs`
//! pins for every op.
//!
//! Requests:
//!
//! ```text
//! {"op":"submit","name":"web","replicas":2,"cpu_milli":500,"ram_mib":512,"priority":0}
//! {"op":"delete","pod":"web-0"}
//! {"op":"join","cpu_milli":4000,"ram_mib":4096}            // or {"op":"join","pool":"large",...}
//! {"op":"drain","node":0}
//! {"op":"remove","node":0}
//! {"op":"query"} {"op":"health"} {"op":"metrics"} {"op":"trace_export"} {"op":"profile"} {"op":"shutdown"}
//! ```
//!
//! Every request may carry `"tag": N` — an opaque client correlation id
//! echoed verbatim in the reply (load generators match latencies by
//! tag; the server never interprets it). Replies additionally carry
//! `"seq"`, the server-assigned global arrival sequence number: replies
//! are a deterministic function of the seq-ordered request interleaving
//! at any `--threads` count.
//!
//! Malformed input — bad JSON, an unknown `op`, a wrong-typed field, or
//! an oversized line — produces a structured `{"error":{"code":...,
//! "message":...}}` reply and leaves the connection alive.

use crate::cluster::{ReplicaSet, Toleration};
use crate::util::json::{parse, Json};
use crate::workload::churn::{ChurnTrace, TraceOp};

/// Default per-line byte cap. A line longer than this is answered with
/// an `oversized` error and discarded without unbounded buffering.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// Wire protocol version, reported by `health`.
pub const PROTOCOL_VERSION: u64 = 1;

/// Everything that can go wrong between the socket and a valid
/// [`WireOp`]. Each variant maps to a stable `code` string on the wire.
#[derive(Clone, Debug, PartialEq)]
pub enum WireError {
    /// The line is not valid JSON.
    BadJson(String),
    /// Valid JSON, but `op` is missing or names no known operation.
    UnknownOp(String),
    /// Known op with a missing, wrong-typed, or out-of-range field.
    BadRequest(String),
    /// The line exceeded the per-line byte cap.
    Oversized { got: usize, max: usize },
    /// The daemon is draining: no new requests are accepted.
    Draining,
    /// The admission queue is full (`--max-pending`): shed load instead
    /// of buffering without bound. Retry after a window closes.
    Overloaded { pending: usize, max: usize },
}

impl WireError {
    pub fn code(&self) -> &'static str {
        match self {
            WireError::BadJson(_) => "bad-json",
            WireError::UnknownOp(_) => "unknown-op",
            WireError::BadRequest(_) => "bad-request",
            WireError::Oversized { .. } => "oversized",
            WireError::Draining => "draining",
            WireError::Overloaded { .. } => "overloaded",
        }
    }

    pub fn message(&self) -> String {
        match self {
            WireError::BadJson(m) => format!("invalid JSON: {m}"),
            WireError::UnknownOp(op) => format!("unknown op {op:?}"),
            WireError::BadRequest(m) => m.clone(),
            WireError::Oversized { got, max } => {
                format!("line of {got} bytes exceeds the {max}-byte cap")
            }
            WireError::Draining => "daemon is draining; request rejected".to_string(),
            WireError::Overloaded { pending, max } => {
                format!("admission queue full ({pending} pending, max {max}); retry after a window")
            }
        }
    }

    /// The structured error reply for this failure, carrying whatever
    /// identifiers are known (`seq` is absent when the request was
    /// rejected before sequencing, e.g. during drain).
    pub fn reply(&self, seq: Option<u64>, tag: Option<u64>) -> Json {
        let mut err = Json::obj();
        err.set("code", self.code()).set("message", self.message());
        let mut o = Json::obj();
        if let Some(s) = seq {
            o.set("seq", s);
        }
        if let Some(t) = tag {
            o.set("tag", t);
        }
        o.set("error", err);
        o
    }
}

/// A `submit` payload: one ReplicaSet-shaped admission request. The
/// optional constraint fields mirror the [`ReplicaSet`] template
/// vocabulary so churn traces convert losslessly (see
/// [`trace_to_windows`]).
#[derive(Clone, Debug, PartialEq)]
pub struct SubmitSpec {
    /// Explicit ReplicaSet identity. `Some` scales the known set (or
    /// registers the template under that id); `None` resolves by name,
    /// falling back to a server-assigned id.
    pub rs_id: Option<u32>,
    pub name: String,
    pub replicas: u32,
    pub cpu_milli: i64,
    pub ram_mib: i64,
    pub priority: u32,
    pub labels: Vec<(String, String)>,
    pub tolerations: Vec<Toleration>,
    pub anti_affinity: Vec<(String, String)>,
    pub spread_max_skew: Option<i64>,
    pub extended: Vec<(String, i64)>,
}

impl SubmitSpec {
    /// Minimal spec (no constraint fields) — the common client case.
    pub fn basic(name: &str, replicas: u32, cpu_milli: i64, ram_mib: i64, priority: u32) -> Self {
        SubmitSpec {
            rs_id: None,
            name: name.to_string(),
            replicas,
            cpu_milli,
            ram_mib,
            priority,
            labels: Vec::new(),
            tolerations: Vec::new(),
            anti_affinity: Vec::new(),
            spread_max_skew: None,
            extended: Vec::new(),
        }
    }

    /// Capture a trace ReplicaSet template (with an explicit replica
    /// count — trace `Scale` ops reuse the template at a delta count).
    pub fn from_replicaset(rs: &ReplicaSet, replicas: u32) -> Self {
        SubmitSpec {
            rs_id: Some(rs.id),
            name: rs.name.clone(),
            replicas,
            cpu_milli: rs.template_request.cpu,
            ram_mib: rs.template_request.ram,
            priority: rs.priority.0,
            labels: rs.labels.clone(),
            tolerations: rs.tolerations.clone(),
            anti_affinity: rs.anti_affinity.clone(),
            spread_max_skew: rs.spread_max_skew,
            extended: rs.extended.clone(),
        }
    }

    /// Materialise the template this spec describes, under a resolved
    /// dense id. The engine's single instantiation path — replicas are
    /// stamped via [`ReplicaSet::instantiate`], exactly like the churn
    /// simulator's.
    pub fn to_replicaset(&self, id: u32) -> ReplicaSet {
        let mut rs = ReplicaSet::new(
            id,
            self.name.clone(),
            self.replicas,
            crate::cluster::Resources::new(self.cpu_milli, self.ram_mib),
            crate::cluster::Priority(self.priority),
        );
        rs.labels = self.labels.clone();
        rs.tolerations = self.tolerations.clone();
        rs.anti_affinity = self.anti_affinity.clone();
        rs.spread_max_skew = self.spread_max_skew;
        rs.extended = self.extended.clone();
        rs
    }
}

/// One admission operation on the wire.
#[derive(Clone, Debug, PartialEq)]
pub enum WireOp {
    /// Admit `replicas` pods from a ReplicaSet template; the reply is
    /// deferred to the enclosing solve window and carries placements +
    /// the window certificate.
    Submit(SubmitSpec),
    /// Terminate a pod by name (replies immediately).
    Delete { pod: String },
    /// Join a node: plain capacity, or a pool preset (`small` | `large`
    /// | `gpu`) decorated with the pool's labels/taints/extended
    /// capacities. Capacity defaults to the pool's scale of the
    /// daemon's reference capacity when omitted.
    Join {
        pool: Option<String>,
        cpu_milli: Option<i64>,
        ram_mib: Option<i64>,
    },
    /// Drain a ready node by index: evictees return to pending and are
    /// re-placed in the next window.
    Drain { node: u32 },
    /// Remove a drained/cordoned node by index.
    Remove { node: u32 },
    /// Cluster snapshot: placements per tier, pending, utilisation, and
    /// the solve-relevant state fingerprint. `latency: true` opts into
    /// a wall-clock p50/p95/p99 latency summary — non-canonical, like
    /// the journal's `wall` flag.
    Query { latency: bool },
    /// Liveness + protocol version + drain status (same optional
    /// latency summary as `query`).
    Health { latency: bool },
    /// Live Prometheus text exposition of the daemon's counters.
    Metrics,
    /// Live Chrome-trace JSON export of the daemon's spans.
    TraceExport,
    /// Page through the window-close event journal. `since` is a
    /// start-from window-id cursor (entries with `window >= since` are
    /// returned; pass the previous reply's `next` to resume; omitted
    /// means everything retained); `limit` caps the page;
    /// `wall` opts into the wall-clock timing fields, which live
    /// outside the determinism boundary and are omitted by default.
    Journal {
        since: Option<u64>,
        limit: Option<u64>,
        wall: bool,
    },
    /// Subscribe this connection to push-mode delta frames on every
    /// window close (journal entry + state digest). Frames carry no
    /// `tag`/`seq`; a `lagged` frame replaces frames dropped past the
    /// per-subscriber queue bound.
    Watch,
    /// Explain why a pod is (still) pending: per-ready-node rejection
    /// tally across the constraint modules, plus the latest window
    /// certificate.
    Explain { pod: String },
    /// Solve forensics for the most recent solve window: the
    /// `kube-packd/profile/v1` document (per-constraint-module effort,
    /// decision-indexed gap timeline, folded stacks) plus the window id
    /// it profiles. Deterministic — nothing wall-clock-indexed.
    Profile,
    /// Begin graceful drain: finish the in-flight window, answer every
    /// already-enqueued request, flush telemetry exports, exit 0.
    Shutdown,
}

impl WireOp {
    /// Stable op name on the wire.
    pub fn name(&self) -> &'static str {
        match self {
            WireOp::Submit(_) => "submit",
            WireOp::Delete { .. } => "delete",
            WireOp::Join { .. } => "join",
            WireOp::Drain { .. } => "drain",
            WireOp::Remove { .. } => "remove",
            WireOp::Query { .. } => "query",
            WireOp::Health { .. } => "health",
            WireOp::Metrics => "metrics",
            WireOp::TraceExport => "trace_export",
            WireOp::Journal { .. } => "journal",
            WireOp::Watch => "watch",
            WireOp::Explain { .. } => "explain",
            WireOp::Profile => "profile",
            WireOp::Shutdown => "shutdown",
        }
    }

    /// Canonical JSON form (the exact bytes a round-trip must preserve).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("op", self.name());
        match self {
            WireOp::Submit(s) => {
                if let Some(id) = s.rs_id {
                    o.set("rs_id", id);
                }
                o.set("name", s.name.as_str())
                    .set("replicas", s.replicas)
                    .set("cpu_milli", s.cpu_milli)
                    .set("ram_mib", s.ram_mib)
                    .set("priority", s.priority);
                if !s.labels.is_empty() {
                    o.set("labels", pairs_to_json(&s.labels));
                }
                if !s.tolerations.is_empty() {
                    let tols = s
                        .tolerations
                        .iter()
                        .map(|t| {
                            let mut tj = Json::obj();
                            tj.set("key", t.key.as_str());
                            if let Some(v) = &t.value {
                                tj.set("value", v.as_str());
                            }
                            tj
                        })
                        .collect();
                    o.set("tolerations", Json::Arr(tols));
                }
                if !s.anti_affinity.is_empty() {
                    o.set("anti_affinity", pairs_to_json(&s.anti_affinity));
                }
                if let Some(skew) = s.spread_max_skew {
                    o.set("spread_max_skew", skew);
                }
                if !s.extended.is_empty() {
                    let ext = s
                        .extended
                        .iter()
                        .map(|(k, v)| Json::Arr(vec![Json::Str(k.clone()), Json::from(*v)]))
                        .collect();
                    o.set("extended", Json::Arr(ext));
                }
            }
            WireOp::Delete { pod } => {
                o.set("pod", pod.as_str());
            }
            WireOp::Join {
                pool,
                cpu_milli,
                ram_mib,
            } => {
                if let Some(p) = pool {
                    o.set("pool", p.as_str());
                }
                if let Some(c) = cpu_milli {
                    o.set("cpu_milli", *c);
                }
                if let Some(r) = ram_mib {
                    o.set("ram_mib", *r);
                }
            }
            WireOp::Drain { node } | WireOp::Remove { node } => {
                o.set("node", *node);
            }
            WireOp::Journal { since, limit, wall } => {
                if let Some(s) = since {
                    o.set("since", *s);
                }
                if let Some(l) = limit {
                    o.set("limit", *l);
                }
                if *wall {
                    o.set("wall", true);
                }
            }
            WireOp::Explain { pod } => {
                o.set("pod", pod.as_str());
            }
            WireOp::Query { latency } | WireOp::Health { latency } => {
                if *latency {
                    o.set("latency", true);
                }
            }
            WireOp::Metrics
            | WireOp::TraceExport
            | WireOp::Watch
            | WireOp::Profile
            | WireOp::Shutdown => {}
        }
        o
    }

    /// Parse a request object (sans `tag`, which [`WireRequest`] owns).
    pub fn from_json(j: &Json) -> Result<WireOp, WireError> {
        let op = j
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| WireError::BadRequest("missing string field 'op'".into()))?;
        match op {
            "submit" => Ok(WireOp::Submit(submit_from_json(j)?)),
            "delete" => Ok(WireOp::Delete {
                pod: req_str(j, "pod")?,
            }),
            "join" => {
                let pool = match j.get("pool") {
                    None => None,
                    Some(v) => Some(
                        v.as_str()
                            .ok_or_else(|| bad("field 'pool' must be a string"))?
                            .to_string(),
                    ),
                };
                let cpu_milli = opt_i64(j, "cpu_milli")?;
                let ram_mib = opt_i64(j, "ram_mib")?;
                if pool.is_none() && (cpu_milli.is_none() || ram_mib.is_none()) {
                    return Err(bad("join wants a 'pool' or both 'cpu_milli' and 'ram_mib'"));
                }
                Ok(WireOp::Join {
                    pool,
                    cpu_milli,
                    ram_mib,
                })
            }
            "drain" => Ok(WireOp::Drain {
                node: req_u32(j, "node")?,
            }),
            "remove" => Ok(WireOp::Remove {
                node: req_u32(j, "node")?,
            }),
            "query" => Ok(WireOp::Query {
                latency: opt_bool(j, "latency")?.unwrap_or(false),
            }),
            "health" => Ok(WireOp::Health {
                latency: opt_bool(j, "latency")?.unwrap_or(false),
            }),
            "metrics" => Ok(WireOp::Metrics),
            "trace_export" => Ok(WireOp::TraceExport),
            "journal" => Ok(WireOp::Journal {
                since: opt_u64(j, "since")?,
                limit: opt_u64(j, "limit")?,
                wall: opt_bool(j, "wall")?.unwrap_or(false),
            }),
            "watch" => Ok(WireOp::Watch),
            "explain" => Ok(WireOp::Explain {
                pod: req_str(j, "pod")?,
            }),
            "profile" => Ok(WireOp::Profile),
            "shutdown" => Ok(WireOp::Shutdown),
            other => Err(WireError::UnknownOp(other.to_string())),
        }
    }
}

/// A parsed request: the operation plus the client's optional opaque
/// correlation tag (echoed in the reply, never interpreted).
#[derive(Clone, Debug, PartialEq)]
pub struct WireRequest {
    pub op: WireOp,
    pub tag: Option<u64>,
}

impl WireRequest {
    pub fn new(op: WireOp) -> Self {
        WireRequest { op, tag: None }
    }

    pub fn tagged(op: WireOp, tag: u64) -> Self {
        WireRequest { op, tag: Some(tag) }
    }

    pub fn to_json(&self) -> Json {
        let mut o = self.op.to_json();
        if let Some(t) = self.tag {
            o.set("tag", t);
        }
        o
    }

    /// One wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string_compact()
    }

    pub fn from_json(j: &Json) -> Result<WireRequest, WireError> {
        let tag = match j.get("tag") {
            None => None,
            Some(v) => Some(
                v.as_i64()
                    .filter(|t| *t >= 0)
                    .map(|t| t as u64)
                    .ok_or_else(|| bad("field 'tag' must be a non-negative integer"))?,
            ),
        };
        Ok(WireRequest {
            op: WireOp::from_json(j)?,
            tag,
        })
    }
}

/// Parse one wire line into a request, enforcing the byte cap. On
/// `BadJson`/`BadRequest` failures the tag is still recovered when the
/// line parses as JSON, so the error reply can carry it.
pub fn parse_request(line: &str, max_bytes: usize) -> Result<WireRequest, (WireError, Option<u64>)> {
    if line.len() > max_bytes {
        return Err((
            WireError::Oversized {
                got: line.len(),
                max: max_bytes,
            },
            None,
        ));
    }
    let j = parse(line).map_err(|e| (WireError::BadJson(format!("{e}")), None))?;
    let tag = j.get("tag").and_then(Json::as_i64).filter(|t| *t >= 0).map(|t| t as u64);
    WireRequest::from_json(&j).map_err(|e| (e, tag))
}

fn bad(msg: &str) -> WireError {
    WireError::BadRequest(msg.to_string())
}

fn req_str(j: &Json, key: &str) -> Result<String, WireError> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| bad(&format!("missing string field '{key}'")))
}

fn req_i64(j: &Json, key: &str) -> Result<i64, WireError> {
    j.get(key)
        .and_then(Json::as_i64)
        .ok_or_else(|| bad(&format!("missing integer field '{key}'")))
}

fn opt_i64(j: &Json, key: &str) -> Result<Option<i64>, WireError> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_i64()
            .map(Some)
            .ok_or_else(|| bad(&format!("field '{key}' must be an integer"))),
    }
}

fn opt_u64(j: &Json, key: &str) -> Result<Option<u64>, WireError> {
    match opt_i64(j, key)? {
        None => Ok(None),
        Some(v) => u64::try_from(v)
            .map(Some)
            .map_err(|_| bad(&format!("field '{key}' must be non-negative"))),
    }
}

fn opt_bool(j: &Json, key: &str) -> Result<Option<bool>, WireError> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_bool()
            .map(Some)
            .ok_or_else(|| bad(&format!("field '{key}' must be a boolean"))),
    }
}

fn req_u32(j: &Json, key: &str) -> Result<u32, WireError> {
    let v = req_i64(j, key)?;
    u32::try_from(v).map_err(|_| bad(&format!("field '{key}' out of range: {v}")))
}

fn pairs_to_json(pairs: &[(String, String)]) -> Json {
    Json::Arr(
        pairs
            .iter()
            .map(|(k, v)| Json::Arr(vec![Json::Str(k.clone()), Json::Str(v.clone())]))
            .collect(),
    )
}

fn pairs_from_json(j: &Json, key: &str) -> Result<Vec<(String, String)>, WireError> {
    let Some(v) = j.get(key) else {
        return Ok(Vec::new());
    };
    let arr = v
        .as_arr()
        .ok_or_else(|| bad(&format!("field '{key}' must be an array of [key, value] pairs")))?;
    arr.iter()
        .map(|item| {
            let pair = item.as_arr().filter(|p| p.len() == 2);
            match pair {
                Some(p) => match (p[0].as_str(), p[1].as_str()) {
                    (Some(k), Some(v)) => Ok((k.to_string(), v.to_string())),
                    _ => Err(bad(&format!("'{key}' entries must be string pairs"))),
                },
                None => Err(bad(&format!("'{key}' entries must be [key, value] pairs"))),
            }
        })
        .collect()
}

fn submit_from_json(j: &Json) -> Result<SubmitSpec, WireError> {
    let rs_id = match j.get("rs_id") {
        None => None,
        Some(v) => Some(
            v.as_i64()
                .and_then(|x| u32::try_from(x).ok())
                .ok_or_else(|| bad("field 'rs_id' must be a non-negative integer"))?,
        ),
    };
    let replicas = req_u32(j, "replicas")?;
    if replicas == 0 {
        return Err(bad("'replicas' must be at least 1"));
    }
    let cpu_milli = req_i64(j, "cpu_milli")?;
    let ram_mib = req_i64(j, "ram_mib")?;
    if cpu_milli < 0 || ram_mib < 0 {
        return Err(bad("resource requests must be non-negative"));
    }
    let tolerations = match j.get("tolerations") {
        None => Vec::new(),
        Some(v) => {
            let arr = v
                .as_arr()
                .ok_or_else(|| bad("field 'tolerations' must be an array"))?;
            arr.iter()
                .map(|t| {
                    let key = req_str(t, "key")?;
                    let value = match t.get("value") {
                        None => None,
                        Some(v) => Some(
                            v.as_str()
                                .map(str::to_string)
                                .ok_or_else(|| bad("toleration 'value' must be a string"))?,
                        ),
                    };
                    Ok(Toleration { key, value })
                })
                .collect::<Result<Vec<_>, WireError>>()?
        }
    };
    let extended = match j.get("extended") {
        None => Vec::new(),
        Some(v) => {
            let arr = v
                .as_arr()
                .ok_or_else(|| bad("field 'extended' must be an array of [name, amount] pairs"))?;
            arr.iter()
                .map(|item| {
                    let p = item.as_arr().filter(|p| p.len() == 2);
                    match p {
                        Some(p) => match (p[0].as_str(), p[1].as_i64()) {
                            (Some(k), Some(v)) if v > 0 => Ok((k.to_string(), v)),
                            _ => Err(bad("'extended' entries must be [name, positive amount]")),
                        },
                        None => Err(bad("'extended' entries must be [name, amount] pairs")),
                    }
                })
                .collect::<Result<Vec<_>, WireError>>()?
        }
    };
    Ok(SubmitSpec {
        rs_id,
        name: req_str(j, "name")?,
        replicas,
        cpu_milli,
        ram_mib,
        priority: req_u32(j, "priority")?,
        labels: pairs_from_json(j, "labels")?,
        tolerations,
        anti_affinity: pairs_from_json(j, "anti_affinity")?,
        spread_max_skew: opt_i64(j, "spread_max_skew")?,
        extended,
    })
}

// ---- trace ops ⇄ wire ops ---------------------------------------------------

/// Expand a seeded [`ChurnTrace`] into per-tick wire-op windows: the
/// daemon-side equivalent of feeding the trace to the lifecycle
/// simulator under [`Policy::Fallback`]. Each `(tick, ops)` window maps
/// to one engine solve window; replaying them through
/// [`Engine::run_window`] must land in the same final
/// [`ClusterState`] fingerprint as `run_churn` — the daemon ⇄ simulator
/// equivalence `rust/tests/server.rs` pins.
///
/// The conversion mirrors the churn runner's semantics exactly:
///
/// * `Deploy`/`Scale(+)` become `submit` ops; pod lifetimes become
///   `delete` ops at the completion tick (the daemon has no virtual
///   clock, so completions must arrive as explicit requests).
/// * `Scale(-)` terminates the newest still-live replicas — matching
///   the runner's "newest first, skip retired" downscale — as `delete`
///   ops in the scale's own position.
/// * Deletes for pods a scale-down already terminated are still
///   emitted (the engine answers `deleted:false`), because the runner
///   processes the completion event tick anyway — and runs a
///   scheduling round there, which the replay must reproduce.
/// * Events past the horizon never fire; the converter drops them.
///
/// [`Policy::Fallback`]: crate::lifecycle::Policy::Fallback
/// [`Engine::run_window`]: super::engine::Engine::run_window
pub fn trace_to_windows(trace: &ChurnTrace) -> Vec<(u64, Vec<WireOp>)> {
    use std::collections::BTreeMap;

    struct Replica {
        name: String,
        completes_at: u64,
        spawn_seq: u64,
    }
    let horizon = trace.params.horizon_ms;
    // Tick -> trace-derived ops, in trace order (the runner's insertion
    // order: all trace ops are scheduled before any completion).
    let mut windows: BTreeMap<u64, Vec<WireOp>> = BTreeMap::new();
    // Completion tick -> (spawn seq, pod name): appended after the
    // trace ops of the same tick, in spawn order — exactly the
    // timeline's insertion-sequence tie-break.
    let mut completions: BTreeMap<u64, Vec<(u64, String)>> = BTreeMap::new();
    let mut catalog: BTreeMap<u32, ReplicaSet> = BTreeMap::new();
    let mut next_ord: BTreeMap<u32, u32> = BTreeMap::new();
    let mut live: BTreeMap<u32, Vec<Replica>> = BTreeMap::new();
    let mut spawn_seq = 0u64;

    let mut spawn = |rs_id: u32,
                     at: u64,
                     lifetime_ms: u64,
                     catalog: &BTreeMap<u32, ReplicaSet>,
                     next_ord: &mut BTreeMap<u32, u32>,
                     live: &mut BTreeMap<u32, Vec<Replica>>,
                     completions: &mut BTreeMap<u64, Vec<(u64, String)>>| {
        // detlint: allow(panic-on-wire) — offline trace expansion, not a
        // connection path; every spawn references a catalogued ReplicaSet.
        let rs = catalog.get(&rs_id).expect("catalogued rs");
        let ord = next_ord.entry(rs_id).or_insert(0);
        let name = format!("{}-{}", rs.name, *ord);
        *ord += 1;
        let completes_at = at + lifetime_ms;
        if completes_at <= horizon {
            completions
                .entry(completes_at)
                .or_default()
                .push((spawn_seq, name.clone()));
        }
        live.entry(rs_id).or_default().push(Replica {
            name,
            completes_at,
            spawn_seq,
        });
        spawn_seq += 1;
    };

    for (t, op) in &trace.ops {
        let t = *t;
        if t > horizon {
            continue; // the runner's hard horizon cut
        }
        let ops = windows.entry(t).or_default();
        match op {
            TraceOp::Deploy { rs, lifetimes_ms } => {
                catalog.insert(rs.id, rs.clone());
                ops.push(WireOp::Submit(SubmitSpec::from_replicaset(
                    rs,
                    lifetimes_ms.len() as u32,
                )));
                for &life in lifetimes_ms {
                    spawn(rs.id, t, life, &catalog, &mut next_ord, &mut live, &mut completions);
                }
            }
            TraceOp::Scale {
                rs,
                delta,
                lifetimes_ms,
            } => {
                let Some(template) = catalog.get(rs).cloned() else {
                    continue; // unknown set: the runner logs a skip (tick still rounds)
                };
                if *delta >= 0 {
                    ops.push(WireOp::Submit(SubmitSpec::from_replicaset(
                        &template,
                        lifetimes_ms.len() as u32,
                    )));
                    for &life in lifetimes_ms {
                        spawn(*rs, t, life, &catalog, &mut next_ord, &mut live, &mut completions);
                    }
                } else {
                    // Newest first; a replica whose completion already
                    // fired (strictly before this tick — same-tick
                    // completions apply *after* trace ops) is skipped
                    // without counting, like the runner's retired check.
                    let mut want = (-*delta) as usize;
                    let stack = live.entry(*rs).or_default();
                    while want > 0 {
                        let Some(r) = stack.pop() else { break };
                        if r.completes_at < t {
                            continue;
                        }
                        ops.push(WireOp::Delete { pod: r.name });
                        want -= 1;
                    }
                }
            }
            TraceOp::Drain { node } => ops.push(WireOp::Drain { node: *node }),
            TraceOp::Join { capacity, pool } => ops.push(WireOp::Join {
                pool: pool.as_ref().map(|p| p.name.clone()),
                cpu_milli: Some(capacity.cpu),
                ram_mib: Some(capacity.ram),
            }),
        }
    }
    for (t, mut deletes) in completions {
        deletes.sort_by_key(|(seq, _)| *seq);
        let ops = windows.entry(t).or_default();
        ops.extend(deletes.into_iter().map(|(_, name)| WireOp::Delete { pod: name }));
    }
    windows.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::churn::{ChurnParams, ChurnTraceGenerator};
    use crate::workload::GenParams;

    #[test]
    fn parse_rejects_garbage_and_unknown_ops() {
        assert!(matches!(
            parse_request("{nope", MAX_LINE_BYTES),
            Err((WireError::BadJson(_), None))
        ));
        assert!(matches!(
            parse_request("{\"op\":\"fly\"}", MAX_LINE_BYTES),
            Err((WireError::UnknownOp(_), None))
        ));
        assert!(matches!(
            parse_request("{\"op\":\"drain\"}", MAX_LINE_BYTES),
            Err((WireError::BadRequest(_), None))
        ));
        // Tag is recovered even when the op is broken.
        assert!(matches!(
            parse_request("{\"op\":\"drain\",\"tag\":7}", MAX_LINE_BYTES),
            Err((WireError::BadRequest(_), Some(7)))
        ));
        let oversized = format!("{{\"op\":\"health\",\"pad\":\"{}\"}}", "x".repeat(64));
        assert!(matches!(
            parse_request(&oversized, 16),
            Err((WireError::Oversized { .. }, None))
        ));
    }

    #[test]
    fn error_replies_are_structured() {
        let r = WireError::UnknownOp("fly".into()).reply(Some(3), Some(9));
        assert_eq!(r.get("seq").and_then(Json::as_i64), Some(3));
        assert_eq!(r.get("tag").and_then(Json::as_i64), Some(9));
        let e = r.get("error").expect("error object");
        assert_eq!(e.get("code").and_then(Json::as_str), Some("unknown-op"));
    }

    #[test]
    fn trace_windows_are_tick_ordered_and_inside_horizon() {
        let trace = ChurnTraceGenerator::new(
            ChurnParams {
                horizon_ms: 5_000,
                mean_arrival_ms: 300,
                mean_lifetime_ms: 1_200,
                ..ChurnParams::for_cluster(GenParams {
                    nodes: 3,
                    pods_per_node: 3,
                    priority_tiers: 2,
                    usage: 0.9,
                })
            },
            11,
        )
        .generate();
        let windows = trace_to_windows(&trace);
        assert!(!windows.is_empty());
        let mut prev = None;
        let mut submits = 0usize;
        let mut deletes = 0usize;
        for (t, ops) in &windows {
            assert!(*t <= trace.params.horizon_ms);
            if let Some(p) = prev {
                assert!(*t > p, "windows must be strictly tick-ordered");
            }
            prev = Some(*t);
            for op in ops {
                match op {
                    WireOp::Submit(_) => submits += 1,
                    WireOp::Delete { .. } => deletes += 1,
                    _ => {}
                }
            }
        }
        assert!(submits > 0, "trace must produce admissions");
        assert!(deletes > 0, "lifetimes inside the horizon must convert to deletes");
    }
}
