//! Scheduler-as-a-service: the `kube-packd serve` daemon.
//!
//! The paper deploys the CP optimiser as a plug-in inside a live
//! scheduler; this module is that deployment shape for the crate — a
//! long-lived daemon that owns a [`ClusterState`] + persistent
//! [`SolveSession`] and admits a concurrent request stream over
//! newline-delimited JSON on std TCP (no tokio, no gRPC, no serde;
//! the crate's hand-rolled [`Json`] codec end to end).
//!
//! Architecture — three kinds of thread, one owner of truth:
//!
//! * **Connection readers** (one per accepted socket) frame lines under
//!   a byte cap, parse them, and enqueue into the [`Batcher`]. They own
//!   nothing and decide nothing; even parse errors are enqueued so the
//!   error replies join the global order.
//! * **The serve loop** (the thread that called [`serve`]) is the
//!   single engine thread: it accepts connections, drains the batcher
//!   in seq order, applies ops to the [`Engine`], and writes every
//!   reply line itself. Because one thread owns state, session,
//!   telemetry, and reply emission, replies are a deterministic
//!   function of the seq interleaving at any `--threads` count.
//! * **Solver workers** live inside the portfolio for the duration of
//!   one window solve, exactly as in batch mode.
//!
//! Admission is windowed per the paper's scheduling-window framing:
//! `submit` requests are deferred and answered together when the window
//! closes — after `--window-ms` of wall time (default 1000), early when
//! `--max-batch` submits have gathered, or immediately at drain. Each
//! close advances the daemon's *virtual* clock by `window_ms`; replies
//! carry window ordinals and virtual time only, never wall-clock, so a
//! fixed request interleaving yields byte-identical reply streams.
//!
//! Graceful shutdown: `{"op":"shutdown"}` or SIGINT stops admission
//! (late requests get a structured `draining` error), finishes the
//! in-flight window so every enqueued request is answered, flushes the
//! `--trace`/`--metrics` telemetry exports, and returns cleanly.
//!
//! [`ClusterState`]: crate::cluster::ClusterState
//! [`SolveSession`]: crate::optimizer::session::SolveSession
//! [`Json`]: crate::util::json::Json

pub mod batcher;
pub mod engine;
pub mod journal;
pub mod loadgen;
pub mod protocol;

use std::collections::BTreeMap;
use std::io::{self, BufRead, BufReader};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::telemetry::Telemetry;

use batcher::{send_line, Admit, Batcher, Drained, ReplySink};
use engine::{Engine, EngineConfig};
use journal::{WatchHub, WATCH_QUEUE_CAP};
use protocol::{parse_request, WireError, WireOp, MAX_LINE_BYTES};

/// How often the serve loop wakes to poll for new connections and the
/// SIGINT flag when no window deadline is nearer.
const POLL: Duration = Duration::from_millis(50);

/// Everything `kube-packd serve` needs beyond the engine knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:7878` (port 0 picks an ephemeral
    /// port — [`ServeHandle::spawn`] reports the resolved address).
    pub addr: String,
    /// Close the open window early once this many `submit` requests
    /// have gathered.
    pub max_batch: usize,
    /// Per-line byte cap on the wire.
    pub max_line_bytes: usize,
    /// Admission-queue bound: past this many enqueued-but-undrained
    /// requests, new ones are shed with a structured `overloaded` error
    /// instead of buffering without bound (`--max-pending`).
    pub max_pending: usize,
    /// Engine knobs (fleet, tiers, solve budget, `window_ms`, ...).
    pub engine: EngineConfig,
    /// Record spans/counters (on by default so live `metrics` /
    /// `trace_export` requests have substance).
    pub telemetry: bool,
    /// Write the Chrome trace export here at shutdown.
    pub trace_out: Option<String>,
    /// Write the Prometheus text exposition here at shutdown.
    pub metrics_out: Option<String>,
    /// Install the process SIGINT handler (the CLI does; in-process
    /// tests and benches don't).
    pub install_sigint: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_batch: 64,
            max_line_bytes: MAX_LINE_BYTES,
            max_pending: 4096,
            engine: EngineConfig::default(),
            telemetry: true,
            trace_out: None,
            metrics_out: None,
            install_sigint: false,
        }
    }
}

/// A daemon running on a background thread (tests and the load
/// generator drive it over loopback).
pub struct ServeHandle {
    /// The resolved bind address (meaningful when the config asked for
    /// port 0).
    pub addr: SocketAddr,
    join: thread::JoinHandle<io::Result<()>>,
}

impl ServeHandle {
    /// Bind synchronously (so the caller can connect immediately), then
    /// run the serve loop on a background thread.
    pub fn spawn(cfg: ServeConfig) -> io::Result<ServeHandle> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let join = thread::Builder::new()
            .name("kube-packd-serve".to_string())
            .spawn(move || serve_loop(listener, cfg))?;
        Ok(ServeHandle { addr, join })
    }

    /// Wait for the daemon to drain and exit.
    pub fn join(self) -> io::Result<()> {
        self.join.join().unwrap_or_else(|_| {
            Err(io::Error::other("serve thread panicked"))
        })
    }
}

/// Run the daemon on the calling thread until it drains (the CLI
/// entrypoint). Returns once every enqueued request has been answered
/// and telemetry exports are flushed.
pub fn serve(cfg: ServeConfig) -> io::Result<()> {
    let listener = TcpListener::bind(&cfg.addr)?;
    serve_loop(listener, cfg)
}

fn serve_loop(listener: TcpListener, cfg: ServeConfig) -> io::Result<()> {
    if cfg.install_sigint {
        sigint::install();
    }
    listener.set_nonblocking(true)?;
    let batcher = Batcher::with_max_pending(cfg.max_pending);
    let tel = if cfg.telemetry {
        Telemetry::recording()
    } else {
        Telemetry::off()
    };
    let mut engine = Engine::with_telemetry(cfg.engine.clone(), tel);
    let window = Duration::from_millis(cfg.engine.window_ms.max(1));
    let mut conns = 0u64;
    // Wall-clock deadline of the open window (None = no submits
    // pending, no window open).
    let mut deadline: Option<Instant> = None;
    // seq -> reply sink for deferred `submit` replies.
    let mut waiting: BTreeMap<u64, ReplySink> = BTreeMap::new();
    // Watch subscribers: the hub owns the bounded frame queues, keyed
    // by the `watch` request's seq; this map holds their sockets.
    let mut hub = WatchHub::new(WATCH_QUEUE_CAP);
    let mut watch_sinks: BTreeMap<u64, ReplySink> = BTreeMap::new();

    loop {
        // Gated on the install flag: the flag is process-global, and an
        // in-process test daemon must not drain because some other
        // daemon's SIGINT test fired.
        if cfg.install_sigint && sigint::pending() {
            batcher.begin_drain();
        }
        // Accept whatever is waiting; readers are detached and exit on
        // client close.
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    let id = conns;
                    conns += 1;
                    let b = Arc::clone(&batcher);
                    let max = cfg.max_line_bytes;
                    thread::Builder::new()
                        .name(format!("kube-packd-conn-{id}"))
                        .spawn(move || reader_loop(stream, id, &b, max))?;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e),
            }
        }
        // Wait for work, but never past the window deadline or the poll
        // tick.
        let timeout = match deadline {
            Some(d) => d.saturating_duration_since(Instant::now()).min(POLL),
            None => POLL,
        };
        let drained = batcher.pop_all(timeout);
        let terminal = matches!(drained, Drained::Empty);
        if let Drained::Items(items) = drained {
            for sub in items {
                match sub.request {
                    Ok(req) => match engine.apply(sub.seq, req.tag, &req.op) {
                        Some(reply) => {
                            if matches!(req.op, WireOp::Watch) {
                                // Register before the ack goes out so no
                                // window close can slip between them.
                                hub.subscribe(sub.seq);
                                watch_sinks.insert(sub.seq, Arc::clone(&sub.reply));
                            }
                            send_line(&sub.reply, &reply.to_string_compact());
                        }
                        None => {
                            // A deferred submit: opens the window if
                            // none is open.
                            waiting.insert(sub.seq, sub.reply);
                            deadline.get_or_insert_with(|| Instant::now() + window);
                        }
                    },
                    Err((err, tag)) => {
                        let reply = engine.error_reply(Some(sub.seq), tag, &err);
                        send_line(&sub.reply, &reply.to_string_compact());
                    }
                }
            }
        }
        // A shutdown op stops admission; already-enqueued requests keep
        // draining through the loop.
        if engine.draining() {
            batcher.begin_drain();
        }
        // Close the window on deadline, early on batch size, or
        // unconditionally once the drain has emptied the queue.
        let due = deadline.is_some_and(|d| Instant::now() >= d)
            || engine.pending_submit_count() >= cfg.max_batch.max(1)
            || (terminal && engine.has_pending_submits());
        if engine.has_pending_submits() && due {
            let at = (engine.windows_closed() + 1) * cfg.engine.window_ms;
            for (seq, reply) in engine.close_window_at(at) {
                if let Some(sink) = waiting.remove(&seq) {
                    send_line(&sink, &reply.to_string_compact());
                }
            }
            // Fan the close's delta frame out to watch subscribers; a
            // subscriber whose socket write fails is dropped here.
            if let Some(frame) = engine.take_watch_frame() {
                if !hub.is_empty() {
                    hub.publish(&frame.to_string_compact());
                    for id in hub.subscriber_ids() {
                        let Some(sink) = watch_sinks.get(&id) else {
                            hub.unsubscribe(id);
                            continue;
                        };
                        let alive = hub.drain(id).iter().all(|line| send_line(sink, line));
                        if !alive {
                            hub.unsubscribe(id);
                            watch_sinks.remove(&id);
                        }
                    }
                }
            }
            deadline = None;
        }
        if terminal && !engine.has_pending_submits() {
            debug_assert!(waiting.is_empty(), "drained with unanswered submits");
            break;
        }
        if !engine.has_pending_submits() {
            deadline = None;
        }
    }
    // Flush telemetry exports before reporting a clean exit.
    if let Some(path) = &cfg.trace_out {
        std::fs::write(path, engine.telemetry().export_chrome())?;
    }
    if let Some(path) = &cfg.metrics_out {
        std::fs::write(path, engine.telemetry().export_prometheus())?;
    }
    Ok(())
}

/// One framed line off the socket, or why there isn't one.
enum Frame {
    Line(String),
    /// The line blew the byte cap; it was discarded without unbounded
    /// buffering. Payload is the observed length.
    Oversized(usize),
    Eof,
}

/// Read one newline-delimited frame, enforcing the byte cap *while*
/// reading — an attacker line never occupies more than `max` bytes of
/// buffer no matter how long it is.
fn read_frame(r: &mut impl BufRead, max: usize) -> io::Result<Frame> {
    let mut buf: Vec<u8> = Vec::new();
    let mut seen = 0usize;
    let mut dropped = false;
    loop {
        let chunk = r.fill_buf()?;
        if chunk.is_empty() {
            return Ok(match (seen, dropped) {
                (0, _) => Frame::Eof,
                (_, true) => Frame::Oversized(seen),
                // A final unterminated line still counts as a frame.
                (_, false) => Frame::Line(String::from_utf8_lossy(&buf).into_owned()),
            });
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                seen += pos;
                if !dropped {
                    buf.extend_from_slice(&chunk[..pos]);
                }
                r.consume(pos + 1);
                return Ok(if dropped || seen > max {
                    Frame::Oversized(seen)
                } else {
                    Frame::Line(String::from_utf8_lossy(&buf).into_owned())
                });
            }
            None => {
                let n = chunk.len();
                seen += n;
                if !dropped {
                    buf.extend_from_slice(chunk);
                    if buf.len() > max {
                        dropped = true;
                        buf = Vec::new();
                    }
                }
                r.consume(n);
            }
        }
    }
}

/// Per-connection reader: frame, parse, enqueue. Parse failures are
/// enqueued too (the engine answers them in seq order); only drain-time
/// rejections are answered here, because they never join the
/// interleaving.
fn reader_loop(stream: TcpStream, conn: u64, batcher: &Batcher, max: usize) {
    let Ok(write_half) = stream.try_clone() else {
        return;
    };
    let sink: ReplySink = Arc::new(Mutex::new(write_half));
    let mut reader = BufReader::new(stream);
    loop {
        let frame = match read_frame(&mut reader, max) {
            Ok(f) => f,
            Err(_) => break, // connection died
        };
        let parsed = match frame {
            Frame::Eof => break,
            Frame::Oversized(got) => Err((WireError::Oversized { got, max }, None)),
            Frame::Line(line) => {
                if line.trim().is_empty() {
                    continue;
                }
                parse_request(&line, max)
            }
        };
        let tag = match &parsed {
            Ok(req) => req.tag,
            Err((_, tag)) => *tag,
        };
        // Rejections never join the interleaving, so they are answered
        // in place (carrying no seq) rather than by the engine thread.
        let rejection = match batcher.submit(conn, parsed, Arc::clone(&sink)) {
            Admit::Accepted(_) => None,
            Admit::Draining => Some(WireError::Draining),
            Admit::Overloaded { pending, max } => Some(WireError::Overloaded { pending, max }),
        };
        if let Some(err) = rejection {
            if !send_line(&sink, &err.reply(None, tag).to_string_compact()) {
                break;
            }
        }
    }
}

/// SIGINT → drain flag, with no libc crate: `signal(2)` is in the C
/// library std already links. The handler only flips an atomic; the
/// serve loop polls it.
#[cfg(unix)]
mod sigint {
    use std::sync::atomic::{AtomicBool, Ordering};

    static FLAG: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_sigint(_sig: i32) {
        FLAG.store(true, Ordering::SeqCst);
    }

    pub fn install() {
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        const SIGINT: i32 = 2;
        unsafe {
            let _ = signal(SIGINT, on_sigint);
        }
    }

    pub fn pending() -> bool {
        FLAG.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sigint {
    pub fn install() {}

    pub fn pending() -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_split_on_newlines_and_cap_bytes() {
        let mut r = BufReader::new(Cursor::new(b"{\"op\":\"health\"}\nshort\n".to_vec()));
        match read_frame(&mut r, 64).expect("frame") {
            Frame::Line(l) => assert_eq!(l, "{\"op\":\"health\"}"),
            _ => panic!("expected a line"),
        }
        match read_frame(&mut r, 64).expect("frame") {
            Frame::Line(l) => assert_eq!(l, "short"),
            _ => panic!("expected a line"),
        }
        assert!(matches!(read_frame(&mut r, 64).expect("frame"), Frame::Eof));
    }

    #[test]
    fn oversized_frames_are_discarded_not_buffered() {
        let long = format!("{}\nnext\n", "x".repeat(1000));
        let mut r = BufReader::new(Cursor::new(long.into_bytes()));
        match read_frame(&mut r, 16).expect("frame") {
            Frame::Oversized(got) => assert_eq!(got, 1000),
            _ => panic!("expected oversized"),
        }
        // The stream recovers at the next line.
        match read_frame(&mut r, 16).expect("frame") {
            Frame::Line(l) => assert_eq!(l, "next"),
            _ => panic!("expected recovery line"),
        }
    }

    #[test]
    fn unterminated_tail_is_a_frame() {
        let mut r = BufReader::new(Cursor::new(b"{\"op\":\"query\"}".to_vec()));
        match read_frame(&mut r, 64).expect("frame") {
            Frame::Line(l) => assert_eq!(l, "{\"op\":\"query\"}"),
            _ => panic!("expected a line"),
        }
        assert!(matches!(read_frame(&mut r, 64).expect("frame"), Frame::Eof));
    }
}
