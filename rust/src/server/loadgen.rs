//! Closed-loop load generator for the serve daemon (`serve-bench`).
//!
//! Drives a freshly-spawned daemon over loopback with the admission
//! stream of a seeded [`ChurnTrace`] (converted by
//! [`trace_to_windows`]) and measures what the paper's service framing
//! cares about: sustained admissions/sec and the decision-latency
//! distribution (p50/p95/p99) a client observes, window batching
//! included — a `submit` reply intentionally waits for its solve window
//! to close, so latency is dominated by `--window-ms` under light load
//! and by solve time under saturation.
//!
//! Two seeded arrival modes:
//!
//! * **closed** — N client connections, each with one request in
//!   flight; the next request fires when the reply lands. Throughput
//!   self-regulates to what the daemon sustains.
//! * **open** — one firehose connection paced by seeded exponential
//!   gaps at a target rate, replies matched asynchronously by tag.
//!   Measures latency under offered (not sustained) load.
//!
//! Wall-clock numbers are measurements, never protocol content — the
//! reply *streams* stay deterministic, which
//! [`replay_reply_stream`] exposes for the determinism record in
//! `BENCH_serve.json` and the thread-count proptests.

use std::io::{self, BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

use crate::telemetry::Telemetry;
use crate::util::json::{parse, Json};
use crate::util::rng::Rng;
use crate::util::stats::{mean, percentile};
use crate::workload::churn::{ChurnParams, ChurnTrace, ChurnTraceGenerator};
use crate::workload::GenParams;

use super::engine::{Engine, EngineConfig};
use super::protocol::{trace_to_windows, WireOp, WireRequest};
use super::{ServeConfig, ServeHandle};

/// How requests are offered to the daemon.
#[derive(Clone, Copy, Debug)]
pub enum ArrivalMode {
    /// `clients` connections, one request in flight each.
    Closed { clients: usize },
    /// One connection, seeded exponential gaps at `rate_per_s`.
    Open { rate_per_s: f64 },
}

impl ArrivalMode {
    pub fn label(&self) -> String {
        match self {
            ArrivalMode::Closed { clients } => format!("closed/{clients}"),
            ArrivalMode::Open { rate_per_s } => format!("open/{rate_per_s}"),
        }
    }
}

/// One `serve-bench` cell.
#[derive(Clone, Debug)]
pub struct LoadgenParams {
    pub seed: u64,
    pub mode: ArrivalMode,
    /// Workload shape; the trace also supplies the daemon's initial
    /// fleet and tier count.
    pub churn: ChurnParams,
    pub window_ms: u64,
    pub max_batch: usize,
    pub threads: usize,
    pub solve_timeout: Duration,
}

/// Engine configured the way the daemon would be for this trace: the
/// trace's fleet, tiers, and reference capacity, the bench's solve
/// knobs.
pub fn engine_for_trace(
    trace: &ChurnTrace,
    threads: usize,
    solve_timeout: Duration,
    window_ms: u64,
) -> EngineConfig {
    EngineConfig {
        p_max: trace.p_max,
        nodes: trace.nodes.clone(),
        reference_capacity: trace.reference_capacity,
        solve_timeout,
        threads,
        incremental: true,
        autoscale: None,
        window_ms,
    }
}

/// Replay a trace's converted windows through an in-process [`Engine`]
/// and return every reply line in emission order plus the final state
/// fingerprint. This is the determinism surface: for a fixed trace the
/// result must be byte-identical at any `threads` count (solves must
/// prove within budget — the anytime caveat the lifecycle module
/// documents).
pub fn replay_reply_stream(
    trace: &ChurnTrace,
    threads: usize,
    solve_timeout: Duration,
) -> (Vec<String>, u64) {
    let mut engine = Engine::new(engine_for_trace(trace, threads, solve_timeout, 1_000));
    let mut lines = Vec::new();
    for (t, ops) in trace_to_windows(trace) {
        lines.extend(engine.run_window(t, &ops));
    }
    (lines, engine.digest())
}

/// Everything observable from one in-process replay: the reply stream,
/// the canonical journal and watch-frame lines, and the end-state
/// digest.
pub struct ObservedReplay {
    pub lines: Vec<String>,
    /// Canonical journal entries (`wall = false`), oldest first.
    pub journal: Vec<String>,
    /// The delta frame each window close would push to a subscriber.
    pub frames: Vec<String>,
    pub digest: u64,
}

/// Like [`replay_reply_stream`], but optionally armed with a recording
/// telemetry handle and returning the full observability surface. The
/// proptest surface for "observability never feeds back": replies,
/// journal, frames, and digest must be byte-identical with telemetry on
/// or off and at any `threads` count.
pub fn replay_observed(
    trace: &ChurnTrace,
    threads: usize,
    solve_timeout: Duration,
    telemetry: bool,
) -> ObservedReplay {
    let tel = if telemetry {
        Telemetry::recording()
    } else {
        Telemetry::off()
    };
    let mut engine = Engine::with_telemetry(
        engine_for_trace(trace, threads, solve_timeout, 1_000),
        tel,
    );
    let mut lines = Vec::new();
    let mut frames = Vec::new();
    for (t, ops) in trace_to_windows(trace) {
        lines.extend(engine.run_window(t, &ops));
        if let Some(frame) = engine.take_watch_frame() {
            frames.push(frame.to_string_compact());
        }
    }
    let journal = engine
        .journal()
        .since(0, usize::MAX)
        .map(|e| e.to_json(false).to_string_compact())
        .collect();
    ObservedReplay {
        lines,
        journal,
        frames,
        digest: engine.digest(),
    }
}

/// FNV-1a over a reply stream — a compact identity for the determinism
/// record in `BENCH_serve.json`.
pub fn stream_fingerprint(lines: &[String]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for line in lines {
        for &b in line.as_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h ^= b'\n' as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A blocking newline-JSON client connection (also the CLI's transport
/// for `kube-packd journal`).
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        Ok(Client {
            writer,
            reader: BufReader::new(stream),
        })
    }

    pub fn send(&mut self, req: &WireRequest) -> io::Result<()> {
        let mut line = req.to_line();
        line.push('\n');
        self.writer.write_all(line.as_bytes())
    }

    pub fn recv(&mut self) -> io::Result<Json> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "daemon closed the connection",
            ));
        }
        parse(line.trim_end())
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("bad reply: {e}")))
    }

    /// Send, then block until the reply carrying this request's tag
    /// arrives (single-outstanding discipline).
    pub fn request(&mut self, req: &WireRequest) -> io::Result<Json> {
        self.send(req)?;
        loop {
            let reply = self.recv()?;
            if reply.get("tag").and_then(Json::as_i64).map(|t| t as u64) == req.tag {
                return Ok(reply);
            }
        }
    }
}

/// Generate the trace and flatten its windows into one tagged request
/// stream (window structure re-emerges daemon-side from the batcher).
fn request_stream(p: &LoadgenParams) -> (ChurnTrace, Vec<WireRequest>) {
    let trace = ChurnTraceGenerator::new(p.churn, p.seed).generate();
    let mut reqs = Vec::new();
    for (_, ops) in trace_to_windows(&trace) {
        for op in ops {
            let tag = reqs.len() as u64;
            reqs.push(WireRequest::tagged(op, tag));
        }
    }
    (trace, reqs)
}

/// Run one bench cell against a live daemon on loopback and return the
/// cell object for `BENCH_serve.json`.
pub fn run_bench(p: &LoadgenParams) -> io::Result<Json> {
    let (trace, reqs) = request_stream(p);
    let total = reqs.len();
    let handle = ServeHandle::spawn(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        max_batch: p.max_batch,
        engine: engine_for_trace(&trace, p.threads, p.solve_timeout, p.window_ms),
        telemetry: true,
        ..ServeConfig::default()
    })?;
    let addr = handle.addr.to_string();

    let started = Instant::now();
    let latencies_ms = match p.mode {
        ArrivalMode::Closed { clients } => drive_closed(&addr, reqs, clients.max(1))?,
        ArrivalMode::Open { rate_per_s } => drive_open(&addr, reqs, rate_per_s, p.seed)?,
    };
    let elapsed = started.elapsed().as_secs_f64();

    // Snapshot the end state, then drain the daemon.
    let mut control = Client::connect(&addr)?;
    let query = control.request(&WireRequest::tagged(
        WireOp::Query { latency: false },
        total as u64,
    ))?;
    let shutdown = control.request(&WireRequest::tagged(WireOp::Shutdown, total as u64 + 1))?;
    if shutdown.get("error").is_some() {
        return Err(io::Error::other("shutdown rejected"));
    }
    handle.join()?;

    let mut cell = Json::obj();
    cell.set("mode", p.mode.label())
        .set("seed", p.seed)
        .set("threads", p.threads as u64)
        .set("window_ms", p.window_ms)
        .set("max_batch", p.max_batch as u64)
        .set("requests", total as u64)
        .set("elapsed_s", elapsed)
        .set(
            "admissions_per_s",
            if elapsed > 0.0 { total as f64 / elapsed } else { 0.0 },
        )
        .set("latency_p50_ms", percentile(&latencies_ms, 50.0))
        .set("latency_p95_ms", percentile(&latencies_ms, 95.0))
        .set("latency_p99_ms", percentile(&latencies_ms, 99.0))
        .set("latency_mean_ms", mean(&latencies_ms));
    for key in ["windows", "pods", "pending", "digest"] {
        if let Some(v) = query.get(key) {
            cell.set(key, v.clone());
        }
    }
    Ok(cell)
}

/// Build the complete `BENCH_serve.json` document: bench cells over
/// closed and open arrival modes on one seeded churn workload, plus the
/// determinism record — reply-stream fingerprints and end-state digests
/// from in-process replays at portfolio threads 1 and 8 (the acceptance
/// surface: they must agree byte for byte).
pub fn bench_document(quick: bool) -> io::Result<Json> {
    let seed = 0x5E17;
    let churn = ChurnParams {
        horizon_ms: if quick { 3_000 } else { 10_000 },
        mean_arrival_ms: 300,
        mean_lifetime_ms: 2_500,
        drain_chance: 0.03,
        join_chance: 0.03,
        ..ChurnParams::for_cluster(GenParams {
            nodes: 8,
            pods_per_node: 4,
            priority_tiers: 2,
            usage: 0.95,
        })
    };
    let mk = |mode| LoadgenParams {
        seed,
        mode,
        churn,
        window_ms: 50,
        max_batch: 32,
        threads: 1,
        solve_timeout: Duration::from_secs(2),
    };
    let modes: Vec<ArrivalMode> = if quick {
        vec![ArrivalMode::Closed { clients: 4 }]
    } else {
        vec![
            ArrivalMode::Closed { clients: 1 },
            ArrivalMode::Closed { clients: 8 },
            ArrivalMode::Open { rate_per_s: 400.0 },
        ]
    };
    let mut cells = Vec::new();
    for mode in modes {
        cells.push(run_bench(&mk(mode))?);
    }
    let trace = ChurnTraceGenerator::new(churn, seed).generate();
    let (s1, d1) = replay_reply_stream(&trace, 1, Duration::from_secs(2));
    let (s8, d8) = replay_reply_stream(&trace, 8, Duration::from_secs(2));
    let mut det = Json::obj();
    det.set("trace_seed", seed)
        .set("t1_stream", format!("{:016x}", stream_fingerprint(&s1)))
        .set("t8_stream", format!("{:016x}", stream_fingerprint(&s8)))
        .set("t1_digest", format!("{d1:016x}"))
        .set("t8_digest", format!("{d8:016x}"))
        .set("thread_independent", s1 == s8 && d1 == d8);
    let mut doc = Json::obj();
    doc.set("bench", "serve")
        .set("schema", 1u64)
        .set("determinism", det)
        .set("cells", Json::Arr(cells));
    Ok(doc)
}

/// Closed loop: split the stream round-robin over `clients` threads,
/// each keeping exactly one request outstanding on its own connection.
fn drive_closed(addr: &str, reqs: Vec<WireRequest>, clients: usize) -> io::Result<Vec<f64>> {
    let mut lanes: Vec<Vec<WireRequest>> = (0..clients).map(|_| Vec::new()).collect();
    for (i, r) in reqs.into_iter().enumerate() {
        lanes[i % clients].push(r);
    }
    let mut workers = Vec::new();
    for lane in lanes {
        let addr = addr.to_string();
        workers.push(thread::spawn(move || -> io::Result<Vec<f64>> {
            let mut client = Client::connect(&addr)?;
            let mut out = Vec::with_capacity(lane.len());
            for req in &lane {
                let sent = Instant::now();
                client.request(req)?;
                out.push(sent.elapsed().as_secs_f64() * 1_000.0);
            }
            Ok(out)
        }));
    }
    let mut all = Vec::new();
    for w in workers {
        let lane = w
            .join()
            .map_err(|_| io::Error::other("client thread panicked"))??;
        all.extend(lane);
    }
    Ok(all)
}

/// Open loop: one connection, seeded exponential pacing; a reader
/// thread matches replies to send times by tag.
fn drive_open(addr: &str, reqs: Vec<WireRequest>, rate_per_s: f64, seed: u64) -> io::Result<Vec<f64>> {
    let total = reqs.len();
    let mut writer = Client::connect(addr)?;
    let read_stream = writer.writer.try_clone()?;
    let reader = thread::spawn(move || -> io::Result<Vec<(u64, f64)>> {
        let mut r = BufReader::new(read_stream);
        let origin = Instant::now();
        let mut seen = Vec::with_capacity(total);
        while seen.len() < total {
            let mut line = String::new();
            if r.read_line(&mut line)? == 0 {
                break;
            }
            let at = origin.elapsed().as_secs_f64();
            let reply = parse(line.trim_end())
                .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("{e}")))?;
            if let Some(tag) = reply.get("tag").and_then(Json::as_i64) {
                seen.push((tag as u64, at));
            }
        }
        Ok(seen)
    });

    let rate = rate_per_s.max(1.0);
    let mut rng = Rng::new(seed ^ 0x6f70_656e); // "open"
    let origin = Instant::now();
    let mut sends = vec![0.0f64; total];
    let mut next_at = 0.0f64;
    for req in &reqs {
        let gap = -(1.0 - rng.f64()).ln() / rate;
        next_at += gap;
        loop {
            let now = origin.elapsed().as_secs_f64();
            if now >= next_at {
                break;
            }
            thread::sleep(Duration::from_secs_f64((next_at - now).min(0.01)));
        }
        sends[req.tag.expect("tagged") as usize] = origin.elapsed().as_secs_f64();
        writer.send(req)?;
    }
    let seen = reader
        .join()
        .map_err(|_| io::Error::other("reader thread panicked"))??;
    if seen.len() != total {
        return Err(io::Error::other(format!(
            "open-loop run lost replies: {}/{total}",
            seen.len()
        )));
    }
    Ok(seen
        .into_iter()
        .map(|(tag, at)| (at - sends[tag as usize]) * 1_000.0)
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_params(mode: ArrivalMode) -> LoadgenParams {
        LoadgenParams {
            seed: 7,
            mode,
            churn: ChurnParams {
                horizon_ms: 3_000,
                mean_arrival_ms: 400,
                mean_lifetime_ms: 1_500,
                drain_chance: 0.05,
                join_chance: 0.05,
                ..ChurnParams::for_cluster(GenParams {
                    nodes: 3,
                    pods_per_node: 3,
                    priority_tiers: 2,
                    usage: 0.9,
                })
            },
            window_ms: 20,
            max_batch: 16,
            threads: 1,
            solve_timeout: Duration::from_secs(2),
        }
    }

    #[test]
    fn closed_loop_bench_round_trips() {
        let cell = run_bench(&tiny_params(ArrivalMode::Closed { clients: 4 })).expect("bench");
        assert!(cell.get("requests").and_then(Json::as_i64).expect("requests") > 0);
        assert!(cell.get("admissions_per_s").and_then(Json::as_f64).expect("rate") > 0.0);
        let p50 = cell.get("latency_p50_ms").and_then(Json::as_f64).expect("p50");
        let p99 = cell.get("latency_p99_ms").and_then(Json::as_f64).expect("p99");
        assert!(p50 >= 0.0 && p99 >= p50);
        assert!(cell.get("digest").and_then(Json::as_str).is_some());
    }

    #[test]
    fn open_loop_bench_round_trips() {
        let cell =
            run_bench(&tiny_params(ArrivalMode::Open { rate_per_s: 500.0 })).expect("bench");
        assert!(cell.get("requests").and_then(Json::as_i64).expect("requests") > 0);
        assert!(cell.get("latency_p99_ms").and_then(Json::as_f64).expect("p99") >= 0.0);
    }

    #[test]
    fn replay_streams_are_reproducible() {
        let p = tiny_params(ArrivalMode::Closed { clients: 1 });
        let trace = ChurnTraceGenerator::new(p.churn, p.seed).generate();
        let (a, da) = replay_reply_stream(&trace, 1, Duration::from_secs(2));
        let (b, db) = replay_reply_stream(&trace, 1, Duration::from_secs(2));
        assert_eq!(a, b, "same trace, same threads: byte-identical replies");
        assert_eq!(da, db);
        assert!(stream_fingerprint(&a) == stream_fingerprint(&b));
    }
}
