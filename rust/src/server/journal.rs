//! Window-close event journal and watch-frame fan-out.
//!
//! The journal is the daemon's flight recorder: one structured
//! [`JournalEntry`] per closed solve window — seq range, per-tier
//! placed/pending deltas, certificate outcome, cumulative engine
//! counters, wall + virtual timings — kept in a bounded ring so memory
//! stays flat under unbounded uptime. Clients page through it with the
//! `journal` wire op (`since`-window cursor) or subscribe to live
//! deltas with `watch`; `kube-packd journal` pretty-prints it.
//!
//! # Determinism contract
//!
//! The canonical wire form of an entry ([`JournalEntry::to_json`] with
//! `wall = false`, the default) is a pure function of the seq-ordered
//! request interleaving: identical at any `--threads` count and with
//! telemetry on or off (the counters snapshot is engine-owned, not
//! telemetry-derived). The wall-clock solve time is recorded but only
//! rendered when a client opts in with `"wall":true` — it sits outside
//! the byte-identity boundary, exactly like span timestamps.
//!
//! [`WatchHub`] owns the per-subscriber frame queues. Queues are
//! bounded: past the cap, new frames are dropped and counted, and the
//! next successful drain leads with a structured `lagged` frame
//! carrying the missed count — slow consumers shed history instead of
//! growing the daemon's heap.

use std::collections::VecDeque;

use crate::util::json::Json;

/// Default journal ring capacity (entries, i.e. windows retained).
pub const JOURNAL_CAP: usize = 512;

/// Default per-subscriber watch queue bound (frames).
pub const WATCH_QUEUE_CAP: usize = 64;

/// Cumulative engine-owned counters at a window close. Tracked by the
/// engine itself (not telemetry) so journal entries are identical with
/// recording on or off.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    /// Requests applied (all ops, including failed ones).
    pub requests: u64,
    /// Pods admitted through `submit`.
    pub submit_pods: u64,
    /// Windows whose round invoked the CP solver.
    pub solver_invocations: u64,
    /// Autoscale scale-ups applied by window rounds.
    pub scale_ups: u64,
    /// Structured error replies sent.
    pub errors: u64,
}

impl CounterSnapshot {
    fn to_json(self) -> Json {
        let mut o = Json::obj();
        o.set("requests", self.requests)
            .set("submit_pods", self.submit_pods)
            .set("solver_invocations", self.solver_invocations)
            .set("scale_ups", self.scale_ups)
            .set("errors", self.errors);
        o
    }
}

/// One window-close record.
#[derive(Clone, Debug, PartialEq)]
pub struct JournalEntry {
    /// Window id as it appears on the wire (0-based: the first window
    /// to close is window 0, matching submit replies).
    pub window: u64,
    /// Virtual close time: `(window + 1) * window_ms`.
    pub virtual_ms: u64,
    /// Seq range applied since the previous close; `None` when the
    /// window closed on a timer with no requests.
    pub seq_lo: Option<u64>,
    pub seq_hi: Option<u64>,
    /// Deferred submit requests answered at this close.
    pub submits: u64,
    /// Window certificate: `proven-optimal` | `anytime` | `default`.
    pub certificate: String,
    pub solver_invoked: bool,
    /// Per-tier placed counts before/after the window round.
    pub placed_before: Vec<u64>,
    pub placed_after: Vec<u64>,
    /// Pending pod counts before/after the window round.
    pub pending_before: u64,
    pub pending_after: u64,
    /// Cumulative engine counters at this close.
    pub counters: CounterSnapshot,
    /// Wall-clock time the round took, microseconds. **Non-canonical**:
    /// omitted from the wire form unless the client asks for it.
    pub wall_us: u64,
}

impl JournalEntry {
    /// Wire form. With `wall = false` (the canonical default) the
    /// output is byte-identical across thread counts and telemetry
    /// settings; `wall = true` appends the wall-clock field.
    pub fn to_json(&self, wall: bool) -> Json {
        let mut o = Json::obj();
        o.set("window", self.window)
            .set("virtual_ms", self.virtual_ms)
            .set("submits", self.submits)
            .set("certificate", self.certificate.as_str())
            .set("solver_invoked", self.solver_invoked)
            .set(
                "placed_before",
                Json::Arr(self.placed_before.iter().map(|&v| Json::from(v)).collect()),
            )
            .set(
                "placed_after",
                Json::Arr(self.placed_after.iter().map(|&v| Json::from(v)).collect()),
            )
            .set("pending_before", self.pending_before)
            .set("pending_after", self.pending_after)
            .set("counters", self.counters.to_json());
        if let (Some(lo), Some(hi)) = (self.seq_lo, self.seq_hi) {
            o.set("seq_lo", lo).set("seq_hi", hi);
        }
        if wall {
            o.set("wall_us", self.wall_us);
        }
        o
    }
}

/// Bounded ring of window-close entries. Old windows fall off the
/// front; the cursor API reports the retained range so clients can see
/// when they have a gap.
#[derive(Debug)]
pub struct Journal {
    cap: usize,
    entries: VecDeque<JournalEntry>,
}

impl Journal {
    pub fn new(cap: usize) -> Journal {
        Journal {
            cap: cap.max(1),
            entries: VecDeque::new(),
        }
    }

    pub fn push(&mut self, entry: JournalEntry) {
        if self.entries.len() == self.cap {
            self.entries.pop_front();
        }
        self.entries.push_back(entry);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Oldest retained window id, if any.
    pub fn first_window(&self) -> Option<u64> {
        self.entries.front().map(|e| e.window)
    }

    /// Newest retained window id, if any.
    pub fn last_window(&self) -> Option<u64> {
        self.entries.back().map(|e| e.window)
    }

    /// Entries with `window >= since` (a start-from cursor: pass the
    /// previous reply's `next` to resume), oldest first, at most
    /// `limit`.
    pub fn since(&self, since: u64, limit: usize) -> impl Iterator<Item = &JournalEntry> {
        self.entries
            .iter()
            .filter(move |e| e.window >= since)
            .take(limit)
    }
}

/// A structured `lagged` frame: `missed` delta frames were dropped for
/// this subscriber since its last successful drain.
pub fn lagged_frame(missed: u64) -> Json {
    let mut o = Json::obj();
    o.set("frame", "lagged").set("missed", missed);
    o
}

struct Subscriber {
    id: u64,
    queue: VecDeque<String>,
    missed: u64,
}

/// Fan-out of window-close delta frames to watch subscribers, with
/// per-subscriber bounded queues. Pure bookkeeping — the serve loop
/// owns the sockets and calls [`drain`](WatchHub::drain) after every
/// publish; a subscriber whose socket write fails is dropped there.
#[derive(Default)]
pub struct WatchHub {
    subs: Vec<Subscriber>,
    cap: usize,
}

impl WatchHub {
    pub fn new(queue_cap: usize) -> WatchHub {
        WatchHub {
            subs: Vec::new(),
            cap: queue_cap.max(1),
        }
    }

    pub fn subscribe(&mut self, id: u64) {
        if !self.subs.iter().any(|s| s.id == id) {
            self.subs.push(Subscriber {
                id,
                queue: VecDeque::new(),
                missed: 0,
            });
        }
    }

    pub fn unsubscribe(&mut self, id: u64) {
        self.subs.retain(|s| s.id != id);
    }

    pub fn subscriber_ids(&self) -> Vec<u64> {
        self.subs.iter().map(|s| s.id).collect()
    }

    pub fn len(&self) -> usize {
        self.subs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.subs.is_empty()
    }

    /// Enqueue one frame line for every subscriber. Full queues drop
    /// the frame and count it toward the subscriber's `lagged` notice.
    pub fn publish(&mut self, line: &str) {
        for s in &mut self.subs {
            if s.queue.len() >= self.cap {
                s.missed += 1;
            } else {
                s.queue.push_back(line.to_string());
            }
        }
    }

    /// Take everything queued for `id`: a `lagged` frame first when
    /// frames were dropped, then the surviving frames in order.
    pub fn drain(&mut self, id: u64) -> Vec<String> {
        let Some(s) = self.subs.iter_mut().find(|s| s.id == id) else {
            return Vec::new();
        };
        let mut out = Vec::with_capacity(s.queue.len() + 1);
        if s.missed > 0 {
            out.push(lagged_frame(s.missed).to_string_compact());
            s.missed = 0;
        }
        out.extend(s.queue.drain(..));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(window: u64) -> JournalEntry {
        JournalEntry {
            window,
            virtual_ms: window * 1000,
            seq_lo: Some(window * 10),
            seq_hi: Some(window * 10 + 3),
            submits: 2,
            certificate: "proven-optimal".to_string(),
            solver_invoked: true,
            placed_before: vec![1, 0],
            placed_after: vec![3, 1],
            pending_before: 3,
            pending_after: 0,
            counters: CounterSnapshot {
                requests: window * 4,
                submit_pods: window * 2,
                solver_invocations: window,
                scale_ups: 0,
                errors: 0,
            },
            wall_us: 1234,
        }
    }

    #[test]
    fn ring_evicts_oldest_past_cap() {
        let mut j = Journal::new(3);
        for w in 1..=5 {
            j.push(entry(w));
        }
        assert_eq!(j.len(), 3);
        assert_eq!(j.first_window(), Some(3));
        assert_eq!(j.last_window(), Some(5));
    }

    #[test]
    fn since_cursor_pages_forward() {
        let mut j = Journal::new(10);
        for w in 1..=6 {
            j.push(entry(w));
        }
        let windows: Vec<u64> = j.since(2, 3).map(|e| e.window).collect();
        assert_eq!(windows, vec![2, 3, 4]);
        let rest: Vec<u64> = j.since(5, 100).map(|e| e.window).collect();
        assert_eq!(rest, vec![5, 6]);
        assert!(j.since(7, 100).next().is_none());
    }

    #[test]
    fn wall_time_is_opt_in_on_the_wire() {
        let e = entry(1);
        let canonical = e.to_json(false).to_string_compact();
        assert!(!canonical.contains("wall_us"));
        let with_wall = e.to_json(true).to_string_compact();
        assert!(with_wall.contains("\"wall_us\":1234"));
        // The canonical form is stable under re-rendering.
        assert_eq!(canonical, e.to_json(false).to_string_compact());
    }

    #[test]
    fn hub_bounds_queues_and_reports_lag() {
        let mut hub = WatchHub::new(2);
        hub.subscribe(7);
        hub.subscribe(7); // idempotent
        assert_eq!(hub.len(), 1);
        for i in 0..5 {
            hub.publish(&format!("frame-{i}"));
        }
        let got = hub.drain(7);
        // 2 queued + 3 dropped → lagged first, then the survivors.
        assert_eq!(got.len(), 3);
        assert_eq!(got[0], "{\"frame\":\"lagged\",\"missed\":3}");
        assert_eq!(got[1], "frame-0");
        assert_eq!(got[2], "frame-1");
        // Drained state resets.
        assert!(hub.drain(7).is_empty());
        hub.publish("frame-5");
        assert_eq!(hub.drain(7), vec!["frame-5".to_string()]);
        hub.unsubscribe(7);
        assert!(hub.is_empty());
        hub.publish("frame-6");
        assert!(hub.drain(7).is_empty());
    }
}
