//! The serve engine: single-threaded owner of the live cluster.
//!
//! One engine instance owns the daemon's [`ClusterState`], its
//! persistent [`SolveSession`], the provisioning-failure memo, and the
//! [`Telemetry`] recorder. Connection threads never touch any of it —
//! they enqueue seq-stamped requests through the
//! [`Batcher`](super::batcher::Batcher) and the engine thread applies
//! them in seq order, which is the whole determinism story: replies are
//! a pure function of the seq-ordered request interleaving, at any
//! portfolio `--threads` count (threads change solve *speed* inside the
//! window budget, never results — the crate-wide contract).
//!
//! Scheduling follows the churn runner's fallback semantics exactly:
//! mutations apply as they arrive, and at each window close the engine
//! runs default-scheduler-first with CP fallback
//! ([`OptimizingScheduler::run_with_session_traced`]) over whatever is
//! pending, carrying the solve session and provision memo across
//! windows. `submit` replies are deferred to the window close and carry
//! per-pod placements plus the window certificate (`proven-optimal` |
//! `anytime` | `default`). The daemon ⇄ simulator equivalence test
//! rides this symmetry: a [`ChurnTrace`] converted by
//! [`trace_to_windows`](super::protocol::trace_to_windows) and replayed
//! through [`Engine::run_window`] lands in the same state fingerprint
//! as `run_churn`.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::autoscaler::{AutoscaleConfig, NodePool, ScaleUpReport};
use crate::cluster::{identical_nodes, ClusterState, Node, NodeId, PodId, ReplicaSet, Resources};
use crate::optimizer::algorithm::OptimizerConfig;
use crate::optimizer::constraints::ModuleRegistry;
use crate::optimizer::explain::explain_pod;
use crate::optimizer::plugin::RunReport;
use crate::optimizer::session::{fingerprint_state, SolveSession};
use crate::optimizer::OptimizingScheduler;
use crate::portfolio::PortfolioConfig;
use crate::solver::Probe;
use crate::telemetry::Telemetry;
use crate::util::json::Json;

use super::journal::{CounterSnapshot, Journal, JournalEntry, JOURNAL_CAP};
use super::protocol::{SubmitSpec, WireError, WireOp, PROTOCOL_VERSION};

/// Engine knobs (the daemon's `serve` flags, minus the socket ones).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Highest priority value (tiers = `p_max + 1`).
    pub p_max: u32,
    /// Initial fleet.
    pub nodes: Vec<Node>,
    /// Reference capacity for pool-preset joins and the autoscaler.
    pub reference_capacity: Resources,
    /// `T_total` handed to each window's fallback optimisation.
    pub solve_timeout: Duration,
    /// Portfolio threads per solve (1 = the single-threaded solver,
    /// bit for bit).
    pub threads: usize,
    /// Keep the solve session alive across windows (byte-identical
    /// results, warm-started work — on by default for a long-lived
    /// daemon).
    pub incremental: bool,
    /// Opt-in CP-driven scale-up inside the window solve.
    pub autoscale: Option<AutoscaleConfig>,
    /// Window length in virtual ms: each closed window advances the
    /// daemon's logical clock by this much (the paper's 1s scheduling
    /// window).
    pub window_ms: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            p_max: 1,
            nodes: identical_nodes(4, Resources::new(4000, 4096)),
            reference_capacity: Resources::new(4000, 4096),
            solve_timeout: Duration::from_secs(1),
            threads: 1,
            incremental: true,
            autoscale: None,
            window_ms: 1_000,
        }
    }
}

/// A `submit` awaiting its window close.
struct PendingSubmit {
    seq: u64,
    tag: Option<u64>,
    rs_name: String,
    pods: Vec<PodId>,
    /// Wall-clock arrival, for the admission→decision latency
    /// histogram. Observability only — never read by scheduling.
    arrived: Instant,
}

/// Single-threaded owner of the daemon's cluster, session, and
/// telemetry. See the module docs for the threading model.
pub struct Engine {
    cfg: EngineConfig,
    state: ClusterState,
    session: Option<SolveSession>,
    provision_memo: Option<(u64, ScaleUpReport)>,
    tel: Telemetry,
    /// ReplicaSet templates by id (first-seen template wins, like the
    /// churn runner's catalog).
    catalog: BTreeMap<u32, ReplicaSet>,
    name_to_rs: BTreeMap<String, u32>,
    next_ord: BTreeMap<u32, u32>,
    next_rs_id: u32,
    pod_names: BTreeMap<String, PodId>,
    pending_submits: Vec<PendingSubmit>,
    windows: u64,
    requests: u64,
    now_ms: u64,
    draining: bool,
    /// Seq counter for the in-process [`Engine::run_window`] driver
    /// (the TCP path sequences in the batcher instead).
    auto_seq: u64,
    /// Window-close flight recorder (the `journal` op pages it).
    journal: Journal,
    /// Engine-owned cumulative counters snapshotted into each journal
    /// entry. Deliberately not telemetry-derived: these are identical
    /// with recording on or off and at any thread count, so journal
    /// entries stay inside the byte-identity boundary.
    ctr: CounterSnapshot,
    /// Seq range applied since the last window close.
    win_seq: Option<(u64, u64)>,
    /// Certificate of the most recently closed window (for `explain`).
    last_certificate: Option<String>,
    /// Solve-forensics probe of the most recent window that invoked the
    /// solver — rearmed fresh per solve window so the `profile` reply
    /// never grows with daemon uptime. Like telemetry, it observes
    /// only: placements are byte-identical armed or off.
    last_prof: Probe,
    /// Window id `last_prof` recorded (None until the first solve).
    last_prof_window: Option<u64>,
    /// Delta frame built at the last close, until the serve loop claims
    /// it for watch fan-out.
    last_frame: Option<Json>,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Engine {
        Engine::with_telemetry(cfg, Telemetry::off())
    }

    /// Engine recording onto a caller-provided handle (the daemon arms
    /// a recording handle so `metrics`/`trace_export` have substance).
    pub fn with_telemetry(cfg: EngineConfig, tel: Telemetry) -> Engine {
        let state = ClusterState::new(cfg.nodes.clone(), Vec::new());
        Engine {
            state,
            session: cfg.incremental.then(SolveSession::new),
            provision_memo: None,
            tel,
            catalog: BTreeMap::new(),
            name_to_rs: BTreeMap::new(),
            next_ord: BTreeMap::new(),
            next_rs_id: 0,
            pod_names: BTreeMap::new(),
            pending_submits: Vec::new(),
            windows: 0,
            requests: 0,
            now_ms: 0,
            draining: false,
            auto_seq: 0,
            journal: Journal::new(JOURNAL_CAP),
            ctr: CounterSnapshot::default(),
            win_seq: None,
            last_certificate: None,
            last_prof: Probe::armed(),
            last_prof_window: None,
            last_frame: None,
            cfg,
        }
    }

    pub fn telemetry(&self) -> &Telemetry {
        &self.tel
    }

    pub fn draining(&self) -> bool {
        self.draining
    }

    pub fn windows_closed(&self) -> u64 {
        self.windows
    }

    /// Are any `submit` replies waiting on a window close?
    pub fn has_pending_submits(&self) -> bool {
        !self.pending_submits.is_empty()
    }

    /// How many `submit` requests the open window has gathered (the
    /// `--max-batch` early-flush counter).
    pub fn pending_submit_count(&self) -> usize {
        self.pending_submits.len()
    }

    pub fn state(&self) -> &ClusterState {
        &self.state
    }

    /// The window-close flight recorder (read-only; CLI/test surface).
    pub fn journal(&self) -> &Journal {
        &self.journal
    }

    /// Claim the delta frame built at the most recent window close, if
    /// any. The serve loop publishes it to watch subscribers; the frame
    /// is built unconditionally but costs one small Json when nobody
    /// watches.
    pub fn take_watch_frame(&mut self) -> Option<Json> {
        self.last_frame.take()
    }

    /// Solve-relevant state fingerprint (the equivalence digest).
    pub fn digest(&self) -> u64 {
        fingerprint_state(&self.state, self.cfg.p_max)
    }

    /// Count and structure a request-level failure (parse error, drain
    /// rejection) so errors ride the same counters as successes.
    pub fn error_reply(&mut self, seq: Option<u64>, tag: Option<u64>, err: &WireError) -> Json {
        self.ctr.errors += 1;
        self.tel.add("server_errors_total", &format!("code=\"{}\"", err.code()), 1);
        err.reply(seq, tag)
    }

    /// Apply one seq-stamped operation. Returns the immediate reply,
    /// or `None` for a `submit` (answered at the next window close).
    pub fn apply(&mut self, seq: u64, tag: Option<u64>, op: &WireOp) -> Option<Json> {
        self.requests += 1;
        self.ctr.requests += 1;
        self.win_seq = Some(match self.win_seq {
            None => (seq, seq),
            Some((lo, _)) => (lo, seq),
        });
        self.tel.add("server_requests_total", &format!("op=\"{}\"", op.name()), 1);
        match op {
            WireOp::Submit(spec) => self.apply_submit(seq, tag, spec),
            WireOp::Delete { pod } => Some(self.apply_delete(seq, tag, pod)),
            WireOp::Join {
                pool,
                cpu_milli,
                ram_mib,
            } => Some(self.apply_join(seq, tag, pool.as_deref(), *cpu_milli, *ram_mib)),
            WireOp::Drain { node } => Some(self.apply_drain(seq, tag, *node)),
            WireOp::Remove { node } => Some(self.apply_remove(seq, tag, *node)),
            WireOp::Query { latency } => Some(self.apply_query(seq, tag, *latency)),
            WireOp::Health { latency } => {
                let summary = latency.then(|| self.latency_summary());
                let mut o = self.base("health", seq, tag);
                o.set("ok", true)
                    .set("protocol", PROTOCOL_VERSION)
                    .set("draining", self.draining)
                    .set("windows", self.windows)
                    .set("requests", self.requests);
                if let Some(s) = summary {
                    o.set("latency", s);
                }
                Some(o)
            }
            WireOp::Journal { since, limit, wall } => {
                Some(self.apply_journal(seq, tag, *since, *limit, *wall))
            }
            WireOp::Watch => {
                // Registration happens in the serve loop (it owns the
                // sockets); the engine just acknowledges, reporting the
                // window id the stream will start after.
                let mut o = self.base("watch", seq, tag);
                o.set("subscribed", true).set("window", self.windows);
                Some(o)
            }
            WireOp::Explain { pod } => Some(self.apply_explain(seq, tag, pod)),
            WireOp::Metrics => {
                let mut o = self.base("metrics", seq, tag);
                o.set("content_type", "text/plain; version=0.0.4")
                    // detlint: allow(telemetry-feedback) — export endpoint:
                    // the bytes leave on the wire, never steer placement.
                    .set("body", self.tel.export_prometheus());
                Some(o)
            }
            WireOp::TraceExport => {
                let mut o = self.base("trace_export", seq, tag);
                // detlint: allow(telemetry-feedback) — export endpoint:
                // the bytes leave on the wire, never steer placement.
                o.set("body", self.tel.export_chrome());
                Some(o)
            }
            WireOp::Profile => {
                let mut o = self.base("profile", seq, tag);
                match self.last_prof_window {
                    Some(w) => o.set("window", w),
                    None => o.set("window", Json::Null),
                };
                // detlint: allow(telemetry-feedback) — export endpoint:
                // the bytes leave on the wire, never steer placement.
                o.set("body", self.last_prof.export_profile_json());
                Some(o)
            }
            WireOp::Shutdown => {
                self.draining = true;
                let mut o = self.base("shutdown", seq, tag);
                o.set("draining", true);
                Some(o)
            }
        }
    }

    /// Close the current solve window at virtual time `at_ms`: run the
    /// default-first/CP-fallback round over everything pending, then
    /// answer every deferred `submit` in seq order with placements and
    /// the window certificate.
    pub fn close_window_at(&mut self, at_ms: u64) -> Vec<(u64, Json)> {
        self.advance_to(at_ms);
        let submits = std::mem::take(&mut self.pending_submits);
        let placed_before: Vec<u64> = self
            .state
            .placed_per_priority(self.cfg.p_max)
            .into_iter()
            .map(|c| c as u64)
            .collect();
        let pending_before = self.state.pending_pods().len() as u64;
        let sp = self.tel.span("serve_window");
        sp.arg("window", self.windows);
        sp.arg("submits", submits.len());
        // detlint: allow(wall-clock) — window-solve latency stopwatch:
        // feeds the histograms only, never the solve.
        let started = Instant::now();
        let report = if self.state.pending_pods().is_empty() {
            None
        } else {
            let prof = Probe::armed();
            let report = self.round(&prof);
            self.last_prof = prof;
            self.last_prof_window = Some(self.windows);
            Some(report)
        };
        let wall_us = started.elapsed().as_micros() as u64;
        drop(sp);
        if report.is_some() {
            self.tel.observe_us("serve_window_solve_seconds", "", wall_us);
        }
        for sub in &submits {
            self.tel.observe_us(
                "serve_admission_seconds",
                "",
                sub.arrived.elapsed().as_micros() as u64,
            );
        }
        self.windows += 1;
        self.tel.add("server_windows_total", "", 1);
        let certificate = match &report {
            None => "default",
            Some(r) if !r.solver_invoked => "default",
            Some(r) if r.proved_optimal => "proven-optimal",
            Some(_) => "anytime",
        };
        let solver_invoked = report.as_ref().is_some_and(|r| r.solver_invoked);
        let window = self.windows - 1;
        self.last_certificate = Some(certificate.to_string());
        let (seq_lo, seq_hi) = match self.win_seq.take() {
            Some((lo, hi)) => (Some(lo), Some(hi)),
            None => (None, None),
        };
        let entry = JournalEntry {
            window,
            virtual_ms: self.now_ms,
            seq_lo,
            seq_hi,
            submits: submits.len() as u64,
            certificate: certificate.to_string(),
            solver_invoked,
            placed_before,
            placed_after: self
                .state
                .placed_per_priority(self.cfg.p_max)
                .into_iter()
                .map(|c| c as u64)
                .collect(),
            pending_before,
            pending_after: self.state.pending_pods().len() as u64,
            counters: self.ctr,
            wall_us,
        };
        let mut frame = Json::obj();
        frame
            .set("frame", "delta")
            .set("window", window)
            .set("digest", format!("{:016x}", self.digest()))
            .set("entry", entry.to_json(false));
        self.last_frame = Some(frame);
        self.journal.push(entry);
        let mut replies = Vec::with_capacity(submits.len());
        for sub in submits {
            let placements = sub
                .pods
                .iter()
                .map(|&id| {
                    let mut p = Json::obj();
                    p.set("pod", self.state.pod(id).name.as_str());
                    match self.state.assignment_of(id) {
                        Some(n) => p.set("node", self.state.node(n).name.as_str()),
                        None => p.set("node", Json::Null),
                    };
                    p
                })
                .collect();
            let mut o = self.base("submit", sub.seq, sub.tag);
            o.set("rs", sub.rs_name.as_str())
                .set("window", window)
                .set("certificate", certificate)
                .set("solver_invoked", solver_invoked)
                .set("placements", Json::Arr(placements));
            replies.push((sub.seq, o));
        }
        replies
    }

    /// Drive one whole window in-process: set the virtual clock, apply
    /// `ops` under engine-assigned seqs, close the window, and return
    /// every reply line in emission order. This is the replay/bench
    /// surface — byte-identical across runs and thread counts for the
    /// same window stream.
    pub fn run_window(&mut self, at_ms: u64, ops: &[WireOp]) -> Vec<String> {
        self.advance_to(at_ms);
        let mut lines = Vec::new();
        for op in ops {
            let seq = self.auto_seq;
            self.auto_seq += 1;
            if let Some(reply) = self.apply(seq, None, op) {
                lines.push(reply.to_string_compact());
            }
        }
        for (_, reply) in self.close_window_at(at_ms) {
            lines.push(reply.to_string_compact());
        }
        lines
    }

    // ---- op handlers ------------------------------------------------------

    fn base(&mut self, op: &str, seq: u64, tag: Option<u64>) -> Json {
        self.tel.add("server_replies_total", &format!("op=\"{op}\""), 1);
        let mut o = Json::obj();
        o.set("seq", seq).set("op", op);
        if let Some(t) = tag {
            o.set("tag", t);
        }
        o
    }

    fn apply_submit(&mut self, seq: u64, tag: Option<u64>, spec: &SubmitSpec) -> Option<Json> {
        if spec.priority > self.cfg.p_max {
            let err = WireError::BadRequest(format!(
                "priority {} exceeds p_max {}",
                spec.priority, self.cfg.p_max
            ));
            return Some(self.error_reply(Some(seq), tag, &err));
        }
        // Resolve the template: explicit id, then name, then a fresh
        // registration (first-seen template wins, like the churn
        // runner's catalog — a scale-up never re-stamps the template).
        let rs_id = match spec.rs_id {
            Some(id) => id,
            None => match self.name_to_rs.get(&spec.name) {
                Some(&id) => id,
                None => {
                    let id = self.next_rs_id;
                    self.next_rs_id += 1;
                    id
                }
            },
        };
        if let Some(&owner) = self.name_to_rs.get(&spec.name) {
            if owner != rs_id {
                let err = WireError::BadRequest(format!(
                    "name {:?} already owned by rs {}",
                    spec.name, owner
                ));
                return Some(self.error_reply(Some(seq), tag, &err));
            }
        }
        let rs = self
            .catalog
            .entry(rs_id)
            .or_insert_with(|| spec.to_replicaset(rs_id))
            .clone();
        self.name_to_rs.insert(rs.name.clone(), rs_id);
        self.next_rs_id = self.next_rs_id.max(rs_id + 1);
        let mut pods = Vec::with_capacity(spec.replicas as usize);
        for _ in 0..spec.replicas {
            let ord = self.next_ord.entry(rs_id).or_insert(0);
            let pod = rs.instantiate(0, *ord);
            *ord += 1;
            let name = pod.name.clone();
            let id = self.state.add_pod(pod);
            self.pod_names.insert(name, id);
            pods.push(id);
        }
        self.ctr.submit_pods += pods.len() as u64;
        self.tel.add("server_submit_pods_total", "", pods.len() as u64);
        self.pending_submits.push(PendingSubmit {
            seq,
            tag,
            rs_name: rs.name,
            pods,
            // detlint: allow(wall-clock) — admission-latency stamp
            // (histogram observability only)
            arrived: Instant::now(),
        });
        None
    }

    fn apply_delete(&mut self, seq: u64, tag: Option<u64>, pod: &str) -> Json {
        let Some(&id) = self.pod_names.get(pod) else {
            let err = WireError::BadRequest(format!("unknown pod {pod:?}"));
            return self.error_reply(Some(seq), tag, &err);
        };
        let mut o = self.base("delete", seq, tag);
        o.set("pod", pod);
        if self.state.is_retired(id) {
            // Mirrors the churn runner's completion of an
            // already-scaled-down pod: a silent skip, not an error.
            o.set("deleted", false).set("reason", "retired");
            return o;
        }
        // detlint: allow(panic-on-wire) — unreachable: the is_retired
        // guard above already filtered dead pods.
        let node = self.state.terminate(id).expect("live pod terminates");
        o.set("deleted", true);
        match node {
            Some(n) => o.set("node", self.state.node(n).name.as_str()),
            None => o.set("node", Json::Null),
        };
        o
    }

    fn apply_join(
        &mut self,
        seq: u64,
        tag: Option<u64>,
        pool: Option<&str>,
        cpu_milli: Option<i64>,
        ram_mib: Option<i64>,
    ) -> Json {
        let joined = match pool {
            Some(name) => {
                let Some(p) = NodePool::parse(name) else {
                    let err = WireError::BadRequest(format!("unknown pool {name:?}"));
                    return self.error_reply(Some(seq), tag, &err);
                };
                let capacity = match (cpu_milli, ram_mib) {
                    (Some(c), Some(r)) => Resources::new(c, r),
                    _ => p.capacity_for(self.cfg.reference_capacity),
                };
                self.state.join_node_from(&p.node_template_with_capacity(capacity))
            }
            None => {
                let capacity = Resources::new(
                    // detlint: allow(panic-on-wire) — the protocol layer
                    // guarantees presence when no pool is named.
                    cpu_milli.expect("validated cpu"),
                    // detlint: allow(panic-on-wire) — same guarantee
                    ram_mib.expect("validated ram"),
                );
                self.state.join_node(capacity)
            }
        };
        self.tel.add("server_joins_total", "", 1);
        let mut o = self.base("join", seq, tag);
        o.set("node", self.state.node(joined).name.as_str());
        o
    }

    fn apply_drain(&mut self, seq: u64, tag: Option<u64>, node: u32) -> Json {
        let mut o = self.base("drain", seq, tag);
        // Same skip condition as the churn runner: out-of-range or
        // not-ready drains are recorded, not errors.
        if node as usize >= self.state.nodes().len() || !self.state.node_ready(NodeId(node)) {
            o.set("drained", false).set("reason", "not-ready");
            return o;
        }
        let victims = self.state.drain(NodeId(node));
        self.tel.add("server_drains_total", "", 1);
        o.set("drained", true)
            .set("node", self.state.node(NodeId(node)).name.as_str())
            .set("evicted", victims.len() as u64);
        o
    }

    fn apply_remove(&mut self, seq: u64, tag: Option<u64>, node: u32) -> Json {
        if node as usize >= self.state.nodes().len() {
            let err = WireError::BadRequest(format!("no node at index {node}"));
            return self.error_reply(Some(seq), tag, &err);
        }
        match self.state.remove_node(NodeId(node)) {
            Ok(()) => {
                let mut o = self.base("remove", seq, tag);
                o.set("node", self.state.node(NodeId(node)).name.as_str())
                    .set("removed", true);
                o
            }
            Err(e) => {
                let err = WireError::BadRequest(format!("remove refused: {e:?}"));
                self.error_reply(Some(seq), tag, &err)
            }
        }
    }

    /// Page the journal: entries with `window >= since`, oldest first,
    /// capped at `limit`. The reply's `next` is the resume cursor; the
    /// retained range exposes ring eviction gaps to slow pollers.
    fn apply_journal(
        &mut self,
        seq: u64,
        tag: Option<u64>,
        since: Option<u64>,
        limit: Option<u64>,
        wall: bool,
    ) -> Json {
        let from = since.unwrap_or(0);
        let lim = limit.map(|l| l as usize).unwrap_or(usize::MAX);
        let page: Vec<&JournalEntry> = self.journal.since(from, lim).collect();
        let next = page.last().map(|e| e.window + 1).unwrap_or(from);
        let entries: Vec<Json> = page.iter().map(|e| e.to_json(wall)).collect();
        let (first, last) = (self.journal.first_window(), self.journal.last_window());
        let mut o = self.base("journal", seq, tag);
        o.set("entries", Json::Arr(entries)).set("next", next);
        if let Some(fw) = first {
            o.set("first_window", fw);
        }
        if let Some(lw) = last {
            o.set("last_window", lw);
        }
        o
    }

    /// Explain a pod by name: placed/retired pods report their state;
    /// a pending pod gets the per-ready-node rejection census plus the
    /// latest window certificate.
    fn apply_explain(&mut self, seq: u64, tag: Option<u64>, pod: &str) -> Json {
        let Some(&id) = self.pod_names.get(pod) else {
            let err = WireError::BadRequest(format!("unknown pod {pod:?}"));
            return self.error_reply(Some(seq), tag, &err);
        };
        let mut o = self.base("explain", seq, tag);
        o.set("pod", pod).set("tier", self.state.pod(id).priority.0);
        if self.state.is_retired(id) {
            o.set("status", "retired");
            return o;
        }
        if let Some(n) = self.state.assignment_of(id) {
            o.set("status", "placed")
                .set("node", self.state.node(n).name.as_str());
            return o;
        }
        let registry = ModuleRegistry::standard();
        let report = explain_pod(&self.state, &registry, id);
        let mut reasons = Json::obj();
        for (reason, count) in &report.tally {
            reasons.set(reason, *count as u64);
        }
        o.set("status", "pending")
            .set(
                "certificate",
                self.last_certificate.as_deref().unwrap_or("none"),
            )
            .set("ready_nodes", report.ready_nodes as u64)
            .set("feasible", report.feasible as u64)
            .set("reasons", reasons);
        o
    }

    /// Wall-clock p50/p95/p99 summary over the recorded latency
    /// histograms, in milliseconds. Non-canonical by construction: a
    /// client only sees it after opting in with `"latency":true`, and
    /// it renders `null` when telemetry is off.
    fn latency_summary(&self) -> Json {
        if !self.tel.enabled() {
            return Json::Null;
        }
        // detlint: allow(telemetry-feedback) — opt-in latency summary:
        // explicitly non-canonical, reply-only, never read by the engine.
        let hists = self.tel.histograms();
        let mut o = Json::obj();
        for (key, metric) in [
            ("admission", "serve_admission_seconds"),
            ("race_task", "race_task_seconds"),
            ("window_solve", "serve_window_solve_seconds"),
        ] {
            let h = hists.total(metric);
            let mut m = Json::obj();
            m.set("count", h.count())
                .set("p50_ms", h.quantile_us(0.50) / 1000.0)
                .set("p95_ms", h.quantile_us(0.95) / 1000.0)
                .set("p99_ms", h.quantile_us(0.99) / 1000.0);
            o.set(key, m);
        }
        o
    }

    fn apply_query(&mut self, seq: u64, tag: Option<u64>, latency: bool) -> Json {
        let (cpu, ram) = self.state.utilization();
        let placed = self
            .state
            .placed_per_priority(self.cfg.p_max)
            .into_iter()
            .map(|c| Json::from(c as u64))
            .collect();
        let ready = self
            .state
            .nodes()
            .iter()
            .filter(|n| self.state.node_ready(n.id))
            .count();
        let digest = self.digest();
        let mut o = self.base("query", seq, tag);
        o.set("windows", self.windows)
            .set("virtual_ms", self.now_ms)
            .set("nodes", self.state.nodes().len() as u64)
            .set("ready_nodes", ready as u64)
            .set("pods", self.state.pods().len() as u64)
            .set("placed", Json::Arr(placed))
            .set("pending", self.state.pending_pods().len() as u64)
            .set("cpu_util", cpu)
            .set("ram_util", ram)
            .set("digest", format!("{digest:016x}"));
        if latency {
            o.set("latency", self.latency_summary());
        }
        o
    }

    // ---- scheduling -------------------------------------------------------

    fn advance_to(&mut self, at_ms: u64) {
        if at_ms > self.now_ms {
            self.now_ms = at_ms;
            self.state.set_time(at_ms);
        }
    }

    /// One fallback scheduling round — the churn runner's
    /// `schedule_round` arm, verbatim: rebuild the scheduler, carry the
    /// session and the provision memo.
    fn round(&mut self, prof: &Probe) -> RunReport {
        let mut osched = OptimizingScheduler::new(
            self.cfg.p_max,
            OptimizerConfig {
                total_timeout: self.cfg.solve_timeout,
                portfolio: PortfolioConfig::with_threads(self.cfg.threads),
                autoscale: self.cfg.autoscale.clone(),
                ..Default::default()
            },
        );
        osched.set_provision_memo(self.provision_memo.take());
        let report = osched.run_with_session_probed(
            &mut self.state,
            self.session.as_mut(),
            &self.tel,
            prof,
        );
        self.provision_memo = osched.take_provision_memo();
        if report.solver_invoked {
            self.ctr.solver_invocations += 1;
            self.tel.add("server_solver_invocations_total", "", 1);
        }
        if report.autoscale.is_some() {
            self.ctr.scale_ups += 1;
            self.tel.add("server_scale_ups_total", "", 1);
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn engine() -> Engine {
        Engine::new(EngineConfig {
            p_max: 0,
            nodes: identical_nodes(2, Resources::new(4000, 4096)),
            solve_timeout: Duration::from_secs(5),
            ..EngineConfig::default()
        })
    }

    #[test]
    fn figure_one_batch_gets_certified_placements() {
        let mut e = engine();
        // 2Gi + 2Gi + 3Gi over two 4Gi nodes: LeastAllocated spreads the
        // 2Gi pods across both nodes and strands the 3Gi pod; the window
        // solve re-packs all three and proves it.
        let lines = e.run_window(
            1_000,
            &[
                WireOp::Submit(SubmitSpec::basic("web", 2, 100, 2048, 0)),
                WireOp::Submit(SubmitSpec::basic("db", 1, 100, 3072, 0)),
            ],
        );
        assert_eq!(lines.len(), 2, "one deferred reply per submit");
        for line in &lines {
            let reply = parse(line).expect("reply parses");
            assert_eq!(reply.get("op").and_then(Json::as_str), Some("submit"));
            assert_eq!(
                reply.get("certificate").and_then(Json::as_str),
                Some("proven-optimal"),
                "{line}"
            );
            let placements = reply.get("placements").and_then(Json::as_arr).expect("arr");
            for p in placements {
                assert!(p.get("node").and_then(Json::as_str).is_some(), "{line}");
            }
        }
    }

    #[test]
    fn replies_carry_seq_and_tag_and_errors_are_structured() {
        let mut e = engine();
        let r = e
            .apply(7, Some(99), &WireOp::Health { latency: false })
            .expect("immediate");
        assert_eq!(r.get("seq").and_then(Json::as_i64), Some(7));
        assert_eq!(r.get("tag").and_then(Json::as_i64), Some(99));
        let err = e.apply(
            8,
            None,
            &WireOp::Submit(SubmitSpec::basic("hi", 1, 100, 100, 3)),
        );
        let err = err.expect("priority above p_max fails immediately");
        assert_eq!(
            err.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("bad-request")
        );
    }

    #[test]
    fn delete_then_redundant_delete() {
        let mut e = engine();
        let lines = e.run_window(0, &[WireOp::Submit(SubmitSpec::basic("web", 1, 100, 128, 0))]);
        assert_eq!(lines.len(), 1);
        let del = e
            .apply(10, None, &WireOp::Delete { pod: "web-0".into() })
            .expect("immediate");
        assert_eq!(del.get("deleted").and_then(Json::as_bool), Some(true));
        let again = e
            .apply(11, None, &WireOp::Delete { pod: "web-0".into() })
            .expect("immediate");
        assert_eq!(again.get("deleted").and_then(Json::as_bool), Some(false));
        assert_eq!(again.get("reason").and_then(Json::as_str), Some("retired"));
    }

    #[test]
    fn query_reports_digest_and_counts() {
        let mut e = engine();
        e.run_window(0, &[WireOp::Submit(SubmitSpec::basic("web", 2, 100, 128, 0))]);
        let q = e
            .apply(5, None, &WireOp::Query { latency: false })
            .expect("immediate");
        assert_eq!(q.get("pods").and_then(Json::as_i64), Some(2));
        assert_eq!(q.get("pending").and_then(Json::as_i64), Some(0));
        let digest = q.get("digest").and_then(Json::as_str).expect("digest");
        assert_eq!(digest, format!("{:016x}", e.digest()));
        // The canonical query carries no latency block; asking for one
        // without telemetry renders an explicit null.
        assert!(q.get("latency").is_none());
        let q2 = e
            .apply(6, None, &WireOp::Query { latency: true })
            .expect("immediate");
        assert_eq!(q2.get("latency"), Some(&Json::Null));
    }

    #[test]
    fn window_closes_record_journal_entries_and_frames() {
        let mut e = engine();
        e.run_window(
            1_000,
            &[WireOp::Submit(SubmitSpec::basic("web", 2, 100, 128, 0))],
        );
        e.run_window(2_000, &[]);
        assert_eq!(e.journal().len(), 2);
        let entries: Vec<_> = e.journal().since(0, 100).collect();
        assert_eq!(entries[0].window, 0);
        assert_eq!(entries[0].submits, 1);
        assert_eq!(entries[0].pending_before, 2);
        assert_eq!(entries[0].pending_after, 0);
        assert_eq!(entries[0].counters.submit_pods, 2);
        assert_eq!(entries[0].seq_lo, Some(0));
        // The timer-only window has no seq range and no submits.
        assert_eq!(entries[1].window, 1);
        assert_eq!(entries[1].submits, 0);
        assert_eq!(entries[1].seq_lo, None);
        // The latest close leaves one claimable delta frame.
        let frame = e.take_watch_frame().expect("frame");
        assert_eq!(frame.get("frame").and_then(Json::as_str), Some("delta"));
        assert_eq!(frame.get("window").and_then(Json::as_i64), Some(1));
        assert_eq!(
            frame.get("digest").and_then(Json::as_str),
            Some(format!("{:016x}", e.digest()).as_str())
        );
        assert!(frame.get("entry").is_some());
        assert!(e.take_watch_frame().is_none(), "frames claim once");
        // The journal op pages with a resume cursor.
        let page = e
            .apply(
                20,
                None,
                &WireOp::Journal {
                    since: Some(1),
                    limit: None,
                    wall: false,
                },
            )
            .expect("immediate");
        let got = page.get("entries").and_then(Json::as_arr).expect("arr");
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].get("window").and_then(Json::as_i64), Some(1));
        assert_eq!(page.get("next").and_then(Json::as_i64), Some(2));
        assert!(!page.to_string_compact().contains("wall_us"));
    }

    #[test]
    fn profile_op_exports_the_last_solve_windows_forensics() {
        let mut e = engine();
        // Before any solve: a schema-valid empty document, null window.
        let empty = e.apply(1, None, &WireOp::Profile).expect("immediate");
        assert_eq!(empty.get("window"), Some(&Json::Null));
        let body = empty.get("body").and_then(Json::as_str).expect("body");
        assert!(body.contains(crate::solver::PROFILE_SCHEMA));
        // A window that strands a pod invokes the solver and records.
        e.run_window(
            1_000,
            &[
                WireOp::Submit(SubmitSpec::basic("web", 2, 100, 2048, 0)),
                WireOp::Submit(SubmitSpec::basic("db", 1, 100, 3072, 0)),
            ],
        );
        let r = e.apply(2, None, &WireOp::Profile).expect("immediate");
        assert_eq!(r.get("window").and_then(Json::as_i64), Some(0));
        let body = r.get("body").and_then(Json::as_str).expect("body");
        let doc = parse(body).expect("profile document parses");
        assert_eq!(
            doc.get("schema").and_then(Json::as_str),
            Some(crate::solver::PROFILE_SCHEMA)
        );
        let modules = doc.get("modules").and_then(Json::as_arr).expect("modules");
        assert!(!modules.is_empty(), "solve must attribute effort");
        // A later timer-only window (no pending pods) keeps the last
        // solve's profile instead of blanking it.
        e.run_window(2_000, &[]);
        let again = e.apply(3, None, &WireOp::Profile).expect("immediate");
        assert_eq!(again.get("window").and_then(Json::as_i64), Some(0));
    }

    #[test]
    fn explain_reports_placement_state_and_rejection_census() {
        let mut e = engine();
        // Two 4Gi nodes; a 3Gi pod lands, then a 6Gi pod cannot fit
        // anywhere — explain must cover both ready nodes with reasons.
        e.run_window(
            1_000,
            &[WireOp::Submit(SubmitSpec::basic("web", 1, 100, 3072, 0))],
        );
        e.run_window(
            2_000,
            &[WireOp::Submit(SubmitSpec::basic("big", 1, 100, 6144, 0))],
        );
        let placed = e
            .apply(30, None, &WireOp::Explain { pod: "web-0".into() })
            .expect("immediate");
        assert_eq!(placed.get("status").and_then(Json::as_str), Some("placed"));
        assert!(placed.get("node").and_then(Json::as_str).is_some());
        let pending = e
            .apply(31, None, &WireOp::Explain { pod: "big-0".into() })
            .expect("immediate");
        assert_eq!(pending.get("status").and_then(Json::as_str), Some("pending"));
        assert_eq!(pending.get("ready_nodes").and_then(Json::as_i64), Some(2));
        assert_eq!(pending.get("feasible").and_then(Json::as_i64), Some(0));
        let reasons = pending.get("reasons").expect("reasons");
        assert_eq!(
            reasons.get("insufficient-ram").and_then(Json::as_i64),
            Some(2)
        );
        assert!(pending.get("certificate").and_then(Json::as_str).is_some());
        let missing = e
            .apply(32, None, &WireOp::Explain { pod: "ghost-0".into() })
            .expect("immediate");
        assert_eq!(
            missing
                .get("error")
                .and_then(|e| e.get("code"))
                .and_then(Json::as_str),
            Some("bad-request")
        );
    }
}
