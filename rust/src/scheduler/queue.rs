//! The scheduling queue.
//!
//! Kubernetes keeps three sub-queues: *active* (ready to schedule),
//! *backoff* (retry later), and *unschedulable* (parked until a cluster
//! event might make them feasible). This model keeps active +
//! unschedulable (the simulator is event-driven, so a timed backoff
//! queue would only add noise — unschedulable pods are re-activated
//! explicitly via [`SchedulingQueue::flush_unschedulable`], which is what
//! a cluster event does in Kubernetes).
//!
//! Ordering follows the default `PrioritySort` QueueSort plugin: highest
//! priority first (numerically lowest, per the paper's convention), FIFO
//! within a priority. The queue also supports the optimiser's *pause*
//! (paper: "during solver execution, new pods arriving in the scheduling
//! queue are temporarily paused ... re-queued once the solver execution
//! completes").

use crate::cluster::{PodId, Priority};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Entry {
    pod: PodId,
    priority: Priority,
    seq: u64,
}

/// Priority scheduling queue with pause support.
#[derive(Debug, Default)]
pub struct SchedulingQueue {
    active: Vec<Entry>,
    unschedulable: Vec<Entry>,
    paused_arrivals: Vec<Entry>,
    paused: bool,
    next_seq: u64,
}

impl SchedulingQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue a pod. While paused, arrivals are parked on the side list.
    pub fn push(&mut self, pod: PodId, priority: Priority) {
        let e = Entry {
            pod,
            priority,
            seq: self.next_seq,
        };
        self.next_seq += 1;
        if self.paused {
            self.paused_arrivals.push(e);
        } else {
            self.active.push(e);
        }
    }

    /// Pop the next pod to schedule: min (priority, seq). `None` when the
    /// active queue is empty or the queue is paused.
    pub fn pop(&mut self) -> Option<PodId> {
        if self.paused || self.active.is_empty() {
            return None;
        }
        let best = self
            .active
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| (e.priority, e.seq))
            .map(|(i, _)| i)
            .unwrap();
        Some(self.active.swap_remove(best).pod)
    }

    /// Park a pod as unschedulable (failed its scheduling cycle).
    pub fn mark_unschedulable(&mut self, pod: PodId, priority: Priority) {
        let e = Entry {
            pod,
            priority,
            seq: self.next_seq,
        };
        self.next_seq += 1;
        self.unschedulable.push(e);
    }

    /// Move all unschedulable pods back to active (a "cluster event").
    pub fn flush_unschedulable(&mut self) -> usize {
        let n = self.unschedulable.len();
        self.active.append(&mut self.unschedulable);
        n
    }

    /// Pause scheduling (optimiser running). Arrivals are buffered.
    pub fn pause(&mut self) {
        self.paused = true;
    }

    /// Resume after the optimiser: buffered arrivals re-queued in order.
    pub fn resume(&mut self) {
        self.paused = false;
        self.active.append(&mut self.paused_arrivals);
    }

    pub fn is_paused(&self) -> bool {
        self.paused
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn unschedulable_len(&self) -> usize {
        self.unschedulable.len()
    }

    /// Pods currently parked as unschedulable (id order of arrival).
    pub fn unschedulable_pods(&self) -> Vec<PodId> {
        self.unschedulable.iter().map(|e| e.pod).collect()
    }

    pub fn is_drained(&self) -> bool {
        self.active.is_empty() && self.paused_arrivals.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_then_fifo() {
        let mut q = SchedulingQueue::new();
        q.push(PodId(0), Priority(2));
        q.push(PodId(1), Priority(0));
        q.push(PodId(2), Priority(0));
        q.push(PodId(3), Priority(1));
        assert_eq!(q.pop(), Some(PodId(1))); // highest prio, first in
        assert_eq!(q.pop(), Some(PodId(2))); // FIFO within prio 0
        assert_eq!(q.pop(), Some(PodId(3)));
        assert_eq!(q.pop(), Some(PodId(0)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn unschedulable_flush() {
        let mut q = SchedulingQueue::new();
        q.mark_unschedulable(PodId(5), Priority(1));
        q.mark_unschedulable(PodId(6), Priority(0));
        assert_eq!(q.pop(), None);
        assert_eq!(q.unschedulable_pods(), vec![PodId(5), PodId(6)]);
        assert_eq!(q.flush_unschedulable(), 2);
        assert_eq!(q.pop(), Some(PodId(6))); // priority order restored
        assert_eq!(q.pop(), Some(PodId(5)));
    }

    #[test]
    fn pause_buffers_arrivals() {
        let mut q = SchedulingQueue::new();
        q.push(PodId(0), Priority(0));
        q.pause();
        q.push(PodId(1), Priority(0)); // arrives during solver run
        assert_eq!(q.pop(), None); // paused: nothing schedulable
        assert!(q.is_paused());
        q.resume();
        assert_eq!(q.pop(), Some(PodId(0)));
        assert_eq!(q.pop(), Some(PodId(1)));
        assert!(q.is_drained());
    }

    #[test]
    fn drained_accounts_for_paused_arrivals() {
        let mut q = SchedulingQueue::new();
        q.pause();
        q.push(PodId(9), Priority(0));
        assert!(!q.is_drained());
        q.resume();
        assert!(!q.is_drained());
        q.pop();
        assert!(q.is_drained());
    }
}
