//! `TaintToleration` — Filter plugin mirroring the
//! [`TaintsTolerations`](crate::optimizer::constraints::TaintsTolerations)
//! constraint module: a node with an untolerated `NoSchedule` taint is
//! infeasible for the pod. Taint-free clusters make it a no-op.

use crate::cluster::{ClusterState, NodeId, PodId};
use crate::scheduler::framework::{CycleContext, FilterPlugin};

#[derive(Default)]
pub struct TaintToleration;

impl FilterPlugin for TaintToleration {
    fn filter(&self, state: &ClusterState, pod: PodId, node: NodeId, _ctx: &CycleContext) -> bool {
        state.pod(pod).tolerates(state.node(node))
    }

    fn name(&self) -> &'static str {
        "TaintToleration"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Pod, Priority, Resources, Taint, Toleration};

    #[test]
    fn untolerated_taint_filters_node() {
        let mut nodes = identical_nodes(2, Resources::new(1000, 1000));
        nodes[0] = nodes[0]
            .clone()
            .with_taint(Taint::no_schedule("dedicated", "batch"));
        let pods = vec![
            Pod::new(0, "plain", Resources::new(1, 1), Priority(0)),
            Pod::new(1, "tolerant", Resources::new(1, 1), Priority(0))
                .with_toleration(Toleration::equal("dedicated", "batch")),
        ];
        let st = ClusterState::new(nodes, pods);
        let f = TaintToleration;
        let ctx = CycleContext::default();
        assert!(!f.filter(&st, PodId(0), NodeId(0), &ctx));
        assert!(f.filter(&st, PodId(0), NodeId(1), &ctx));
        assert!(f.filter(&st, PodId(1), NodeId(0), &ctx));
    }
}
