//! `NodeResourcesFit` — the default resource-feasibility Filter plugin:
//! CPU/RAM *and* extended (named) resources, plus node-selector matching
//! (labels are the paper's future-work extension; empty selectors and
//! extended requests make both checks no-ops for paper workloads). It
//! mirrors the [`NodeCapacity`](crate::optimizer::constraints::NodeCapacity)
//! and [`NodeSelector`](crate::optimizer::constraints::NodeSelector)
//! constraint modules.

use crate::cluster::{ClusterState, NodeId, PodId};
use crate::scheduler::framework::{CycleContext, FilterPlugin};

#[derive(Default)]
pub struct NodeResourcesFit;

impl FilterPlugin for NodeResourcesFit {
    fn filter(&self, state: &ClusterState, pod: PodId, node: NodeId, _ctx: &CycleContext) -> bool {
        let p = state.pod(pod);
        state.node_ready(node)
            && p.request.fits_in(&state.free(node))
            && state.extended_fits(pod, node)
            && p.selector_matches(state.node(node))
    }

    fn name(&self) -> &'static str {
        "NodeResourcesFit"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Pod, Priority, Resources};

    #[test]
    fn filters_by_free_capacity() {
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "big", Resources::new(900, 100), Priority(0)),
            Pod::new(1, "huge", Resources::new(1100, 100), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        let f = NodeResourcesFit;
        let ctx = CycleContext::default();
        assert!(f.filter(&st, PodId(0), NodeId(0), &ctx));
        assert!(!f.filter(&st, PodId(1), NodeId(0), &ctx)); // over capacity
        st.bind(PodId(0), NodeId(0)).unwrap();
        // node 0 now has 100 cpu free: pod of 900 no longer fits
        assert!(!f.filter(&st, PodId(0), NodeId(0), &ctx) || st.free(NodeId(0)).cpu >= 900);
    }

    #[test]
    fn filters_by_extended_resources() {
        let mut nodes = identical_nodes(2, Resources::new(1000, 1000));
        nodes[1] = nodes[1].clone().with_extended("gpu", 1);
        let pods = vec![
            Pod::new(0, "gpu-1", Resources::new(1, 1), Priority(0)).with_extended("gpu", 1),
            Pod::new(1, "gpu-2", Resources::new(1, 1), Priority(0)).with_extended("gpu", 1),
        ];
        let mut st = ClusterState::new(nodes, pods);
        let f = NodeResourcesFit;
        let ctx = CycleContext::default();
        assert!(!f.filter(&st, PodId(0), NodeId(0), &ctx)); // no gpu at all
        assert!(f.filter(&st, PodId(0), NodeId(1), &ctx));
        st.bind(PodId(0), NodeId(1)).unwrap();
        assert!(!f.filter(&st, PodId(1), NodeId(1), &ctx)); // gpu exhausted
    }

    #[test]
    fn respects_selector() {
        let mut nodes = identical_nodes(1, Resources::new(1000, 1000));
        nodes[0] = nodes[0].clone().with_label("zone", "a");
        let pods = vec![
            Pod::new(0, "z-a", Resources::new(1, 1), Priority(0)).with_selector("zone", "a"),
            Pod::new(1, "z-b", Resources::new(1, 1), Priority(0)).with_selector("zone", "b"),
        ];
        let st = ClusterState::new(nodes, pods);
        let f = NodeResourcesFit;
        let ctx = CycleContext::default();
        assert!(f.filter(&st, PodId(0), NodeId(0), &ctx));
        assert!(!f.filter(&st, PodId(1), NodeId(0), &ctx));
    }
}
