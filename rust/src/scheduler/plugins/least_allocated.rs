//! `LeastAllocated` scoring — kube-scheduler's default strategy, and the
//! exact formula the L1 Pallas kernel computes in batch
//! (`python/compile/kernels/ref.py` is the shared oracle):
//!
//! ```text
//! score(pod, node) = 100 * mean_r( (free_r - req_r) / max(cap_r, 1) )
//! ```
//!
//! Spreads pods across nodes — precisely the behaviour that produces the
//! paper's Figure 1 fragmentation and motivates the optimiser.

use crate::cluster::{ClusterState, NodeId, PodId};
use crate::scheduler::framework::ScorePlugin;

#[derive(Default)]
pub struct LeastAllocated;

impl LeastAllocated {
    /// The scalar formula; kept public so the native batch scorer and the
    /// XLA-parity tests share one definition. Computed in f32 to match
    /// the kernel bit-for-bit.
    pub fn formula(free_cpu: f32, free_ram: f32, cap_cpu: f32, cap_ram: f32, req_cpu: f32, req_ram: f32) -> f32 {
        let rem_cpu = free_cpu - req_cpu;
        let rem_ram = free_ram - req_ram;
        if rem_cpu < 0.0 || rem_ram < 0.0 {
            return -1.0; // infeasible marker (matches kernel INFEASIBLE)
        }
        let c = rem_cpu / cap_cpu.max(1.0);
        let r = rem_ram / cap_ram.max(1.0);
        100.0 * ((c + r) / 2.0)
    }
}

impl ScorePlugin for LeastAllocated {
    fn score(&self, state: &ClusterState, pod: PodId, node: NodeId) -> f64 {
        let req = state.pod(pod).request;
        let free = state.free(node);
        let cap = state.node(node).capacity;
        Self::formula(
            free.cpu as f32,
            free.ram as f32,
            cap.cpu as f32,
            cap.ram as f32,
            req.cpu as f32,
            req.ram as f32,
        ) as f64
    }

    fn name(&self) -> &'static str {
        "NodeResourcesLeastAllocated"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Pod, Priority, Resources};

    #[test]
    fn emptier_node_scores_higher() {
        let nodes = identical_nodes(2, Resources::new(4000, 4000));
        let pods = vec![
            Pod::new(0, "a", Resources::new(2000, 2000), Priority(0)),
            Pod::new(1, "b", Resources::new(1000, 1000), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        let s = LeastAllocated;
        // node-001 is empty -> more free after placement -> higher score
        assert!(s.score(&st, PodId(1), NodeId(1)) > s.score(&st, PodId(1), NodeId(0)));
    }

    #[test]
    fn formula_matches_kernel_reference_cases() {
        // Mirror of python test: pod (500,500), node free (600,600), cap (1000,1000)
        let v = LeastAllocated::formula(600.0, 600.0, 1000.0, 1000.0, 500.0, 500.0);
        assert!((v - 10.0).abs() < 1e-6); // (100/1000 + 100/1000)/2 * 100 = 10
        // infeasible
        assert_eq!(LeastAllocated::formula(600.0, 600.0, 1000.0, 1000.0, 9000.0, 100.0), -1.0);
        // exact fit -> 0
        assert_eq!(LeastAllocated::formula(1000.0, 2000.0, 4000.0, 4000.0, 1000.0, 2000.0), 0.0);
        // zero-capacity guard
        let g = LeastAllocated::formula(0.0, 0.0, 0.0, 0.0, 0.0, 0.0);
        assert!(g.is_finite() && g == 0.0);
    }

    #[test]
    fn empty_node_scores_100_for_zero_request() {
        let v = LeastAllocated::formula(1000.0, 1000.0, 1000.0, 1000.0, 0.0, 0.0);
        assert_eq!(v, 100.0);
    }
}
