//! `PrioritySort` — the default QueueSort plugin: highest priority first
//! (lowest numeric value under the paper's convention), FIFO within a
//! tier (the tie-break is the queue's enqueue sequence).

use crate::cluster::{ClusterState, PodId};
use crate::scheduler::framework::QueueSortPlugin;

#[derive(Default)]
pub struct PrioritySort;

impl QueueSortPlugin for PrioritySort {
    fn less(&self, state: &ClusterState, a: PodId, b: PodId) -> bool {
        state.pod(a).priority < state.pod(b).priority
    }

    fn name(&self) -> &'static str {
        "PrioritySort"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Pod, Priority, Resources};

    #[test]
    fn higher_priority_sorts_first() {
        let st = ClusterState::new(
            identical_nodes(1, Resources::new(1, 1)),
            vec![
                Pod::new(0, "lo", Resources::ZERO, Priority(3)),
                Pod::new(1, "hi", Resources::ZERO, Priority(0)),
            ],
        );
        let p = PrioritySort;
        assert!(p.less(&st, PodId(1), PodId(0)));
        assert!(!p.less(&st, PodId(0), PodId(1)));
        assert!(!p.less(&st, PodId(0), PodId(0))); // irreflexive
    }
}
