//! `TopologySpread` — constraint filter mirroring the
//! [`TopologySpread`](crate::optimizer::constraints::TopologySpread)
//! constraint module: placing the pod on the candidate node must keep
//! its owner group's replica-count skew within the declared maximum.
//!
//! The candidate domain matches the CP module's: every node the group
//! could in principle be placed on (ready, selector- and
//! taint-admissible for the group's uniform template) plus every node
//! already hosting a group member. Note this honours taints when
//! counting domains (Kubernetes' `nodeTaintsPolicy: Honor`), which is
//! what keeps the filter and the CP model in agreement.
//!
//! Unlike the per-pod filters, spread is order-sensitive: a sequence of
//! individually-accepted placements can dead-end where a joint packing
//! exists — exactly the gap the CP fallback closes. Two consequences:
//!
//! * **Plan-pinned placements are exempt.** A pod pinned to a node by
//!   the optimiser's plan (`ctx.pinned_node`) is part of a
//!   whole-assignment the CP model already validated; the intermediate
//!   states a multi-pod plan passes through may be transiently skewed,
//!   and rejecting them would abort feasible plans.
//! * **Counts are computed once per scheduling cycle.** The group's
//!   per-node counts depend only on (state, pod), not the candidate
//!   node, so the PreFilter hook caches them in the [`CycleContext`]
//!   instead of rescanning every pod for each of the N candidates.

use crate::cluster::{ClusterState, NodeId, PodId};
use crate::scheduler::framework::{CycleContext, FilterPlugin, PluginDecision, PreFilterPlugin};

#[derive(Default)]
pub struct TopologySpread;

/// Per-node bound-replica counts of `owner`'s group.
fn group_counts(state: &ClusterState, owner: u32) -> Vec<i64> {
    let mut count = vec![0i64; state.nodes().len()];
    for q in state.pods() {
        if q.owner == Some(owner) {
            if let Some(n) = state.assignment_of(q.id) {
                count[n.idx()] += 1;
            }
        }
    }
    count
}

impl PreFilterPlugin for TopologySpread {
    fn pre_filter(
        &mut self,
        state: &ClusterState,
        pod: PodId,
        ctx: &mut CycleContext,
    ) -> PluginDecision {
        let p = state.pod(pod);
        if let (Some(owner), Some(_)) = (p.owner, p.spread_max_skew) {
            ctx.spread_counts = Some(group_counts(state, owner));
        }
        PluginDecision::Allow
    }

    fn name(&self) -> &'static str {
        "TopologySpread"
    }
}

impl FilterPlugin for TopologySpread {
    fn filter(&self, state: &ClusterState, pod: PodId, node: NodeId, ctx: &CycleContext) -> bool {
        let p = state.pod(pod);
        let (Some(owner), Some(skew)) = (p.owner, p.spread_max_skew) else {
            return true;
        };
        if ctx.pinned_node == Some(node) {
            return true; // plan placement: the whole target is CP-validated
        }

        let computed;
        let count: &[i64] = match &ctx.spread_counts {
            Some(c) => c, // cached by the PreFilter hook
            None => {
                computed = group_counts(state, owner);
                &computed
            }
        };

        // Candidate domain: nodes the group's (uniform) template could
        // be newly placed on, plus nodes already hosting a member.
        let candidate = count[node.idx()] + 1;
        let min = state
            .nodes()
            .iter()
            .filter(|n| {
                count[n.id.idx()] > 0
                    || (state.node_ready(n.id) && p.selector_matches(n) && p.tolerates(n))
            })
            .map(|n| {
                if n.id == node {
                    candidate
                } else {
                    count[n.id.idx()]
                }
            })
            .min()
            .unwrap_or(0);

        candidate - min <= skew
    }

    fn name(&self) -> &'static str {
        "TopologySpread"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Pod, Priority, Resources};

    fn group_pod(id: u32, name: &str) -> Pod {
        Pod::new(id, name, Resources::new(100, 100), Priority(0))
            .with_owner(7)
            .with_spread(1)
    }

    #[test]
    fn skew_limit_blocks_piling_up() {
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![group_pod(0, "g-0"), group_pod(1, "g-1"), group_pod(2, "g-2")];
        let mut st = ClusterState::new(nodes, pods);
        let f = TopologySpread;
        let ctx = CycleContext::default();
        // first replica anywhere: counts (1,0), skew 1
        assert!(f.filter(&st, PodId(0), NodeId(0), &ctx));
        st.bind(PodId(0), NodeId(0)).unwrap();
        // second on the same node: (2,0) → skew 2 > 1
        assert!(!f.filter(&st, PodId(1), NodeId(0), &ctx));
        assert!(f.filter(&st, PodId(1), NodeId(1), &ctx));
        st.bind(PodId(1), NodeId(1)).unwrap();
        // third anywhere: (2,1) → skew 1
        assert!(f.filter(&st, PodId(2), NodeId(0), &ctx));
    }

    #[test]
    fn pinned_placement_bypasses_skew() {
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![group_pod(0, "g-0"), group_pod(1, "g-1"), group_pod(2, "g-2")];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        let f = TopologySpread;
        let mut ctx = CycleContext::default();
        // transiently skewed (2,0) placement: rejected unpinned …
        assert!(!f.filter(&st, PodId(1), NodeId(0), &ctx));
        // … but a plan pin means the CP model validated the full target
        ctx.pinned_node = Some(NodeId(0));
        assert!(f.filter(&st, PodId(1), NodeId(0), &ctx));
        // the pin only exempts its own node
        assert!(f.filter(&st, PodId(1), NodeId(1), &ctx));
    }

    #[test]
    fn pre_filter_caches_group_counts() {
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![group_pod(0, "g-0"), group_pod(1, "g-1")];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(1)).unwrap();
        let mut f = TopologySpread;
        let mut ctx = CycleContext::default();
        assert_eq!(
            PreFilterPlugin::pre_filter(&mut f, &st, PodId(1), &mut ctx),
            PluginDecision::Allow
        );
        assert_eq!(ctx.spread_counts, Some(vec![0, 1]));
        // the cached counts drive the same verdicts as a fresh scan
        assert!(f.filter(&st, PodId(1), NodeId(0), &ctx));
        assert!(!f.filter(&st, PodId(1), NodeId(1), &ctx));
    }

    #[test]
    fn pods_without_spread_pass() {
        let nodes = identical_nodes(1, Resources::new(1000, 1000));
        let pods = vec![Pod::new(0, "p", Resources::new(1, 1), Priority(0))];
        let st = ClusterState::new(nodes, pods);
        assert!(TopologySpread.filter(&st, PodId(0), NodeId(0), &CycleContext::default()));
    }

    #[test]
    fn unready_empty_nodes_leave_the_domain() {
        // With node 1 cordoned and empty, the domain is just node 0 —
        // so stacking replicas there is fine (min tracks the candidate).
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![group_pod(0, "g-0"), group_pod(1, "g-1")];
        let mut st = ClusterState::new(nodes, pods);
        st.cordon(NodeId(1));
        let f = TopologySpread;
        let ctx = CycleContext::default();
        st.bind(PodId(0), NodeId(0)).unwrap();
        assert!(f.filter(&st, PodId(1), NodeId(0), &ctx));
    }
}
