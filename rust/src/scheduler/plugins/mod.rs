//! Default scheduler plugins (the paper's deterministic profile), plus
//! the constraint filters mirroring the optimiser's constraint modules
//! (`optimizer::constraints`) — one Filter plugin per module, so the
//! default scheduler and the CP model agree on single-pod feasibility.

pub mod inter_pod_anti_affinity;
pub mod least_allocated;
pub mod node_resources_fit;
pub mod priority_sort;
pub mod taint_toleration;
pub mod topology_spread;

pub use inter_pod_anti_affinity::InterPodAntiAffinity;
pub use least_allocated::LeastAllocated;
pub use node_resources_fit::NodeResourcesFit;
pub use priority_sort::PrioritySort;
pub use taint_toleration::TaintToleration;
pub use topology_spread::TopologySpread;
