//! Default scheduler plugins (the paper's deterministic profile).

pub mod least_allocated;
pub mod node_resources_fit;
pub mod priority_sort;

pub use least_allocated::LeastAllocated;
pub use node_resources_fit::NodeResourcesFit;
pub use priority_sort::PrioritySort;
