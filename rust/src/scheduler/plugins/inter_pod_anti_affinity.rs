//! `InterPodAntiAffinity` — Filter plugin mirroring the
//! [`PodAntiAffinity`](crate::optimizer::constraints::PodAntiAffinity)
//! constraint module. Like the Kubernetes InterPodAffinity filter it
//! checks *both* directions: the incoming pod's anti-affinity against
//! every resident of the node, and every resident's anti-affinity
//! against the incoming pod.

use crate::cluster::{ClusterState, NodeId, PodId};
use crate::scheduler::framework::{CycleContext, FilterPlugin};

#[derive(Default)]
pub struct InterPodAntiAffinity;

impl FilterPlugin for InterPodAntiAffinity {
    fn filter(&self, state: &ClusterState, pod: PodId, node: NodeId, _ctx: &CycleContext) -> bool {
        let p = state.pod(pod);
        state.pods_on(node).iter().all(|&q| {
            let other = state.pod(q);
            !(p.anti_affine_with(other) || other.anti_affine_with(p))
        })
    }

    fn name(&self) -> &'static str {
        "InterPodAntiAffinity"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Pod, Priority, Resources};

    #[test]
    fn blocks_colocation_in_both_directions() {
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "a", Resources::new(1, 1), Priority(0))
                .with_label("app", "x")
                .with_anti_affinity("app", "x"),
            Pod::new(1, "b", Resources::new(1, 1), Priority(0)).with_label("app", "x"),
            Pod::new(2, "c", Resources::new(1, 1), Priority(0)).with_label("app", "y"),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        let f = InterPodAntiAffinity;
        let ctx = CycleContext::default();
        // b carries the label a excludes — resident's anti-affinity fires
        assert!(!f.filter(&st, PodId(1), NodeId(0), &ctx));
        assert!(f.filter(&st, PodId(1), NodeId(1), &ctx));
        // c's label is not excluded
        assert!(f.filter(&st, PodId(2), NodeId(0), &ctx));
    }

    #[test]
    fn incoming_pods_anti_affinity_fires_too() {
        let nodes = identical_nodes(1, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "resident", Resources::new(1, 1), Priority(0)).with_label("app", "x"),
            Pod::new(1, "incoming", Resources::new(1, 1), Priority(0))
                .with_anti_affinity("app", "x"),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        let f = InterPodAntiAffinity;
        assert!(!f.filter(&st, PodId(1), NodeId(0), &CycleContext::default()));
    }
}
