//! The binding "cycle".
//!
//! In Kubernetes, binding is asynchronous (the scheduler posts a Binding
//! object; kubelet eventually runs the pod). Under KWOK there is no
//! kubelet, so binding is synchronous: reserve → permit → pre-bind →
//! bind → post-bind collapse into one call that mutates [`ClusterState`].

use crate::cluster::{ClusterState, NodeId, PodId};
use crate::scheduler::framework::{CycleContext, Framework, PluginDecision};

/// Outcome of one binding attempt.
#[derive(Clone, Debug, PartialEq)]
pub enum BindResult {
    Bound,
    /// A gate plugin (Permit/PreBind) rejected, or the state refused the
    /// bind (capacity raced away). The cycle must unreserve and requeue.
    Rejected(String),
}

/// Run the binding half of the cycle for an already-selected host.
pub fn bind_cycle(
    fw: &mut Framework,
    state: &mut ClusterState,
    pod: PodId,
    node: NodeId,
    ctx: &mut CycleContext,
) -> BindResult {
    fw.run_reserve(state, pod, node, ctx);

    if let PluginDecision::Reject(r) = fw.run_permit(state, pod, node) {
        fw.run_unreserve(state, pod, ctx);
        return BindResult::Rejected(format!("permit: {r}"));
    }
    if let PluginDecision::Reject(r) = fw.run_pre_bind(state, pod, node) {
        fw.run_unreserve(state, pod, ctx);
        return BindResult::Rejected(format!("prebind: {r}"));
    }
    match state.bind(pod, node) {
        Ok(()) => {
            fw.run_post_bind(state, pod, node);
            BindResult::Bound
        }
        // Any state refusal (capacity raced away, node cordoned mid-cycle,
        // pod retired, ...) rolls the reservation back and requeues.
        Err(e) => {
            fw.run_unreserve(state, pod, ctx);
            BindResult::Rejected(format!("bind: {e}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Pod, Priority, Resources};
    use crate::scheduler::framework::PermitPlugin;

    struct DenyPermit;
    impl PermitPlugin for DenyPermit {
        fn permit(&mut self, _: &ClusterState, _: PodId, _: NodeId) -> PluginDecision {
            PluginDecision::Reject("testing".into())
        }
        fn name(&self) -> &'static str {
            "DenyPermit"
        }
    }

    fn setup() -> (Framework, ClusterState) {
        let st = ClusterState::new(
            identical_nodes(1, Resources::new(1000, 1000)),
            vec![Pod::new(0, "p", Resources::new(100, 100), Priority(0))],
        );
        (Framework::new(), st)
    }

    #[test]
    fn successful_bind_mutates_state() {
        let (mut fw, mut st) = setup();
        let mut ctx = CycleContext::default();
        let r = bind_cycle(&mut fw, &mut st, PodId(0), NodeId(0), &mut ctx);
        assert_eq!(r, BindResult::Bound);
        assert_eq!(st.assignment_of(PodId(0)), Some(NodeId(0)));
    }

    #[test]
    fn permit_rejection_rolls_back() {
        let (mut fw, mut st) = setup();
        fw.permit.push(Box::new(DenyPermit));
        let mut ctx = CycleContext::default();
        let r = bind_cycle(&mut fw, &mut st, PodId(0), NodeId(0), &mut ctx);
        assert!(matches!(r, BindResult::Rejected(_)));
        assert_eq!(st.assignment_of(PodId(0)), None);
        assert_eq!(st.free(NodeId(0)), Resources::new(1000, 1000));
    }

    #[test]
    fn capacity_race_is_rejected_not_panicked() {
        let (mut fw, mut st) = setup();
        let fat = st.add_pod(Pod::new(0, "fat", Resources::new(1000, 1000), Priority(0)));
        st.bind(fat, NodeId(0)).unwrap();
        let mut ctx = CycleContext::default();
        let r = bind_cycle(&mut fw, &mut st, PodId(0), NodeId(0), &mut ctx);
        assert!(matches!(r, BindResult::Rejected(_)));
    }
}
