//! The scheduling framework: extension points and plugin registry.
//!
//! Faithful (single-threaded) model of the Kubernetes scheduling
//! framework described in the paper's Preliminaries. Each extension point
//! is a trait; the [`Framework`] owns one ordered list of plugins per
//! point and runs them in registration order. The scheduling cycle itself
//! lives in [`super::default::DefaultScheduler`]; the binding "cycle" is
//! immediate (KWOK-style — no kubelet to wait for).

use crate::cluster::{ClusterState, NodeId, PodId};

/// Verdict returned by gate-style plugins (PreEnqueue, PreFilter, Permit,
/// PreBind).
#[derive(Clone, Debug, PartialEq)]
pub enum PluginDecision {
    Allow,
    /// Reject with a human-readable reason (surfaced in events/logs).
    Reject(String),
}

impl PluginDecision {
    pub fn allowed(&self) -> bool {
        matches!(self, PluginDecision::Allow)
    }
}

/// Mutable per-cycle scratch shared between extension points.
///
/// The optimiser's plugin uses `pinned_node` at PreFilter to steer a pod
/// to the node the solver chose for it (paper: "at the PreEnqueue and
/// PreFilter points, it assigns the affected pods to their target
/// nodes"), and `reserved` to carry Reserve bookkeeping to Unreserve.
#[derive(Clone, Debug, Default)]
pub struct CycleContext {
    pub pinned_node: Option<NodeId>,
    pub reserved: Option<NodeId>,
    /// Per-node bound-replica counts of the pod's owner group, cached by
    /// the TopologySpread PreFilter hook so the Filter pass does not
    /// rescan every pod per candidate node.
    pub spread_counts: Option<Vec<i64>>,
}

// ---- extension-point traits ----------------------------------------------

/// Orders the scheduling queue. Exactly one may be active (enforced by
/// [`Framework::set_queue_sort`]).
pub trait QueueSortPlugin {
    /// `true` if `a` should be scheduled before `b`. Ties broken by
    /// enqueue sequence in the queue itself.
    fn less(
        &self,
        state: &ClusterState,
        a: PodId,
        b: PodId,
    ) -> bool;
    fn name(&self) -> &'static str;
}

pub trait PreEnqueuePlugin {
    fn pre_enqueue(&mut self, state: &ClusterState, pod: PodId) -> PluginDecision;
    fn name(&self) -> &'static str;
}

pub trait PreFilterPlugin {
    fn pre_filter(
        &mut self,
        state: &ClusterState,
        pod: PodId,
        ctx: &mut CycleContext,
    ) -> PluginDecision;
    fn name(&self) -> &'static str;
}

pub trait FilterPlugin {
    /// `true` iff `node` is feasible for `pod`.
    fn filter(&self, state: &ClusterState, pod: PodId, node: NodeId, ctx: &CycleContext) -> bool;
    fn name(&self) -> &'static str;
}

/// Runs only when *all* nodes were filtered out ("mainly for pre-emption
/// purposes" — the optimiser's hook).
pub trait PostFilterPlugin {
    fn post_filter(&mut self, state: &ClusterState, pod: PodId);
    fn name(&self) -> &'static str;
}

pub trait ScorePlugin {
    /// Higher is better. Only called on nodes that passed filtering.
    fn score(&self, state: &ClusterState, pod: PodId, node: NodeId) -> f64;
    fn name(&self) -> &'static str;
}

pub trait NormalizeScorePlugin {
    fn normalize(&self, scores: &mut [(NodeId, f64)]);
    fn name(&self) -> &'static str;
}

pub trait ReservePlugin {
    fn reserve(&mut self, state: &ClusterState, pod: PodId, node: NodeId, ctx: &mut CycleContext);
    /// Roll back a failed cycle's reservation.
    fn unreserve(&mut self, state: &ClusterState, pod: PodId, ctx: &mut CycleContext);
    fn name(&self) -> &'static str;
}

pub trait PermitPlugin {
    fn permit(&mut self, state: &ClusterState, pod: PodId, node: NodeId) -> PluginDecision;
    fn name(&self) -> &'static str;
}

pub trait PreBindPlugin {
    fn pre_bind(&mut self, state: &ClusterState, pod: PodId, node: NodeId) -> PluginDecision;
    fn name(&self) -> &'static str;
}

pub trait PostBindPlugin {
    fn post_bind(&mut self, state: &ClusterState, pod: PodId, node: NodeId);
    fn name(&self) -> &'static str;
}

// ---- registry --------------------------------------------------------------

/// Ordered plugin registry, one slot/list per extension point.
#[derive(Default)]
pub struct Framework {
    pub queue_sort: Option<Box<dyn QueueSortPlugin>>,
    pub pre_enqueue: Vec<Box<dyn PreEnqueuePlugin>>,
    pub pre_filter: Vec<Box<dyn PreFilterPlugin>>,
    pub filter: Vec<Box<dyn FilterPlugin>>,
    pub post_filter: Vec<Box<dyn PostFilterPlugin>>,
    pub score: Vec<Box<dyn ScorePlugin>>,
    pub normalize: Vec<Box<dyn NormalizeScorePlugin>>,
    pub reserve: Vec<Box<dyn ReservePlugin>>,
    pub permit: Vec<Box<dyn PermitPlugin>>,
    pub pre_bind: Vec<Box<dyn PreBindPlugin>>,
    pub post_bind: Vec<Box<dyn PostBindPlugin>>,
}

impl Framework {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install the (single) QueueSort plugin; replaces any previous one.
    pub fn set_queue_sort(&mut self, p: Box<dyn QueueSortPlugin>) {
        self.queue_sort = Some(p);
    }

    // -- run helpers, in framework order -----------------------------------

    pub fn run_pre_enqueue(&mut self, state: &ClusterState, pod: PodId) -> PluginDecision {
        for p in &mut self.pre_enqueue {
            let d = p.pre_enqueue(state, pod);
            if !d.allowed() {
                return d;
            }
        }
        PluginDecision::Allow
    }

    pub fn run_pre_filter(
        &mut self,
        state: &ClusterState,
        pod: PodId,
        ctx: &mut CycleContext,
    ) -> PluginDecision {
        for p in &mut self.pre_filter {
            let d = p.pre_filter(state, pod, ctx);
            if !d.allowed() {
                return d;
            }
        }
        PluginDecision::Allow
    }

    /// Feasible nodes after all Filter plugins (and the PreFilter pin).
    pub fn run_filter(
        &self,
        state: &ClusterState,
        pod: PodId,
        ctx: &CycleContext,
    ) -> Vec<NodeId> {
        state
            .nodes()
            .iter()
            .map(|n| n.id)
            .filter(|&n| {
                if let Some(pinned) = ctx.pinned_node {
                    if n != pinned {
                        return false;
                    }
                }
                self.filter.iter().all(|p| p.filter(state, pod, n, ctx))
            })
            .collect()
    }

    pub fn run_post_filter(&mut self, state: &ClusterState, pod: PodId) {
        for p in &mut self.post_filter {
            p.post_filter(state, pod);
        }
    }

    /// Sum of Score plugins per feasible node, then NormalizeScore.
    pub fn run_score(
        &self,
        state: &ClusterState,
        pod: PodId,
        feasible: &[NodeId],
    ) -> Vec<(NodeId, f64)> {
        let mut scores: Vec<(NodeId, f64)> = feasible
            .iter()
            .map(|&n| {
                let s: f64 = self.score.iter().map(|p| p.score(state, pod, n)).sum();
                (n, s)
            })
            .collect();
        for p in &self.normalize {
            p.normalize(&mut scores);
        }
        scores
    }

    pub fn run_reserve(
        &mut self,
        state: &ClusterState,
        pod: PodId,
        node: NodeId,
        ctx: &mut CycleContext,
    ) {
        for p in &mut self.reserve {
            p.reserve(state, pod, node, ctx);
        }
    }

    pub fn run_unreserve(&mut self, state: &ClusterState, pod: PodId, ctx: &mut CycleContext) {
        for p in &mut self.reserve {
            p.unreserve(state, pod, ctx);
        }
    }

    pub fn run_permit(&mut self, state: &ClusterState, pod: PodId, node: NodeId) -> PluginDecision {
        for p in &mut self.permit {
            let d = p.permit(state, pod, node);
            if !d.allowed() {
                return d;
            }
        }
        PluginDecision::Allow
    }

    pub fn run_pre_bind(
        &mut self,
        state: &ClusterState,
        pod: PodId,
        node: NodeId,
    ) -> PluginDecision {
        for p in &mut self.pre_bind {
            let d = p.pre_bind(state, pod, node);
            if !d.allowed() {
                return d;
            }
        }
        PluginDecision::Allow
    }

    pub fn run_post_bind(&mut self, state: &ClusterState, pod: PodId, node: NodeId) {
        for p in &mut self.post_bind {
            p.post_bind(state, pod, node);
        }
    }

    /// Select the winning node: highest score, ties broken by lowest
    /// `NodeId` — i.e. lexicographically smallest node name (the paper's
    /// determinism plugin). `total_cmp` keeps the selection total and
    /// panic-free even if a scoring plugin ever emits NaN (which then
    /// ranks above every finite score — deterministically).
    pub fn select_host(scores: &[(NodeId, f64)]) -> Option<NodeId> {
        scores
            .iter()
            .copied()
            .max_by(|(na, sa), (nb, sb)| {
                sa.total_cmp(sb).then_with(|| nb.cmp(na)) // lower id wins on tie
            })
            .map(|(n, _)| n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Pod, Priority, Resources};

    struct RejectAll;
    impl PreEnqueuePlugin for RejectAll {
        fn pre_enqueue(&mut self, _: &ClusterState, _: PodId) -> PluginDecision {
            PluginDecision::Reject("nope".into())
        }
        fn name(&self) -> &'static str {
            "RejectAll"
        }
    }

    fn tiny_state() -> ClusterState {
        ClusterState::new(
            identical_nodes(2, Resources::new(1000, 1000)),
            vec![Pod::new(0, "p", Resources::new(100, 100), Priority(0))],
        )
    }

    #[test]
    fn select_host_prefers_score_then_name() {
        let scores = vec![
            (NodeId(2), 10.0),
            (NodeId(0), 50.0),
            (NodeId(1), 50.0),
        ];
        assert_eq!(Framework::select_host(&scores), Some(NodeId(0)));
        assert_eq!(Framework::select_host(&[]), None);
    }

    #[test]
    fn select_host_survives_nan_scores() {
        // The NaN family PR 4 fixed in util/stats.rs, applied to the
        // tie-break: a NaN score must never panic the scheduling cycle.
        // Under total_cmp, NaN ranks above every finite score, and the
        // winner is independent of input order.
        let scores = [(NodeId(7), f64::NAN), (NodeId(3), 1.5)];
        assert_eq!(Framework::select_host(&scores), Some(NodeId(7)));
        let flipped = [(NodeId(3), 1.5), (NodeId(7), f64::NAN)];
        assert_eq!(Framework::select_host(&flipped), Some(NodeId(7)));
        // NaN-NaN ties break like any tie: lowest node id wins.
        let ties = [(NodeId(9), f64::NAN), (NodeId(2), f64::NAN)];
        assert_eq!(Framework::select_host(&ties), Some(NodeId(2)));
    }

    #[test]
    fn pre_enqueue_gate() {
        let mut fw = Framework::new();
        let st = tiny_state();
        assert!(fw.run_pre_enqueue(&st, PodId(0)).allowed());
        fw.pre_enqueue.push(Box::new(RejectAll));
        assert!(!fw.run_pre_enqueue(&st, PodId(0)).allowed());
    }

    #[test]
    fn pinned_node_restricts_filter() {
        let fw = Framework::new(); // no filter plugins: everything feasible
        let st = tiny_state();
        let mut ctx = CycleContext::default();
        assert_eq!(fw.run_filter(&st, PodId(0), &ctx).len(), 2);
        ctx.pinned_node = Some(NodeId(1));
        assert_eq!(fw.run_filter(&st, PodId(0), &ctx), vec![NodeId(1)]);
    }
}
