//! The default scheduler: the scheduling cycle over the framework.
//!
//! One `schedule_one` call is one scheduling cycle of Fig. 2: PreFilter →
//! Filter → (PostFilter on total failure) → Score → NormalizeScore →
//! select host (lexicographic tie-break) → binding cycle. `run_queue`
//! drains the scheduling queue with `parallelism = 1` — the paper's
//! deterministic configuration.
//!
//! The scoring phase is pluggable between two *numerically identical*
//! backends (parity pinned by `rust/tests/runtime_parity.rs`):
//!
//! * the [`plugins::LeastAllocated`] Score plugin (pure rust), or
//! * a [`BatchScorer`] — the PJRT-executed XLA/Pallas artifact
//!   (`runtime::XlaScorer`), scoring the pod against all nodes in one
//!   device call. Python is never involved at runtime; the artifact was
//!   AOT-compiled by `make artifacts`.

use crate::cluster::{ClusterState, Event, NodeId, PodId};

use super::binder::{bind_cycle, BindResult};
use super::framework::{CycleContext, Framework, PluginDecision};
use super::queue::SchedulingQueue;

/// Batch scoring backend (implemented by `runtime::XlaScorer` and
/// `runtime::NativeScorer`). Returns one score per node, `-1.0` marking
/// infeasible nodes — the L1 kernel's contract.
pub trait BatchScorer {
    fn score_row(&mut self, state: &ClusterState, pod: PodId) -> Vec<f32>;
    /// Score many pods at once (the optimiser and benches use this).
    fn score_matrix(&mut self, state: &ClusterState, pods: &[PodId]) -> Vec<Vec<f32>> {
        pods.iter().map(|&p| self.score_row(state, p)).collect()
    }
    fn name(&self) -> &'static str;
}

/// Outcome of a single scheduling cycle.
#[derive(Clone, Debug, PartialEq)]
pub enum ScheduleOutcome {
    Bound(NodeId),
    Unschedulable(String),
}

/// Counters for a queue-drain run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RunStats {
    pub cycles: usize,
    pub bound: usize,
    pub unschedulable: usize,
}

/// The default scheduler: framework + queue + optional batch scorer.
pub struct DefaultScheduler {
    pub framework: Framework,
    pub queue: SchedulingQueue,
    batch_scorer: Option<Box<dyn BatchScorer>>,
}

impl DefaultScheduler {
    /// The paper's deterministic profile — NodeResourcesFit filter,
    /// LeastAllocated scoring, PrioritySort queue order, no pre-emption —
    /// plus the constraint filters (taints, anti-affinity, topology
    /// spread) mirroring the optimiser's constraint modules. On
    /// constraint-free workloads the extra filters are no-ops, so the
    /// profile behaves exactly as the paper's.
    pub fn kwok_default() -> Self {
        use super::plugins::{
            InterPodAntiAffinity, LeastAllocated, NodeResourcesFit, PrioritySort, TaintToleration,
            TopologySpread,
        };
        let mut fw = Framework::new();
        fw.set_queue_sort(Box::new(PrioritySort));
        // TopologySpread registers at PreFilter too: it caches the owner
        // group's per-node counts in the CycleContext for the Filter pass.
        fw.pre_filter.push(Box::new(TopologySpread));
        fw.filter.push(Box::new(NodeResourcesFit));
        fw.filter.push(Box::new(TaintToleration));
        fw.filter.push(Box::new(InterPodAntiAffinity));
        fw.filter.push(Box::new(TopologySpread));
        fw.score.push(Box::new(LeastAllocated));
        DefaultScheduler {
            framework: fw,
            queue: SchedulingQueue::new(),
            batch_scorer: None,
        }
    }

    /// Same profile, but the scoring phase executes on the XLA runtime
    /// (or any other [`BatchScorer`]). Score plugins are bypassed; the
    /// backend must be numerically identical to `LeastAllocated`.
    pub fn with_batch_scorer(mut self, scorer: Box<dyn BatchScorer>) -> Self {
        self.set_batch_scorer(scorer);
        self
    }

    /// In-place variant of [`DefaultScheduler::with_batch_scorer`] —
    /// swaps the scoring backend without rebuilding the framework, so
    /// registered plugins and queue state survive.
    pub fn set_batch_scorer(&mut self, scorer: Box<dyn BatchScorer>) {
        self.batch_scorer = Some(scorer);
    }

    pub fn scorer_name(&self) -> &'static str {
        self.batch_scorer
            .as_ref()
            .map(|s| s.name())
            .unwrap_or("plugin:LeastAllocated")
    }

    /// Enqueue every pending pod of `state` (respecting PreEnqueue gates).
    pub fn enqueue_pending(&mut self, state: &ClusterState) {
        for pod in state.pending_pods() {
            self.enqueue(state, pod);
        }
    }

    /// Enqueue one pod through the PreEnqueue extension point.
    pub fn enqueue(&mut self, state: &ClusterState, pod: PodId) {
        match self.framework.run_pre_enqueue(state, pod) {
            PluginDecision::Allow => {
                self.queue.push(pod, state.pod(pod).priority);
            }
            PluginDecision::Reject(_) => {
                // Kubernetes parks such pods in a special queue; the
                // optimiser plugin uses this to hold pods while a plan is
                // in flight. They re-enter via `enqueue` later.
            }
        }
    }

    /// One scheduling cycle for `pod`.
    pub fn schedule_one(&mut self, state: &mut ClusterState, pod: PodId) -> ScheduleOutcome {
        let mut ctx = CycleContext::default();

        if let PluginDecision::Reject(r) = self.framework.run_pre_filter(state, pod, &mut ctx) {
            state.events.push(Event::Unschedulable { pod });
            return ScheduleOutcome::Unschedulable(format!("prefilter: {r}"));
        }

        let feasible = self.framework.run_filter(state, pod, &ctx);
        if feasible.is_empty() {
            self.framework.run_post_filter(state, pod);
            state.events.push(Event::Unschedulable { pod });
            return ScheduleOutcome::Unschedulable("no feasible node".into());
        }

        let mut scores: Vec<(NodeId, f64)> = match &mut self.batch_scorer {
            Some(backend) => {
                // Hot path: one PJRT execute scores all nodes; keep only
                // the feasible ones (the kernel marks the rest -1).
                let row = backend.score_row(state, pod);
                feasible
                    .iter()
                    .map(|&n| (n, row[n.idx()] as f64))
                    .collect()
            }
            None => self.framework.run_score(state, pod, &feasible),
        };
        if self.batch_scorer.is_some() {
            for p in &self.framework.normalize {
                p.normalize(&mut scores);
            }
        }

        let host = match Framework::select_host(&scores) {
            Some(n) => n,
            None => {
                state.events.push(Event::Unschedulable { pod });
                return ScheduleOutcome::Unschedulable("no scored node".into());
            }
        };

        match bind_cycle(&mut self.framework, state, pod, host, &mut ctx) {
            BindResult::Bound => ScheduleOutcome::Bound(host),
            BindResult::Rejected(r) => {
                state.events.push(Event::Unschedulable { pod });
                ScheduleOutcome::Unschedulable(r)
            }
        }
    }

    /// Drain the queue (parallelism = 1). Unschedulable pods are parked;
    /// they do NOT retry within one drain (no cluster event can unblock
    /// them — the cluster only changes through this scheduler).
    pub fn run_queue(&mut self, state: &mut ClusterState) -> RunStats {
        let mut stats = RunStats::default();
        while let Some(pod) = self.queue.pop() {
            stats.cycles += 1;
            match self.schedule_one(state, pod) {
                ScheduleOutcome::Bound(_) => stats.bound += 1,
                ScheduleOutcome::Unschedulable(_) => {
                    stats.unschedulable += 1;
                    self.queue.mark_unschedulable(pod, state.pod(pod).priority);
                }
            }
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Pod, Priority, Resources};

    /// The paper's Figure 1: two 4 GB nodes; pods of 2, 2, 3 GB. The
    /// LeastAllocated heuristic spreads the first two pods and strands
    /// the third — the motivating suboptimality.
    fn figure1_state() -> ClusterState {
        let nodes = identical_nodes(2, Resources::new(4000, 4096));
        let pods = vec![
            Pod::new(0, "pod-1", Resources::new(10, 2048), Priority(0)),
            Pod::new(1, "pod-2", Resources::new(10, 2048), Priority(0)),
            Pod::new(2, "pod-3", Resources::new(10, 3072), Priority(0)),
        ];
        ClusterState::new(nodes, pods)
    }

    #[test]
    fn figure1_fragmentation_reproduced() {
        let mut st = figure1_state();
        let mut sched = DefaultScheduler::kwok_default();
        sched.enqueue_pending(&st);
        let stats = sched.run_queue(&mut st);
        assert_eq!(stats.bound, 2);
        assert_eq!(stats.unschedulable, 1);
        // pods 1 and 2 were spread over both nodes (the suboptimal move)
        assert_ne!(st.assignment_of(PodId(0)), st.assignment_of(PodId(1)));
        assert_eq!(st.assignment_of(PodId(2)), None);
        // ... although total capacity would have sufficed:
        let total_free: Resources = st.free_all().iter().copied().sum();
        assert!(st.pod(PodId(2)).request.fits_in(&total_free));
    }

    #[test]
    fn priority_order_respected() {
        let nodes = identical_nodes(1, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "lo", Resources::new(800, 800), Priority(2)),
            Pod::new(1, "hi", Resources::new(800, 800), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        let mut sched = DefaultScheduler::kwok_default();
        sched.enqueue_pending(&st);
        sched.run_queue(&mut st);
        // Only one fits; the high-priority pod is scheduled first and wins.
        assert!(st.assignment_of(PodId(1)).is_some());
        assert_eq!(st.assignment_of(PodId(0)), None);
    }

    #[test]
    fn lexicographic_tie_break_on_equal_scores() {
        let nodes = identical_nodes(3, Resources::new(1000, 1000));
        let pods = vec![Pod::new(0, "p", Resources::new(100, 100), Priority(0))];
        let mut st = ClusterState::new(nodes, pods);
        let mut sched = DefaultScheduler::kwok_default();
        sched.enqueue_pending(&st);
        sched.run_queue(&mut st);
        // all nodes empty and identical -> first name wins
        assert_eq!(st.assignment_of(PodId(0)), Some(NodeId(0)));
    }

    #[test]
    fn deterministic_across_runs() {
        let run = || {
            let mut st = figure1_state();
            let mut sched = DefaultScheduler::kwok_default();
            sched.enqueue_pending(&st);
            sched.run_queue(&mut st);
            st.assignment().to_vec()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn unschedulable_pods_parked_in_queue() {
        let mut st = figure1_state();
        let mut sched = DefaultScheduler::kwok_default();
        sched.enqueue_pending(&st);
        sched.run_queue(&mut st);
        assert_eq!(sched.queue.unschedulable_len(), 1);
        assert_eq!(sched.queue.unschedulable_pods(), vec![PodId(2)]);
    }
}
