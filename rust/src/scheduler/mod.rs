//! kube-scheduler re-implementation.
//!
//! Mirrors the Kubernetes *scheduling framework* (Preliminaries, Fig. 2 of
//! the paper): pods flow through a priority queue, then per-cycle through
//! the extension points `PreEnqueue → PreFilter → Filter → PostFilter →
//! Score → NormalizeScore → Reserve → Permit → PreBind → Bind → PostBind`.
//! Plugins are trait objects registered on the [`framework::Framework`];
//! the default profile matches the paper's deterministic setup:
//!
//! * `NodeResourcesFit` filter (resource + selector feasibility),
//! * `LeastAllocated` scoring (the exact formula the L1 Pallas kernel
//!   computes — see `python/compile/kernels/ref.py`),
//! * lexicographic node-name tie-breaking (the paper's determinism
//!   plugin; free here because `NodeId` order *is* name order),
//! * `parallelism = 1`, `DefaultPreemption` disabled.

pub mod binder;
pub mod default;
pub mod framework;
pub mod plugins;
pub mod queue;

pub use default::{DefaultScheduler, ScheduleOutcome};
pub use framework::{CycleContext, Framework, PluginDecision};
pub use queue::SchedulingQueue;
