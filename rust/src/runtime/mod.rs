//! PJRT runtime: execute the AOT-compiled L1/L2 batch scorer from Rust.
//!
//! `make artifacts` lowers the JAX scoring graph (which wraps the Pallas
//! kernel) to HLO text; [`engine::XlaEngine`] loads those artifacts,
//! compiles them once on the PJRT CPU client, and serves `execute` calls
//! from the scheduler's hot path. Python never runs at request time.
//!
//! [`scorer`] provides the two interchangeable [`BatchScorer`] backends:
//! the XLA one and a bit-exact native mirror (also the fallback when no
//! artifacts are present). `rust/tests/runtime_parity.rs` pins their
//! equality.
//!
//! [`BatchScorer`]: crate::scheduler::default::BatchScorer

#[cfg(feature = "xla")]
pub mod engine;
/// Stub engine when built without the `xla` feature: same API surface,
/// every load fails gracefully, so callers fall back to [`NativeScorer`].
#[cfg(not(feature = "xla"))]
#[path = "engine_stub.rs"]
pub mod engine;
pub mod scorer;

pub use engine::XlaEngine;
pub use scorer::{NativeScorer, XlaScorer, INFEASIBLE};
