//! XLA engine: load HLO-text artifacts, compile once, execute many.
//!
//! Follows the /opt/xla-example/load_hlo pattern: HLO *text* (not a
//! serialized `HloModuleProto` — jax ≥ 0.5 emits 64-bit instruction ids
//! the bundled xla_extension 0.5.1 rejects; the text parser reassigns
//! ids). One `PjRtLoadedExecutable` per (P, N) shape variant; inputs are
//! padded to the smallest variant that fits (see
//! [`super::scorer::XlaScorer`] for the padding semantics).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Shape variants baked by `python/compile/aot.py` (keep in sync with
/// `SHAPE_VARIANTS` there).
pub const SHAPE_VARIANTS: [(usize, usize); 2] = [(64, 8), (256, 32)];

/// One compiled scorer executable.
struct ScorerExe {
    p: usize,
    n: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT CPU client plus the compiled scorer variants.
pub struct XlaEngine {
    client: xla::PjRtClient,
    scorers: Vec<ScorerExe>,
}

impl XlaEngine {
    /// Create a client and compile every artifact found in `dir`.
    /// Missing individual artifacts are skipped (callers can check
    /// [`XlaEngine::num_variants`]); a missing directory is an error.
    pub fn load(dir: impl AsRef<Path>) -> Result<XlaEngine> {
        let dir = dir.as_ref();
        if !dir.is_dir() {
            bail!(
                "artifact directory {} not found — run `make artifacts`",
                dir.display()
            );
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut scorers = Vec::new();
        for &(p, n) in &SHAPE_VARIANTS {
            let path: PathBuf = dir.join(format!("scorer_p{p}_n{n}.hlo.txt"));
            if !path.is_file() {
                continue;
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            scorers.push(ScorerExe { p, n, exe });
        }
        Ok(XlaEngine { client, scorers })
    }

    /// Standard artifact location relative to the repo root.
    pub fn load_default() -> Result<XlaEngine> {
        XlaEngine::load("artifacts")
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn num_variants(&self) -> usize {
        self.scorers.len()
    }

    /// Smallest variant with `p ≥ pods` and `n ≥ nodes`.
    pub fn pick_variant(&self, pods: usize, nodes: usize) -> Option<(usize, usize)> {
        self.scorers
            .iter()
            .filter(|s| s.p >= pods && s.n >= nodes)
            .map(|s| (s.p, s.n))
            .min()
    }

    /// Execute the (P, N) scorer variant. Inputs are row-major flattened
    /// and must already be padded to exactly (P·2, N·2, N·2) elements.
    /// Returns (scores[P·N], best[P], feasible[P]).
    pub fn execute_scorer(
        &self,
        (p, n): (usize, usize),
        pod_req: &[f32],
        node_free: &[f32],
        node_cap: &[f32],
    ) -> Result<(Vec<f32>, Vec<i32>, Vec<i32>)> {
        assert_eq!(pod_req.len(), p * 2, "pod_req padding mismatch");
        assert_eq!(node_free.len(), n * 2, "node_free padding mismatch");
        assert_eq!(node_cap.len(), n * 2, "node_cap padding mismatch");
        let s = self
            .scorers
            .iter()
            .find(|s| s.p == p && s.n == n)
            .context("unknown scorer variant")?;

        let x = xla::Literal::vec1(pod_req).reshape(&[p as i64, 2])?;
        let f = xla::Literal::vec1(node_free).reshape(&[n as i64, 2])?;
        let c = xla::Literal::vec1(node_cap).reshape(&[n as i64, 2])?;
        let result = s.exe.execute::<xla::Literal>(&[x, f, c])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: (scores, best, feasible).
        let (scores, best, feasible) = result.to_tuple3()?;
        Ok((
            scores.to_vec::<f32>()?,
            best.to_vec::<i32>()?,
            feasible.to_vec::<i32>()?,
        ))
    }
}

// NOTE: engine tests live in `rust/tests/runtime_parity.rs` (they need
// built artifacts, which unit tests must not assume).
