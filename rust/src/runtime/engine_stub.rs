//! API-compatible stand-in for [`engine`](self) when the crate is built
//! without the `xla` feature (the default: the offline image may lack
//! the `xla_extension` shared library). Every load fails with a clear
//! message, `num_variants` is 0, and callers — `XlaScorer`, the `info`
//! subcommand, the parity tests — all degrade to the bit-exact
//! [`NativeScorer`](super::scorer::NativeScorer) path.

use std::path::Path;

use anyhow::{bail, Result};

/// Shape variants baked by `python/compile/aot.py` (keep in sync with
/// `SHAPE_VARIANTS` there and in the real engine).
pub const SHAPE_VARIANTS: [(usize, usize); 2] = [(64, 8), (256, 32)];

/// PJRT engine stub; cannot be constructed (loading always fails).
pub struct XlaEngine {
    _private: (),
}

impl XlaEngine {
    /// Always fails: the PJRT runtime is not compiled in.
    pub fn load(_dir: impl AsRef<Path>) -> Result<XlaEngine> {
        bail!("built without the `xla` feature — PJRT runtime unavailable (rebuild with `--features xla`)")
    }

    /// Standard artifact location relative to the repo root.
    pub fn load_default() -> Result<XlaEngine> {
        XlaEngine::load("artifacts")
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn num_variants(&self) -> usize {
        0
    }

    /// Smallest variant with `p ≥ pods` and `n ≥ nodes` — never any here.
    pub fn pick_variant(&self, _pods: usize, _nodes: usize) -> Option<(usize, usize)> {
        None
    }

    /// Execute the (P, N) scorer variant — unreachable in practice since
    /// the stub cannot be constructed; kept for API parity.
    pub fn execute_scorer(
        &self,
        _shape: (usize, usize),
        _pod_req: &[f32],
        _node_free: &[f32],
        _node_cap: &[f32],
    ) -> Result<(Vec<f32>, Vec<i32>, Vec<i32>)> {
        bail!("built without the `xla` feature")
    }
}
