//! The two [`BatchScorer`] backends.
//!
//! * [`NativeScorer`] — pure-Rust mirror of the L1 kernel's arithmetic
//!   (f32 exactly, same operation order): the fallback when artifacts are
//!   absent and the oracle in parity tests.
//! * [`XlaScorer`] — pads inputs to an AOT variant and executes the
//!   compiled HLO through [`super::engine::XlaEngine`].
//!
//! Padding contract (pinned on the python side by
//! `python/tests/test_kernel.py::test_padding_semantics`):
//! pod rows pad with `req = 0` (harmless, rows ignored), node rows pad
//! with `free = -1, cap = 1` (infeasible everywhere, never selected).
//!
//! [`BatchScorer`]: crate::scheduler::default::BatchScorer

use crate::cluster::{ClusterState, PodId};
use crate::scheduler::default::BatchScorer;
use crate::scheduler::plugins::LeastAllocated;

use super::engine::XlaEngine;

/// Score marking an infeasible (filtered-out) node — the kernel contract.
pub const INFEASIBLE: f32 = -1.0;

/// Pure-Rust scorer, numerically identical to the Pallas kernel.
#[derive(Default)]
pub struct NativeScorer;

impl NativeScorer {
    /// Score a request row against every node of `state`.
    pub fn row(state: &ClusterState, req_cpu: f32, req_ram: f32) -> Vec<f32> {
        state
            .nodes()
            .iter()
            .map(|node| {
                let free = state.free(node.id);
                LeastAllocated::formula(
                    free.cpu as f32,
                    free.ram as f32,
                    node.capacity.cpu as f32,
                    node.capacity.ram as f32,
                    req_cpu,
                    req_ram,
                )
            })
            .collect()
    }
}

impl BatchScorer for NativeScorer {
    fn score_row(&mut self, state: &ClusterState, pod: PodId) -> Vec<f32> {
        let req = state.pod(pod).request;
        NativeScorer::row(state, req.cpu as f32, req.ram as f32)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// XLA-backed scorer: one PJRT execute per invocation, all nodes (and,
/// for `score_matrix`, all pods) in a single device call.
pub struct XlaScorer {
    engine: XlaEngine,
    /// Executions performed (exposed for benches).
    pub executions: u64,
}

impl XlaScorer {
    pub fn new(engine: XlaEngine) -> Self {
        XlaScorer {
            engine,
            executions: 0,
        }
    }

    /// Load from the default `artifacts/` directory.
    pub fn from_artifacts() -> anyhow::Result<Self> {
        let engine = XlaEngine::load_default()?;
        anyhow::ensure!(
            engine.num_variants() > 0,
            "no scorer artifacts found — run `make artifacts`"
        );
        Ok(XlaScorer::new(engine))
    }

    /// Pad + execute for an arbitrary set of pods. Returns one score row
    /// per requested pod (each row truncated to the real node count).
    pub fn score_pods(&mut self, state: &ClusterState, pods: &[PodId]) -> Vec<Vec<f32>> {
        let n_nodes = state.nodes().len();
        let (p, n) = self
            .engine
            .pick_variant(pods.len().max(1), n_nodes)
            .unwrap_or_else(|| {
                panic!(
                    "no AOT variant fits {} pods x {} nodes",
                    pods.len(),
                    n_nodes
                )
            });

        // Pod rows: requests, padded with zeros.
        let mut pod_req = vec![0f32; p * 2];
        for (i, &pod) in pods.iter().enumerate() {
            let r = state.pod(pod).request;
            pod_req[i * 2] = r.cpu as f32;
            pod_req[i * 2 + 1] = r.ram as f32;
        }
        // Node rows: free/cap, padded with (-1, 1) = never feasible.
        let mut node_free = vec![-1f32; n * 2];
        let mut node_cap = vec![1f32; n * 2];
        for (j, node) in state.nodes().iter().enumerate() {
            let free = state.free(node.id);
            node_free[j * 2] = free.cpu as f32;
            node_free[j * 2 + 1] = free.ram as f32;
            node_cap[j * 2] = node.capacity.cpu as f32;
            node_cap[j * 2 + 1] = node.capacity.ram as f32;
        }

        let (scores, _best, _feasible) = self
            .engine
            .execute_scorer((p, n), &pod_req, &node_free, &node_cap)
            .expect("scorer execution failed");
        self.executions += 1;

        pods.iter()
            .enumerate()
            .map(|(i, _)| scores[i * n..i * n + n_nodes].to_vec())
            .collect()
    }
}

impl BatchScorer for XlaScorer {
    fn score_row(&mut self, state: &ClusterState, pod: PodId) -> Vec<f32> {
        self.score_pods(state, &[pod]).pop().unwrap()
    }

    fn score_matrix(&mut self, state: &ClusterState, pods: &[PodId]) -> Vec<Vec<f32>> {
        self.score_pods(state, pods)
    }

    fn name(&self) -> &'static str {
        "xla-pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, ClusterState, NodeId, Pod, Priority, Resources};

    #[test]
    fn native_row_matches_plugin_scores() {
        let nodes = identical_nodes(3, Resources::new(4000, 4000));
        let pods = vec![Pod::new(0, "p", Resources::new(500, 1500), Priority(0))];
        let mut st = ClusterState::new(nodes, pods);
        let extra = st.add_pod(Pod::new(0, "q", Resources::new(1000, 1000), Priority(0)));
        st.bind(extra, NodeId(1)).unwrap();

        let mut scorer = NativeScorer;
        let row = scorer.score_row(&st, PodId(0));
        use crate::scheduler::framework::ScorePlugin;
        let plugin = LeastAllocated;
        for (j, &s) in row.iter().enumerate() {
            let want = plugin.score(&st, PodId(0), NodeId(j as u32)) as f32;
            assert!((s - want).abs() < 1e-6, "node {j}: {s} vs {want}");
        }
        // node 1 is fuller -> lower score than empty nodes
        assert!(row[1] < row[0]);
        assert_eq!(row[0], row[2]);
    }

    #[test]
    fn native_marks_infeasible() {
        let nodes = identical_nodes(1, Resources::new(100, 100));
        let pods = vec![Pod::new(0, "xl", Resources::new(200, 50), Priority(0))];
        let st = ClusterState::new(nodes, pods);
        let row = NativeScorer.score_row(&st, PodId(0));
        assert_eq!(row, vec![INFEASIBLE]);
    }
}
