//! `kube-packd` — CLI for the constraint-based pod-packing reproduction.
//!
//! Subcommands:
//!
//! * `demo`      — walk through the paper's Figure 1 scenario.
//! * `generate`  — emit a challenging dataset as JSON.
//! * `solve`     — run the optimiser over a dataset file.
//! * `churn`     — discrete-event lifecycle simulation comparing
//!   default-only vs fallback vs fallback+sweep on one seeded trace.
//! * `serve`     — long-lived scheduler daemon: batched admission
//!   windows over newline-JSON TCP, graceful drain on shutdown/SIGINT.
//! * `serve-bench` — closed-loop load generator against a live daemon
//!   over loopback; emits the `BENCH_serve.json` document.
//! * `journal`   — page through a live daemon's window-close journal
//!   and pretty-print it (the flight-recorder replay view).
//! * `fig3` / `fig4` / `table1` — regenerate the paper's evaluation
//!   artefacts (reports under `results/`).
//! * `all`       — fig3 + fig4 + table1.
//! * `info`      — runtime/artifact status (PJRT platform, variants).

use std::time::Duration;

use kube_packd::analysis;
use kube_packd::autoscaler::{AutoscaleConfig, NodePool};
use kube_packd::cluster::{identical_nodes, ClusterState, Pod, PodId, Priority, Resources};
use kube_packd::harness::figures;
use kube_packd::harness::grid::GridConfig;
use kube_packd::harness::InstanceRun;
use kube_packd::lifecycle::{
    compare_policies_traced, run_churn_traced, ChurnConfig, Policy, SweepConfig,
};
use kube_packd::optimizer::{
    explain_pod, ModuleRegistry, OptimizerConfig, OptimizingScheduler, SolveSession,
};
use kube_packd::portfolio::PortfolioConfig;
use kube_packd::runtime::XlaEngine;
use kube_packd::server::engine::EngineConfig;
use kube_packd::server::loadgen;
use kube_packd::server::protocol::{WireOp, WireRequest};
use kube_packd::server::{ServeConfig, ServeHandle};
use kube_packd::solver::{Probe, SolveStatus, SolverConfig, PROFILE_SCHEMA};
use kube_packd::telemetry::{Telemetry, Verbosity};
use kube_packd::util::cli::Args;
use kube_packd::util::json::Json;
use kube_packd::workload::{
    dataset, ChurnParams, ChurnTraceGenerator, ConstraintProfile, GenParams, Instance,
};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("demo") => demo(),
        Some("generate") => generate(&args),
        Some("solve") => solve(&args),
        Some("profile") => profile_report(&args),
        Some("churn") => churn(&args),
        Some("autoscale") => autoscale(&args),
        Some("serve") => serve(&args),
        Some("serve-bench") => serve_bench(&args),
        Some("journal") => journal(&args),
        Some("lint") => lint(&args),
        Some("fig3") => figure(&args, "fig3"),
        Some("fig4") => figure(&args, "fig4"),
        Some("table1") => figure(&args, "table1"),
        Some("all") => {
            figure(&args, "fig3")?;
            figure(&args, "fig4")?;
            figure(&args, "table1")
        }
        Some("info") => info(),
        other => {
            // Unknown (or missing) subcommand: full usage, non-zero exit.
            if let Some(cmd) = other {
                eprintln!("unknown command: {cmd}\n");
            }
            usage();
            std::process::exit(2);
        }
    }
}

fn usage() {
    println!(
        "kube-packd — priority-aware constraint-based pod packing (AAAI'25 reproduction)

USAGE: kube-packd <command> [options]

COMMANDS
  demo                     Figure 1 walk-through (fragmentation -> repack)
  generate                 emit a challenging dataset (JSON)
      --nodes N --ppn N --tiers N --usage F --count N --seed N --out FILE
      --constraints none|taints|anti-affinity|spread|extended|mixed
      --node-pools small,large,gpu   (heterogeneous fleet; default
                           identical nodes, the paper's assumption)
  solve                    run the optimiser over a dataset file
                           (constraint profiles travel with the dataset)
      --dataset FILE --timeout SECS --threads N --json FILE --incremental
      --trace FILE --metrics FILE --verbosity off|info|debug|trace
      --profile FILE       solve forensics: per-constraint-module search
                           effort (propagations/conflicts/prunes
                           attributed to capacity:cpu, anti-affinity, …)
                           and decision-indexed optimality-gap timelines,
                           as a kube-packd/profile/v1 JSON document.
                           Deterministic: byte-identical across --threads
                           whenever every racer finishes in-window, and
                           arming it never changes any answer
      --folded FILE        the same effort as flamegraph.pl-compatible
                           folded stacks (`frame;frame;slug;kind count`)
      --explain            per still-pending pod, print the per-node
                           rejection census (taint/selector/capacity/
                           anti-affinity tallies over all ready nodes)
                           (--json: per-tier optimality certificates —
                           proven-optimal vs anytime-best + final bound —
                           and portfolio stats, machine-readable)
  churn                    discrete-event lifecycle simulation; compares
                           default-only vs fallback vs fallback+sweep on
                           one seeded churn trace (deterministic replay)
      --nodes N --ppn N --tiers N --usage F --seed N
      --horizon-ms N --arrival-ms N --lifetime-ms N
      --sweep-ms N --budget N --timeout SECS --threads N --log
      --incremental --autoscale --node-pools small,large,gpu
      --constraints none|taints|anti-affinity|spread|extended|mixed
      --trace FILE --metrics FILE --verbosity off|info|debug|trace
  autoscale                CP-driven elastic-cluster comparison: the same
                           seeded churn trace with the autoscaler off vs
                           on — certified scale-ups (min-cost node pools)
                           and provably-drainable consolidations
      --nodes N --ppn N --tiers N --usage F --seed N --horizon-ms N
      --arrival-ms N --lifetime-ms N --sweep-ms N --budget N
      --timeout SECS --threads N --node-pools small,large,gpu --log
      --trace FILE --metrics FILE --verbosity off|info|debug|trace
  serve                    long-lived scheduler daemon over newline-JSON
                           TCP: pod submit/delete, node join/drain/remove,
                           query/health/metrics/trace_export/shutdown;
                           submits batch into solve windows and answer
                           with placements + optimality certificates
      --addr HOST:PORT (default 127.0.0.1:7878)
      --window-ms N (default 1000) --max-batch N (default 64)
      --nodes N --node-cpu M --node-ram M --tiers N
      --timeout SECS --threads N --no-incremental
      --autoscale --node-pools small,large,gpu --budget N
      --trace FILE --metrics FILE   (flushed at drain; also available
                           live via {{\"op\":\"metrics\"}}/{{\"op\":\"trace_export\"}})
      --max-pending N (default 4096): admission queue bound — past it
                           requests are shed with a structured
                           `overloaded` error instead of growing memory
      live observability: {{\"op\":\"journal\"}} pages the window-close
                           event journal, {{\"op\":\"watch\"}} streams
                           per-window delta frames, {{\"op\":\"explain\"}}
                           gives a pending pod's per-node rejection
                           census; query/health take \"latency\":true for
                           p50/p95/p99 solve+admission summaries
  journal                  connect to a live daemon and pretty-print its
                           window-close journal (flight-recorder replay)
      --addr HOST:PORT (default 127.0.0.1:7878)
      --since N (default 0) --limit N (page size, default 64) --json
  profile FILE             pretty-print a solve --profile document:
                           per-module effort table, optimality-gap
                           timeline, LNS round/improvement accounting
      --folded FILE        re-export the folded stacks from the document
  lint [PATH]              detlint: determinism-boundary static analysis
                           over the Rust tree (default PATH rust/src).
                           Zone manifest + rules wall-clock, hash-iter,
                           float-order, panic-on-wire, telemetry-feedback
                           and the Rust<->Python wire-parity drift check;
                           waivers need an inline
                           `// detlint: allow(<rule>) — <reason>`.
                           Exits nonzero on any unwaived finding (the CI
                           gate). See README \"Static analysis\".
      --json FILE          machine-readable findings report
  serve-bench              closed-loop load generator: spawns a daemon on
                           loopback, drives seeded churn admissions, and
                           emits sustained admissions/sec + p50/p95/p99
                           decision latency plus the threads-{1,8}
                           determinism record
      --out FILE (default BENCH_serve.json) --quick
  fig3 | fig4 | table1     regenerate the paper's figures/tables
      --nodes 4,8,16,32 --ppn 4,8 --tiers 1,2,4 --usage 90,95,100,105
      --timeouts 0.1,0.5,1 --instances N --seed N --out DIR --quick
      --threads N
  all                      fig3 + fig4 + table1
  info                     PJRT platform + artifact status

  --threads N (default 1, or KUBE_PACKD_THREADS): CP solves run a
  parallel portfolio — constraint-graph decomposition plus a strategy
  race per component. 1 = the single-threaded solver, bit for bit.

  --incremental: keep a solve session alive across consecutive solves
  (churn cycles, sweeps, dataset instances) — unchanged states and
  constraint-graph components replay proven certificates, dirty work
  warm-starts from the previous incumbent. Byte-identical results;
  caching only changes how fast they arrive.

  --trace FILE: export the run as Chrome-trace JSON (open in Perfetto or
  chrome://tracing). --metrics FILE: dump solver/portfolio/session
  counters in Prometheus text exposition. --verbosity debug additionally
  echoes pipeline events to stderr. Telemetry observes and never feeds
  back: results are byte-identical with it on or off."
    );
}

/// `--constraints` selects the constraint scenario family for the
/// workload generator (default: the paper's unconstrained workload).
fn constraints_arg(args: &Args) -> ConstraintProfile {
    let v = args.get_str("constraints", "none");
    ConstraintProfile::parse(v).unwrap_or_else(|| {
        panic!("--constraints wants none|taints|anti-affinity|spread|extended|mixed, got {v:?}")
    })
}

/// `--node-pools` selects the heterogeneous fleet mix (empty = the
/// paper's identical nodes).
fn node_pools_arg(args: &Args) -> Vec<NodePool> {
    let v = args.get_str("node-pools", "");
    NodePool::parse_mix(v)
        .unwrap_or_else(|| panic!("--node-pools wants a comma mix of small|large|gpu, got {v:?}"))
}

/// The autoscaler knobs shared by `churn --autoscale` and the
/// `autoscale` subcommand: the trace's pool mix doubles as the
/// provisioning menu (standard mix when the fleet is identical), the
/// provisioning window follows `--timeout`, and `--budget` caps
/// consolidation disruption.
fn autoscale_cfg_arg(args: &Args, pools: &[NodePool], timeout: f64) -> AutoscaleConfig {
    AutoscaleConfig {
        pools: if pools.is_empty() {
            NodePool::standard_mix()
        } else {
            pools.to_vec()
        },
        provision_timeout: Duration::from_secs_f64(timeout),
        consolidation_budget: args.get_usize("budget", 8),
        ..AutoscaleConfig::default()
    }
}

/// `--trace FILE` / `--metrics FILE` / `--verbosity off|info|debug|trace`:
/// build the run's telemetry handle. The export flags arm recording even
/// at the default verbosity; telemetry only observes, so armed and
/// disarmed runs produce byte-identical plans, objectives, and digests.
fn telemetry_arg(args: &Args) -> Telemetry {
    let v = args.get_str("verbosity", "off");
    let verbosity = Verbosity::parse(v)
        .unwrap_or_else(|| panic!("--verbosity wants off|info|debug|trace, got {v:?}"));
    if verbosity == Verbosity::Off && (args.get("trace").is_some() || args.get("metrics").is_some())
    {
        return Telemetry::recording();
    }
    Telemetry::from_verbosity(verbosity)
}

/// Write the `--trace` (Chrome trace JSON — load in Perfetto or
/// chrome://tracing) and `--metrics` (Prometheus text exposition)
/// exports, when requested.
fn write_telemetry(args: &Args, tel: &Telemetry) -> anyhow::Result<()> {
    if let Some(path) = args.get("trace") {
        std::fs::write(path, tel.export_chrome())?;
        eprintln!("chrome trace written to {path} ({} spans)", tel.span_count());
    }
    if let Some(path) = args.get("metrics") {
        std::fs::write(path, tel.export_prometheus())?;
        eprintln!("prometheus metrics written to {path}");
    }
    Ok(())
}

/// `--threads` with the env-aware portfolio default (`KUBE_PACKD_THREADS`
/// or 1).
fn threads_arg(args: &Args) -> usize {
    args.get_usize("threads", PortfolioConfig::default().threads).max(1)
}

/// `--usage` accepts a ratio (0.95) or a percentage (95); normalize to
/// the ratio form every generator expects.
fn usage_arg(args: &Args, default: f64) -> f64 {
    let u = args.get_f64("usage", default);
    if u > 2.0 {
        u / 100.0
    } else {
        u
    }
}

/// Shared grid config from CLI flags.
fn grid_config(args: &Args) -> GridConfig {
    let mut cfg = GridConfig {
        nodes: args.get_usize_list("nodes", &[4, 8, 16, 32]),
        pods_per_node: args.get_usize_list("ppn", &[4, 8]),
        priority_tiers: args
            .get_usize_list("tiers", &[1, 2, 4])
            .into_iter()
            .map(|t| t as u32)
            .collect(),
        usage: args
            .get_f64_list("usage", &[90.0, 95.0, 100.0, 105.0])
            .into_iter()
            .map(|u| if u > 2.0 { u / 100.0 } else { u })
            .collect(),
        timeouts: args.get_f64_list("timeouts", &[0.1, 0.5, 1.0]),
        instances: args.get_usize("instances", 12),
        seed: args.get_u64("seed", 0xC0FFEE),
        solver: SolverConfig::default(),
        portfolio: PortfolioConfig::with_threads(threads_arg(args)),
        max_gen_attempts: args.get_usize("max-gen-attempts", 400),
        verbose: !args.flag("quiet"),
    };
    if args.flag("quick") {
        cfg.nodes = vec![4, 8];
        cfg.instances = cfg.instances.min(4);
        cfg.timeouts = vec![0.1, 0.3];
    }
    cfg
}

fn figure(args: &Args, which: &str) -> anyhow::Result<()> {
    let cfg = grid_config(args);
    let out_dir = args.get_str("out", "results").to_string();
    std::fs::create_dir_all(&out_dir)?;
    let report = match which {
        "fig3" => figures::fig3(&cfg, &out_dir)?,
        "fig4" => figures::fig4(&cfg, &out_dir)?,
        "table1" => figures::table1(&cfg, &out_dir)?,
        _ => unreachable!(),
    };
    println!("{report}");
    let path = format!("{out_dir}/{which}.md");
    std::fs::write(&path, &report)?;
    eprintln!("report written to {path}");
    Ok(())
}

fn generate(args: &Args) -> anyhow::Result<()> {
    let params = GenParams {
        nodes: args.get_usize("nodes", 8),
        pods_per_node: args.get_usize("ppn", 4),
        priority_tiers: args.get_usize("tiers", 2) as u32,
        usage: usage_arg(args, 1.0),
    };
    let count = args.get_usize("count", 10);
    let seed = args.get_u64("seed", 1);
    let out = args.get_str("out", "dataset.json");
    let profile = constraints_arg(args);
    let pools = node_pools_arg(args);
    let insts = Instance::generate_challenging_pooled(
        params,
        count,
        seed,
        count * 50,
        profile,
        &pools,
    );
    dataset::save(&insts, out)?;
    println!(
        "wrote {} challenging instances ({}, constraints={}, pools={}) to {out}",
        insts.len(),
        params.label(),
        profile.label(),
        if pools.is_empty() {
            "identical".to_string()
        } else {
            NodePool::mix_spec(&pools)
        }
    );
    Ok(())
}

fn solve(args: &Args) -> anyhow::Result<()> {
    let path = args.get_str("dataset", "dataset.json");
    let timeout = args.get_f64("timeout", 1.0);
    let threads = threads_arg(args);
    let portfolio = PortfolioConfig::with_threads(threads);
    let insts = dataset::load(path)?;
    let tel = telemetry_arg(args);
    // One session across the whole dataset: instances generated from one
    // grid cell share structure, so certified sub-solves carry over.
    let mut session = args.flag("incremental").then(SolveSession::new);
    println!(
        "instance       outcome          solver(s)  kwok-placed -> opt-placed   moves  certificate"
    );
    let json_out = args.get("json");
    // Solve forensics: --profile/--folded arm the search profiler. Like
    // telemetry it observes only — answers are byte-identical armed or
    // off (proptest-pinned).
    let prof = if args.get("profile").is_some() || args.get("folded").is_some() {
        Probe::armed()
    } else {
        Probe::off()
    };
    let mut rows = Vec::new();
    for (i, inst) in insts.iter().enumerate() {
        let run = {
            // One context frame per instance keeps dataset profiles
            // separable (solve;i3;t0.p1;exact;…).
            let _pf = prof.frame(&format!("i{i}"));
            kube_packd::harness::run_instance_probed(
                inst,
                timeout,
                &SolverConfig::default(),
                &portfolio,
                session.as_mut(),
                &tel,
                &prof,
            )
        };
        println!(
            "{:>3} {:>14} {:>16} {:>9.2}  {:?} -> {:?}  {:>5}  {}",
            i,
            inst.params.label(),
            run.outcome.label(),
            run.solver_duration_s,
            run.kwok_placed,
            run.opt_placed,
            run.disruptions,
            certificate_summary(&run)
        );
        if json_out.is_some() {
            rows.push(instance_json(i, inst, &run));
        }
        if args.flag("explain") {
            explain_pending(&run.final_state);
        }
    }
    if let Some(sess) = &session {
        let c = sess.cache_stats();
        eprintln!(
            "incremental session: {} full replays, {}/{} solve cache hits, {} component hits, \
             {} warm seeds",
            sess.stats.full_hits,
            c.solve_hits,
            c.solve_hits + c.solve_misses,
            c.component_hits,
            c.warm_seeds
        );
    }
    if let Some(out) = json_out {
        let mut doc = Json::obj();
        doc.set("dataset", path)
            .set("timeout_s", timeout)
            .set("threads", threads)
            .set("incremental", session.is_some())
            .set("instances", Json::Arr(rows));
        std::fs::write(out, doc.to_string_pretty())?;
        eprintln!("json report written to {out}");
    }
    if let Some(out) = args.get("profile") {
        std::fs::write(out, prof.export_profile_json())?;
        eprintln!("solve profile written to {out} (schema {PROFILE_SCHEMA})");
    }
    if let Some(out) = args.get("folded") {
        std::fs::write(out, prof.export_folded())?;
        eprintln!("folded stacks written to {out} (flamegraph.pl-compatible)");
    }
    // Per-module effort doubles as Prometheus counter families in the
    // --metrics exposition.
    if prof.enabled() && tel.enabled() {
        for (slug, kind, count) in prof.module_effort() {
            tel.add(
                "forensics_effort_total",
                &format!("module=\"{slug}\",kind=\"{kind}\""),
                count,
            );
        }
    }
    write_telemetry(args, &tel)?;
    Ok(())
}

/// `kube-packd profile FILE`: pretty-print a `solve --profile` document
/// — per-module effort table, optimality-gap timeline, and LNS
/// round/improvement accounting.
fn profile_report(args: &Args) -> anyhow::Result<()> {
    let path = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("in"))
        .unwrap_or("profile.json");
    let raw = std::fs::read_to_string(path)?;
    let doc = kube_packd::util::json::parse(&raw)
        .ok_or_else(|| anyhow::anyhow!("{path}: not valid JSON"))?;
    let schema = doc.get("schema").and_then(Json::as_str).unwrap_or("?");
    if schema != PROFILE_SCHEMA {
        anyhow::bail!("{path}: schema {schema:?}, want {PROFILE_SCHEMA:?}");
    }
    println!("solve profile — {path} ({schema})");

    let modules = doc.get("modules").and_then(Json::as_arr).unwrap_or(&[]);
    println!("\nper-module search effort (summed across contexts)");
    println!("{:<28} {:<14} {:>14}", "module", "kind", "count");
    let mut total = 0i64;
    for m in modules {
        let slug = m.get("slug").and_then(Json::as_str).unwrap_or("?");
        let kind = m.get("kind").and_then(Json::as_str).unwrap_or("?");
        let count = m.get("count").and_then(Json::as_i64).unwrap_or(0);
        total += count;
        println!("{slug:<28} {kind:<14} {count:>14}");
    }
    println!("{:<28} {:<14} {:>14}", "(total)", "", total);

    let gap = doc.get("gap").and_then(Json::as_arr).unwrap_or(&[]);
    println!("\noptimality-gap timeline (decision-indexed)");
    if gap.is_empty() {
        println!("  no incumbents recorded");
    } else {
        println!(
            "{:<34} {:>12} {:>12} {:>10} {:>8}",
            "context", "decisions", "incumbent", "bound", "gap"
        );
        for s in gap {
            let incumbent = s.get("incumbent").and_then(Json::as_i64).unwrap_or(0);
            let bound = s.get("bound").and_then(Json::as_i64).unwrap_or(0);
            println!(
                "{:<34} {:>12} {:>12} {:>10} {:>8}",
                s.get("context").and_then(Json::as_str).unwrap_or("?"),
                s.get("decisions").and_then(Json::as_i64).unwrap_or(0),
                incumbent,
                bound,
                bound - incumbent,
            );
        }
    }

    // LNS accounting: search rounds/improvements recorded under any
    // context frame ending in `lns`.
    let effort = doc.get("effort").and_then(Json::as_arr).unwrap_or(&[]);
    let lns_sum = |kind: &str| -> i64 {
        effort
            .iter()
            .filter(|e| {
                e.get("context")
                    .and_then(Json::as_str)
                    .map_or(false, |c| c.ends_with(";lns") || c.contains(";lns;"))
                    && e.get("kind").and_then(Json::as_str) == Some(kind)
            })
            .filter_map(|e| e.get("count").and_then(Json::as_i64))
            .sum()
    };
    println!(
        "\nLNS: {} round(s), {} improvement(s)",
        lns_sum("rounds"),
        lns_sum("improvements")
    );

    if let Some(out) = args.get("folded") {
        let folded = doc.get("folded").and_then(Json::as_arr).unwrap_or(&[]);
        let mut text = String::new();
        for line in folded {
            if let Some(l) = line.as_str() {
                text.push_str(l);
                text.push('\n');
            }
        }
        std::fs::write(out, text)?;
        eprintln!("folded stacks re-exported to {out}");
    }
    Ok(())
}

/// `solve --explain`: per still-pending pod, print the rejection census
/// over every ready node — which constraint module (or residual
/// capacity dimension) vetoes each node, tallied by reason. A pod with
/// feasible nodes is pending for packing reasons, not hard
/// infeasibility; say so.
fn explain_pending(state: &ClusterState) {
    let reg = ModuleRegistry::standard();
    for (i, slot) in state.assignment().iter().enumerate() {
        if slot.is_some() {
            continue;
        }
        let pod = &state.pods()[i];
        let report = explain_pod(state, &reg, PodId(i as u32));
        let reasons = if report.tally.is_empty() {
            "no hard rejections".to_string()
        } else {
            report
                .tally
                .iter()
                .map(|(r, c)| format!("{r}:{c}"))
                .collect::<Vec<_>>()
                .join(", ")
        };
        let packing = if report.feasible > 0 {
            " (feasible nodes exist — pending for packing, not infeasibility)"
        } else {
            ""
        };
        println!(
            "      explain {} (tier {}): {} ready node(s), {} feasible — {}{}",
            pod.name, pod.priority.0, report.ready_nodes, report.feasible, reasons, packing
        );
    }
}

/// One-line per-tier certificate summary for the solve table: how many
/// tiers were proven optimal vs anytime-best.
fn certificate_summary(run: &InstanceRun) -> String {
    if run.tiers.is_empty() {
        return "-".to_string();
    }
    let proven = run
        .tiers
        .iter()
        .filter(|t| t.phase1_status == SolveStatus::Optimal)
        .count();
    format!("{proven}/{} tiers proven", run.tiers.len())
}

/// Machine-readable record of one instance run, including the paper's
/// "certified optimal" evidence: per-tier status + final bound.
fn instance_json(index: usize, inst: &Instance, run: &InstanceRun) -> Json {
    let mut tiers = Vec::new();
    for t in &run.tiers {
        let mut tj = Json::obj();
        tj.set("priority", t.priority)
            .set("phase1_status", t.phase1_status.label())
            .set(
                "phase1_certificate",
                if t.phase1_status == SolveStatus::Optimal {
                    "proven-optimal"
                } else {
                    "anytime-best"
                },
            )
            .set("phase1_placed", t.phase1_placed)
            .set("phase1_bound", t.phase1_bound)
            .set("phase1_components", t.phase1_components)
            .set("phase1_components_certified", t.phase1_components_certified)
            .set("phase2_status", t.phase2_status.label())
            .set("phase2_metric", t.phase2_metric)
            .set("phase2_bound", t.phase2_bound)
            .set("phase1_cache_hit", t.phase1_cache_hit)
            .set("phase2_cache_hit", t.phase2_cache_hit);
        // Per-tier search effort (phase 1 + phase 2 combined): offline
        // forensics without re-running the solve.
        let mut sj = Json::obj();
        sj.set("decisions", t.search.decisions)
            .set("propagations", t.search.propagations)
            .set("conflicts", t.search.conflicts)
            .set("bound_prunes", t.search.bound_prunes)
            .set("floor_prunes", t.search.floor_prunes)
            .set("symmetry_skips", t.search.symmetry_skips)
            .set("lns_rounds", t.search.lns_rounds);
        tj.set("search", sj);
        tiers.push(tj);
    }
    let mut strategy_wins = Json::obj();
    for (label, wins) in &run.portfolio.strategy_wins {
        strategy_wins.set(label, *wins);
    }
    let mut pf = Json::obj();
    pf.set("solves", run.portfolio.solves)
        .set("legacy_solves", run.portfolio.legacy_solves)
        .set("components", run.portfolio.components)
        .set("components_certified", run.portfolio.components_certified)
        .set("tasks_run", run.portfolio.tasks_run)
        .set("tasks_cancelled", run.portfolio.tasks_cancelled)
        .set("whole_model_wins", run.portfolio.whole_model_wins)
        .set("composite_wins", run.portfolio.composite_wins)
        .set("cache_hits", run.portfolio.cache_hits)
        .set("component_cache_hits", run.portfolio.component_cache_hits)
        .set("warm_starts", run.portfolio.warm_starts)
        .set("strategy_wins", strategy_wins);
    let mut o = Json::obj();
    o.set("index", index)
        .set("params", inst.params.label())
        .set("constraints", inst.profile.label())
        .set("outcome", run.outcome.label())
        .set("solver_duration_s", run.solver_duration_s)
        .set("kwok_placed", run.kwok_placed.clone())
        .set("opt_placed", run.opt_placed.clone())
        .set("disruptions", run.disruptions)
        .set("tiers", Json::Arr(tiers))
        .set("portfolio", pf);
    o
}

/// Lifecycle churn comparison: three policies over one seeded trace.
fn churn(args: &Args) -> anyhow::Result<()> {
    let base = GenParams {
        nodes: args.get_usize("nodes", 16),
        pods_per_node: args.get_usize("ppn", 4),
        priority_tiers: args.get_usize("tiers", 2) as u32,
        usage: usage_arg(args, 0.95),
    };
    let params = ChurnParams {
        horizon_ms: args.get_u64("horizon-ms", 30_000),
        mean_arrival_ms: args.get_u64("arrival-ms", 600),
        mean_lifetime_ms: args.get_u64("lifetime-ms", 8_000),
        ..ChurnParams::for_cluster(base)
    };
    let seed = args.get_u64("seed", 42);
    let timeout = args.get_f64("timeout", 1.0);
    let threads = threads_arg(args);
    let profile = constraints_arg(args);

    let pools = node_pools_arg(args);
    let trace = ChurnTraceGenerator::new(params, seed)
        .with_profile(profile)
        .with_pools(pools.clone())
        .generate();
    let incremental = args.flag("incremental");
    let autoscale = args
        .flag("autoscale")
        .then(|| autoscale_cfg_arg(args, &pools, timeout));
    let cfg = ChurnConfig {
        policy: Policy::FallbackSweep,
        sweep_every_ms: args.get_u64("sweep-ms", 5_000),
        sweep: SweepConfig {
            optimizer: OptimizerConfig::with_timeout(timeout)
                .with_threads(threads)
                .with_incremental(incremental),
            eviction_budget: args.get_usize("budget", 8),
        },
        fallback_timeout: Duration::from_secs_f64(timeout),
        fallback_portfolio: PortfolioConfig::with_threads(threads),
        incremental,
        autoscale,
    };

    let tel = telemetry_arg(args);
    let results = compare_policies_traced(&trace, &cfg, &tel);
    println!("{}", kube_packd::harness::churn_report(&trace, &results));
    write_telemetry(args, &tel)?;
    if args.flag("log") {
        for r in &results {
            println!("--- event log: {} ---", r.policy.label());
            print!("{}", r.log.render());
        }
    }
    println!(
        "replay check: re-run with --seed {seed} — the default-only digest always matches byte \
         for byte; the solver-backed rows match whenever every solve finishes within its budget \
         (raise --timeout if they drift under load)"
    );
    Ok(())
}

/// CP-driven elastic-cluster comparison: the identical seeded trace run
/// with the autoscaler off vs on, under the fallback+sweep policy.
fn autoscale(args: &Args) -> anyhow::Result<()> {
    let base = GenParams {
        nodes: args.get_usize("nodes", 6),
        pods_per_node: args.get_usize("ppn", 4),
        priority_tiers: args.get_usize("tiers", 2) as u32,
        // Overloaded by default: certified scale-ups need a cluster the
        // solver can *prove* full.
        usage: usage_arg(args, 1.15),
    };
    let params = ChurnParams {
        horizon_ms: args.get_u64("horizon-ms", 20_000),
        mean_arrival_ms: args.get_u64("arrival-ms", 600),
        mean_lifetime_ms: args.get_u64("lifetime-ms", 5_000),
        ..ChurnParams::for_cluster(base)
    };
    let seed = args.get_u64("seed", 42);
    let timeout = args.get_f64("timeout", 1.0);
    let threads = threads_arg(args);
    let pools = node_pools_arg(args);
    let trace = ChurnTraceGenerator::new(params, seed)
        .with_profile(constraints_arg(args))
        .with_pools(pools.clone())
        .generate();

    let acfg = autoscale_cfg_arg(args, &pools, timeout);
    let mk = |autoscale: Option<AutoscaleConfig>| ChurnConfig {
        policy: Policy::FallbackSweep,
        sweep_every_ms: args.get_u64("sweep-ms", 2_000),
        sweep: SweepConfig {
            optimizer: OptimizerConfig::with_timeout(timeout).with_threads(threads),
            eviction_budget: args.get_usize("budget", 8),
        },
        fallback_timeout: Duration::from_secs_f64(timeout),
        fallback_portfolio: PortfolioConfig::with_threads(threads),
        incremental: args.flag("incremental"),
        autoscale,
    };
    let tel = telemetry_arg(args);
    let off = run_churn_traced(&trace, &mk(None), &tel);
    let on = run_churn_traced(&trace, &mk(Some(acfg.clone())), &tel);
    write_telemetry(args, &tel)?;

    println!(
        "autoscale — {} · horizon {}ms · seed {seed} · pools {}",
        base.label(),
        params.horizon_ms,
        NodePool::mix_spec(&acfg.pools)
    );
    println!(
        "{:<10} {:>14} {:>8} {:>7} {:>18} {:>11} {:>18}",
        "mode", "served/tier", "pending", "nodes", "scale (+n/-n cost)", "evictions", "log digest"
    );
    for (mode, r) in [("off", &off), ("on", &on)] {
        println!(
            "{:<10} {:>14} {:>8} {:>7} {:>18} {:>11} {:>18}",
            mode,
            format!("{:?}", r.served_per_priority),
            r.final_pending,
            r.final_ready_nodes,
            r.autoscale.cell(),
            r.evictions,
            format!("{:016x}", r.log.digest()),
        );
    }
    let a = &on.autoscale;
    println!(
        "\nscale-ups: {} applied ({} certified min-cost, {} nodes, cost {}), {} \
         proven-infeasible, {} inconclusive",
        a.scale_ups,
        a.certified_scale_ups,
        a.nodes_added,
        a.cost_added,
        a.scale_up_infeasible,
        a.scale_up_unknown
    );
    println!(
        "scale-downs: {} passes removed {} node(s) ({} re-pack moves, {} drained pods)",
        a.scale_downs, a.nodes_removed, a.consolidation_moves, a.drained_pods
    );
    if args.flag("log") {
        println!("--- event log: autoscale on ---");
        print!("{}", on.log.render());
    }
    println!(
        "\nreplay check: identical --seed and --threads replay byte-identically whenever every \
         solve finishes within its budget; scale decisions are certificates, so they replay too"
    );
    Ok(())
}

/// Scheduler-as-a-service: run the daemon until it drains. The serve
/// loop owns the cluster, the persistent solve session, and a recording
/// telemetry handle (so live `metrics`/`trace_export` requests have
/// substance); `--trace`/`--metrics` additionally flush file exports at
/// drain.
fn serve(args: &Args) -> anyhow::Result<()> {
    let tiers = args.get_usize("tiers", 2).max(1) as u32;
    let capacity = Resources::new(
        args.get_u64("node-cpu", 4000) as i64,
        args.get_u64("node-ram", 4096) as i64,
    );
    let timeout = args.get_f64("timeout", 1.0);
    let pools = node_pools_arg(args);
    let autoscale = args
        .flag("autoscale")
        .then(|| autoscale_cfg_arg(args, &pools, timeout));
    let cfg = ServeConfig {
        addr: args.get_str("addr", "127.0.0.1:7878").to_string(),
        max_batch: args.get_usize("max-batch", 64),
        max_pending: args.get_usize("max-pending", 4096),
        engine: EngineConfig {
            p_max: tiers - 1,
            nodes: identical_nodes(args.get_usize("nodes", 8), capacity),
            reference_capacity: capacity,
            solve_timeout: Duration::from_secs_f64(timeout),
            threads: threads_arg(args),
            incremental: !args.flag("no-incremental"),
            autoscale,
            window_ms: args.get_u64("window-ms", 1_000),
        },
        trace_out: args.get("trace").map(str::to_string),
        metrics_out: args.get("metrics").map(str::to_string),
        install_sigint: true,
        ..ServeConfig::default()
    };
    let handle = ServeHandle::spawn(cfg)?;
    eprintln!("kube-packd serve listening on {}", handle.addr);
    handle.join()?;
    eprintln!("kube-packd serve drained cleanly");
    Ok(())
}

/// Closed-loop load generator: spawn a daemon on loopback, drive it
/// with seeded churn admissions, and write the `BENCH_serve.json`
/// document (throughput/latency cells + the threads-{1,8} determinism
/// record).
fn serve_bench(args: &Args) -> anyhow::Result<()> {
    let out = args.get_str("out", "BENCH_serve.json");
    let doc = loadgen::bench_document(args.flag("quick"))?;
    std::fs::write(out, doc.to_string_pretty())?;
    println!("{}", doc.to_string_pretty());
    eprintln!("serve bench written to {out}");
    Ok(())
}

/// `kube-packd journal`: connect to a live daemon, page through its
/// window-close journal with the `since` cursor, and pretty-print one
/// line per window (or the raw wire entries with `--json`).
fn journal(args: &Args) -> anyhow::Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7878");
    let mut since = args.get_u64("since", 0);
    let limit = args.get_u64("limit", 64);
    let raw = args.flag("json");
    let mut client = loadgen::Client::connect(addr)?;
    let mut tag = 1u64;
    let mut total = 0usize;
    loop {
        let req = WireRequest::tagged(
            WireOp::Journal {
                since: Some(since),
                limit: Some(limit),
                wall: true,
            },
            tag,
        );
        let reply = client.request(&req)?;
        tag += 1;
        if let Some(err) = reply.get("error") {
            anyhow::bail!("daemon rejected journal request: {}", err.to_string_compact());
        }
        let entries = reply.get("entries").and_then(Json::as_arr).unwrap_or(&[]);
        if total == 0 {
            if let (Some(f), Some(l)) = (
                reply.get("first_window").and_then(Json::as_i64),
                reply.get("last_window").and_then(Json::as_i64),
            ) {
                eprintln!("journal retains windows {f}..={l}");
            }
        }
        for e in entries {
            if raw {
                println!("{}", e.to_string_compact());
            } else {
                println!("{}", journal_line(e));
            }
        }
        total += entries.len();
        let next = reply
            .get("next")
            .and_then(Json::as_i64)
            .map(|n| n as u64)
            .unwrap_or(since);
        if entries.is_empty() || next <= since {
            break;
        }
        since = next;
    }
    eprintln!("{total} window(s) printed");
    Ok(())
}

/// `kube-packd lint [PATH]`: the detlint determinism-boundary static
/// pass (see `kube_packd::analysis`). Exits 1 on any unwaived finding
/// so CI can use it as a blocking gate.
fn lint(args: &Args) -> anyhow::Result<()> {
    let root = args.positional.first().map_or("rust/src", String::as_str);
    let report = analysis::lint_tree(std::path::Path::new(root))?;
    print!("{}", report.render_human());
    if let Some(path) = args.get("json") {
        std::fs::write(path, report.to_json().to_string_pretty())?;
        eprintln!("machine report written to {path}");
    }
    if !report.clean() {
        std::process::exit(1);
    }
    Ok(())
}

/// One human-readable line per window-close journal entry.
fn journal_line(e: &Json) -> String {
    let num = |k: &str| e.get(k).and_then(Json::as_i64).unwrap_or(0);
    let arr = |k: &str| -> Vec<i64> {
        e.get(k)
            .and_then(Json::as_arr)
            .map(|v| v.iter().filter_map(Json::as_i64).collect())
            .unwrap_or_default()
    };
    let seq = match (
        e.get("seq_lo").and_then(Json::as_i64),
        e.get("seq_hi").and_then(Json::as_i64),
    ) {
        (Some(lo), Some(hi)) => format!("seq {lo}..={hi}"),
        _ => "timer".to_string(),
    };
    let wall = e
        .get("wall_us")
        .and_then(Json::as_i64)
        .map(|us| format!("  {:.1}ms", us as f64 / 1000.0))
        .unwrap_or_default();
    format!(
        "window {:>4} @{:>7}ms  {:<14}  submits {:>3}  placed {:?} -> {:?}  pending {:>3} -> {:<3}  {}{}",
        num("window"),
        num("virtual_ms"),
        seq,
        num("submits"),
        arr("placed_before"),
        arr("placed_after"),
        num("pending_before"),
        num("pending_after"),
        e.get("certificate").and_then(Json::as_str).unwrap_or("?"),
        wall,
    )
}

/// The paper's Figure 1, narrated.
fn demo() -> anyhow::Result<()> {
    println!("Figure 1 demo — 2 nodes x 4Gi; pods of 2Gi, 2Gi, 3Gi\n");
    let nodes = identical_nodes(2, Resources::new(4000, 4096));
    let pods = vec![
        Pod::new(0, "pod-1", Resources::new(100, 2048), Priority(0)),
        Pod::new(1, "pod-2", Resources::new(100, 2048), Priority(0)),
        Pod::new(2, "pod-3", Resources::new(100, 3072), Priority(0)),
    ];
    let mut state = ClusterState::new(nodes, pods);
    let mut sched = OptimizingScheduler::new(
        0,
        OptimizerConfig {
            total_timeout: Duration::from_secs(2),
            ..Default::default()
        },
    );
    let report = sched.run(&mut state);
    println!(
        "default scheduler placed {:?} pods; solver invoked: {}",
        report.placed_before, report.solver_invoked
    );
    println!(
        "after optimisation: {:?} pods placed (improved={}, optimal={}, moves={})",
        report.placed_after, report.improved, report.proved_optimal, report.disruptions
    );
    for (i, a) in state.assignment().iter().enumerate() {
        println!(
            "  {} -> {}",
            state.pods()[i].name,
            a.map(|n| state.node(n).name.clone())
                .unwrap_or_else(|| "<pending>".into())
        );
    }
    Ok(())
}

fn info() -> anyhow::Result<()> {
    println!("kube-packd {}", env!("CARGO_PKG_VERSION"));
    match XlaEngine::load_default() {
        Ok(engine) => {
            println!("PJRT platform : {}", engine.platform());
            println!("AOT variants  : {}", engine.num_variants());
        }
        Err(e) => println!("runtime unavailable: {e:#}"),
    }
    Ok(())
}
