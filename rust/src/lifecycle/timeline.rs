//! The ordered event timeline.
//!
//! A priority queue over `(time, insertion-seq)`: events fire in time
//! order, and events sharing a tick fire in the order they were
//! scheduled. The sequence tie-break is what makes the whole simulator
//! deterministic — `BinaryHeap` alone gives no stable order for equal
//! keys.
//!
//! # Same-tick ordering is contractual
//!
//! Insertion order at an equal timestamp is *the* specified order, not
//! an accident: a trace that schedules `Join` before a `Deploy` at tick
//! `t` applies the join first (its state mutation and log line precede
//! the deploy's), and vice versa. Elastic clusters made this
//! observable — autoscaler-era traces interleave `NodeJoin` with pod
//! arrivals at shared ticks, and replay determinism (byte-identical
//! churn digests) depends on the interleaving being pinned. The
//! regression tests below freeze it. Note the *scheduling round* of the
//! churn runner is unaffected either way: it batches every event of a
//! tick before scheduling, so a same-tick join is always visible to
//! that tick's placements regardless of which side of the deploy it
//! landed on.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

use crate::cluster::PodId;
use crate::workload::churn::TraceOp;

/// Everything that can happen to the simulated cluster.
#[derive(Clone, Debug)]
pub enum LifecycleEvent {
    /// A workload trace operation (deploy / scale / drain / join).
    Trace(TraceOp),
    /// A pod reaches end of life (running or still pending).
    PodCompletion { pod: PodId },
    /// Periodic CP defragmentation sweep.
    OptimizerSweep,
}

struct Entry {
    at_ms: u64,
    seq: u64,
    event: LifecycleEvent,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.at_ms == other.at_ms && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        (self.at_ms, self.seq).cmp(&(other.at_ms, other.seq))
    }
}

/// Min-ordered event queue with stable same-tick ordering.
#[derive(Default)]
pub struct Timeline {
    heap: BinaryHeap<Reverse<Entry>>,
    next_seq: u64,
}

impl Timeline {
    pub fn new() -> Self {
        Timeline::default()
    }

    pub fn schedule(&mut self, at_ms: u64, event: LifecycleEvent) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Entry { at_ms, seq, event }));
    }

    /// Next event in (time, seq) order.
    pub fn pop_next(&mut self) -> Option<(u64, LifecycleEvent)> {
        self.heap.pop().map(|Reverse(e)| (e.at_ms, e.event))
    }

    /// Firing time of the next event, if any.
    pub fn peek_ms(&self) -> Option<u64> {
        self.heap.peek().map(|Reverse(e)| e.at_ms)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn completion(pod: u32) -> LifecycleEvent {
        LifecycleEvent::PodCompletion { pod: PodId(pod) }
    }

    fn popped_pods(tl: &mut Timeline) -> Vec<(u64, u32)> {
        let mut out = Vec::new();
        while let Some((t, ev)) = tl.pop_next() {
            match ev {
                LifecycleEvent::PodCompletion { pod } => out.push((t, pod.0)),
                _ => panic!("unexpected event"),
            }
        }
        out
    }

    #[test]
    fn time_order_wins() {
        let mut tl = Timeline::new();
        tl.schedule(30, completion(0));
        tl.schedule(10, completion(1));
        tl.schedule(20, completion(2));
        assert_eq!(tl.peek_ms(), Some(10));
        assert_eq!(popped_pods(&mut tl), vec![(10, 1), (20, 2), (30, 0)]);
    }

    #[test]
    fn same_tick_fires_in_schedule_order() {
        let mut tl = Timeline::new();
        tl.schedule(5, completion(7));
        tl.schedule(5, completion(3));
        tl.schedule(5, completion(9));
        assert_eq!(popped_pods(&mut tl), vec![(5, 7), (5, 3), (5, 9)]);
    }

    #[test]
    fn same_tick_join_vs_arrival_order_is_insertion_order() {
        use crate::cluster::Resources;
        use crate::workload::churn::TraceOp;

        let join = || {
            LifecycleEvent::Trace(TraceOp::Join {
                capacity: Resources::new(1000, 1000),
                pool: None,
            })
        };
        let arrival = || completion(0); // any pod-side event

        // join scheduled first fires first …
        let mut tl = Timeline::new();
        tl.schedule(100, join());
        tl.schedule(100, arrival());
        match tl.pop_next() {
            Some((100, LifecycleEvent::Trace(TraceOp::Join { .. }))) => {}
            other => panic!("join scheduled first must fire first, got {other:?}"),
        }
        match tl.pop_next() {
            Some((100, LifecycleEvent::PodCompletion { .. })) => {}
            other => panic!("arrival must fire second, got {other:?}"),
        }

        // … and the reverse insertion fires in the reverse order.
        let mut tl = Timeline::new();
        tl.schedule(100, arrival());
        tl.schedule(100, join());
        match tl.pop_next() {
            Some((100, LifecycleEvent::PodCompletion { .. })) => {}
            other => panic!("arrival scheduled first must fire first, got {other:?}"),
        }
        match tl.pop_next() {
            Some((100, LifecycleEvent::Trace(TraceOp::Join { .. }))) => {}
            other => panic!("join must fire second, got {other:?}"),
        }
    }

    #[test]
    fn same_tick_ordering_survives_heap_growth() {
        use crate::cluster::Resources;
        use crate::workload::churn::TraceOp;

        // Many same-tick events around a Join: the heap's internal
        // sift order must never leak through the (time, seq) key.
        let mut tl = Timeline::new();
        for i in 0..8 {
            tl.schedule(50, completion(i));
        }
        tl.schedule(
            50,
            LifecycleEvent::Trace(TraceOp::Join {
                capacity: Resources::new(1, 1),
                pool: None,
            }),
        );
        for i in 8..16 {
            tl.schedule(50, completion(i));
        }
        let mut order = Vec::new();
        while let Some((t, ev)) = tl.pop_next() {
            assert_eq!(t, 50);
            order.push(match ev {
                LifecycleEvent::PodCompletion { pod } => pod.0 as i64,
                LifecycleEvent::Trace(TraceOp::Join { .. }) => -1,
                _ => panic!("unexpected event"),
            });
        }
        let expected: Vec<i64> =
            (0..8).chain([-1]).chain(8..16).collect();
        assert_eq!(order, expected);
    }

    #[test]
    fn interleaved_scheduling_stays_stable() {
        let mut tl = Timeline::new();
        tl.schedule(10, completion(0));
        assert_eq!(tl.pop_next().map(|(t, _)| t), Some(10));
        // schedule into the past relative to popped events is allowed —
        // the *simulator's clock* enforces monotonicity, not the queue
        tl.schedule(10, completion(1));
        tl.schedule(10, completion(2));
        assert_eq!(tl.len(), 2);
        assert_eq!(popped_pods(&mut tl), vec![(10, 1), (10, 2)]);
        assert!(tl.is_empty());
    }
}
