//! Periodic CP defragmentation sweeps.
//!
//! The paper runs the optimiser as a *fallback* when pods go pending.
//! A sweep is the descheduler-style complement: on a timer, re-pack the
//! live cluster with Algorithm 1 and execute the resulting move plan —
//! but only when it strictly improves the per-priority placement vector
//! and stays within an eviction budget (disruption is not free in a real
//! cluster: every move restarts a container).

use crate::cluster::{ClusterState, Event, EvictCause};
use crate::metrics::lex_better;
use crate::optimizer::algorithm::{optimize_traced, OptimizerConfig};
use crate::optimizer::plan::MovePlan;
use crate::optimizer::session::SolveSession;
use crate::telemetry::Telemetry;

/// Sweep policy knobs.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Algorithm 1 configuration for the re-pack solve.
    pub optimizer: OptimizerConfig,
    /// Maximum pods whose node may change in one sweep; improving plans
    /// above the budget are reported but not applied.
    pub eviction_budget: usize,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            optimizer: OptimizerConfig::with_timeout(2.0),
            eviction_budget: 8,
        }
    }
}

/// What one sweep did.
#[derive(Clone, Debug, Default)]
pub struct SweepReport {
    /// Solver produced an improving target.
    pub improved: bool,
    /// The improving plan fit the eviction budget and was executed.
    pub applied: bool,
    /// Disruptions of the plan (moves + displacements); reported even
    /// when the budget vetoed application.
    pub moves: usize,
    pub placed_before: Vec<usize>,
    pub placed_after: Vec<usize>,
}

/// Run one defragmentation sweep over the live cluster.
pub fn run_sweep(state: &mut ClusterState, p_max: u32, cfg: &SweepConfig) -> SweepReport {
    run_sweep_session(state, p_max, cfg, None)
}

/// [`run_sweep`] with an optional incremental [`SolveSession`]: a
/// long-lived churn loop hands the same session to every sweep so
/// consecutive re-packs reuse cached per-component certificates and
/// warm-start from the previous incumbent (see
/// `optimizer::session`).
pub fn run_sweep_session(
    state: &mut ClusterState,
    p_max: u32,
    cfg: &SweepConfig,
    session: Option<&mut SolveSession>,
) -> SweepReport {
    run_sweep_session_traced(state, p_max, cfg, session, &Telemetry::off())
}

/// [`run_sweep_session`] recording onto a caller-owned [`Telemetry`]
/// handle: the whole sweep becomes a `sweep` span wrapping the re-pack
/// solve's own spans, plus `sweep_*` counters.
pub fn run_sweep_session_traced(
    state: &mut ClusterState,
    p_max: u32,
    cfg: &SweepConfig,
    session: Option<&mut SolveSession>,
    tel: &Telemetry,
) -> SweepReport {
    let sp = tel.span("sweep");
    tel.add("sweep_runs_total", "", 1);
    let placed_before = state.placed_per_priority(p_max);
    state.events.push(Event::SweepStarted {
        pending: state.pending_pods().len(),
        at_ms: state.time_ms(),
    });

    let mut report = SweepReport {
        placed_after: placed_before.clone(),
        placed_before,
        ..Default::default()
    };

    let result = match session {
        Some(sess) => sess.solve_traced(state, p_max, &cfg.optimizer, tel),
        None => optimize_traced(state, p_max, &cfg.optimizer, None, tel),
    };
    if let Some(res) = result {
        if lex_better(&res.placed_per_priority, &report.placed_before) {
            report.improved = true;
            let plan = MovePlan::build(state, &res.target);
            report.moves = plan.disruptions();
            if report.moves <= cfg.eviction_budget && apply_plan(state, &plan) {
                report.applied = true;
                report.placed_after = state.placed_per_priority(p_max);
            }
        }
    }

    state.events.push(Event::SweepFinished {
        improved: report.improved,
        applied: report.applied,
        moves: report.moves,
        at_ms: state.time_ms(),
    });
    sp.arg("improved", report.improved);
    sp.arg("applied", report.applied);
    sp.arg("moves", report.moves);
    if report.applied {
        tel.add("sweep_applied_total", "", 1);
        tel.add("sweep_moves_total", "", report.moves as u64);
    }
    report
}

/// Apply a sweep plan all-or-nothing. The plan executes against a trial
/// clone first; a mid-plan failure (reachable when a custom filter /
/// module disagrees with the CP model, same as the plugin path) leaves
/// the live state untouched, emits [`Event::PlanAborted`], and reports
/// `applied = false` instead of panicking the whole churn simulation.
/// The event log — the one unboundedly growing piece of state, and
/// irrelevant to plan feasibility — is detached before the clone, so
/// the trial stays O(pods + nodes) however long the simulation has run.
fn apply_plan(state: &mut ClusterState, plan: &MovePlan) -> bool {
    let mut log = std::mem::take(&mut state.events);
    let mut trial = state.clone();
    match plan.execute_as(&mut trial, EvictCause::Sweep) {
        Ok(()) => {
            *state = trial;
            log.append(&mut state.events); // the plan's own fresh events
            state.events = log;
            true
        }
        Err(_) => {
            log.push(Event::PlanAborted {
                bound: 0,
                missing: plan.placements.len(),
            });
            state.events = log;
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, NodeId, Pod, PodId, Priority, Resources};

    /// Figure 1 after the default scheduler fragmented it: pods 0 and 1
    /// spread over both nodes, pod 2 stranded pending.
    fn fragmented_figure1() -> ClusterState {
        let nodes = identical_nodes(2, Resources::new(4000, 4096));
        let pods = vec![
            Pod::new(0, "pod-1", Resources::new(10, 2048), Priority(0)),
            Pod::new(1, "pod-2", Resources::new(10, 2048), Priority(0)),
            Pod::new(2, "pod-3", Resources::new(10, 3072), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        st.bind(PodId(1), NodeId(1)).unwrap();
        st
    }

    #[test]
    fn sweep_defragments_within_budget() {
        let mut st = fragmented_figure1();
        let report = run_sweep(&mut st, 0, &SweepConfig::default());
        assert!(report.improved);
        assert!(report.applied);
        assert_eq!(report.placed_before, vec![2]);
        assert_eq!(report.placed_after, vec![3]);
        assert!(report.moves >= 1);
        st.check_invariants().unwrap();
        assert_eq!(st.pending_pods(), Vec::<PodId>::new());
        // sweep-driven moves are attributed to the sweep, not pre-emption
        assert!(st.events.evictions_by(EvictCause::Sweep) >= 1);
        assert_eq!(st.events.evictions_by(EvictCause::Preemption), 0);
        // event trail records the sweep
        assert!(st
            .events
            .all()
            .iter()
            .any(|e| matches!(e, Event::SweepFinished { applied: true, .. })));
    }

    #[test]
    fn eviction_budget_vetoes_application() {
        let mut st = fragmented_figure1();
        let cfg = SweepConfig {
            eviction_budget: 0,
            ..Default::default()
        };
        let report = run_sweep(&mut st, 0, &cfg);
        assert!(report.improved, "solver still finds the better packing");
        assert!(!report.applied, "budget 0 must veto the move");
        assert_eq!(report.placed_after, report.placed_before);
        // cluster untouched
        assert_eq!(st.assignment_of(PodId(2)), None);
        st.check_invariants().unwrap();
    }

    #[test]
    fn sweep_is_a_no_op_on_optimal_clusters() {
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "a", Resources::new(400, 400), Priority(0)),
            Pod::new(1, "b", Resources::new(400, 400), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        st.bind(PodId(1), NodeId(1)).unwrap();
        let report = run_sweep(&mut st, 0, &SweepConfig::default());
        assert!(!report.improved);
        assert!(!report.applied);
        assert_eq!(st.assignment_of(PodId(0)), Some(NodeId(0)));
        assert_eq!(st.assignment_of(PodId(1)), Some(NodeId(1)));
    }

    #[test]
    fn mid_plan_failure_aborts_gracefully_instead_of_panicking() {
        // A plan whose bind step cannot apply (the target node lacks the
        // capacity) must leave the state untouched and record
        // PlanAborted — the regression the `expect` in the old
        // `run_sweep` turned into a simulation-wide panic.
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "a", Resources::new(900, 900), Priority(0)),
            Pod::new(1, "xl", Resources::new(800, 800), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        // Bogus plan: move the xl pod onto node 0, which cannot hold it.
        let target = vec![Some(NodeId(0)), Some(NodeId(0))];
        let plan = crate::optimizer::plan::MovePlan::build(&st, &target);
        let placed_before = st.placed_per_priority(0);

        assert!(!super::apply_plan(&mut st, &plan));
        // state untouched: same placements, no partial evictions
        assert_eq!(st.placed_per_priority(0), placed_before);
        assert_eq!(st.assignment_of(PodId(0)), Some(NodeId(0)));
        assert_eq!(st.assignment_of(PodId(1)), None);
        assert_eq!(st.events.evictions(), 0);
        assert!(st
            .events
            .all()
            .iter()
            .any(|e| matches!(e, Event::PlanAborted { .. })));
        st.check_invariants().unwrap();
    }

    #[test]
    fn sweep_session_matches_cold_and_replays_stable_states() {
        // A session-backed sweep must do exactly what a cold sweep does,
        // and once the cluster stops changing, the session answers the
        // re-pack solve from its full-state replay without invoking the
        // solver again.
        let mut cold_st = fragmented_figure1();
        let cold = run_sweep(&mut cold_st, 0, &SweepConfig::default());

        let mut st = fragmented_figure1();
        let mut session = SolveSession::new();
        let warm = run_sweep_session(&mut st, 0, &SweepConfig::default(), Some(&mut session));
        assert_eq!(warm.applied, cold.applied);
        assert_eq!(warm.placed_after, cold.placed_after);
        assert_eq!(warm.moves, cold.moves);
        assert_eq!(st.assignment(), cold_st.assignment(), "byte-identical plan");
        assert_eq!(session.stats.optimizer_runs, 1);

        // The applied plan changed the state: the next sweep re-solves
        // (no-gain), and the one after that sees an unchanged cluster.
        let again = run_sweep_session(&mut st, 0, &SweepConfig::default(), Some(&mut session));
        assert!(!again.improved);
        assert_eq!(session.stats.optimizer_runs, 2);
        let third = run_sweep_session(&mut st, 0, &SweepConfig::default(), Some(&mut session));
        assert!(!third.improved);
        assert_eq!(session.stats.optimizer_runs, 2, "replayed, not re-solved");
        assert_eq!(session.stats.full_hits, 1);
    }

    #[test]
    fn sweep_ignores_unready_nodes() {
        // Node 0 is cordoned and holds pod 0. Nodes 1 and 2 fragmented
        // the figure-1 way (two small pods spread, the big one pending):
        // an improving re-pack exists using only ready nodes, so the
        // sweep MUST apply — and must not touch the cordoned node while
        // doing it.
        let nodes = identical_nodes(3, Resources::new(4000, 4096));
        let pods = vec![
            Pod::new(0, "resident", Resources::new(10, 2048), Priority(0)),
            Pod::new(1, "small-1", Resources::new(10, 2048), Priority(0)),
            Pod::new(2, "small-2", Resources::new(10, 2048), Priority(0)),
            Pod::new(3, "big", Resources::new(10, 3072), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        st.bind(PodId(1), NodeId(1)).unwrap();
        st.bind(PodId(2), NodeId(2)).unwrap();
        st.cordon(NodeId(0));

        let report = run_sweep(&mut st, 0, &SweepConfig::default());
        assert!(report.improved, "re-pack on ready nodes is lex-better");
        assert!(report.applied);
        assert_eq!(report.placed_after, vec![4]);
        // the cordoned node kept exactly its resident pod
        assert_eq!(st.pods_on(NodeId(0)), vec![PodId(0)]);
        assert!(st.assignment_of(PodId(3)).is_some());
        st.check_invariants().unwrap();
    }
}
