//! Periodic CP defragmentation sweeps.
//!
//! The paper runs the optimiser as a *fallback* when pods go pending.
//! A sweep is the descheduler-style complement: on a timer, re-pack the
//! live cluster with Algorithm 1 and execute the resulting move plan —
//! but only when it strictly improves the per-priority placement vector
//! and stays within an eviction budget (disruption is not free in a real
//! cluster: every move restarts a container).

use crate::cluster::{ClusterState, Event};
use crate::metrics::lex_better;
use crate::optimizer::algorithm::{optimize, OptimizerConfig};
use crate::optimizer::plan::MovePlan;

/// Sweep policy knobs.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Algorithm 1 configuration for the re-pack solve.
    pub optimizer: OptimizerConfig,
    /// Maximum pods whose node may change in one sweep; improving plans
    /// above the budget are reported but not applied.
    pub eviction_budget: usize,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            optimizer: OptimizerConfig::with_timeout(2.0),
            eviction_budget: 8,
        }
    }
}

/// What one sweep did.
#[derive(Clone, Debug, Default)]
pub struct SweepReport {
    /// Solver produced an improving target.
    pub improved: bool,
    /// The improving plan fit the eviction budget and was executed.
    pub applied: bool,
    /// Disruptions of the plan (moves + displacements); reported even
    /// when the budget vetoed application.
    pub moves: usize,
    pub placed_before: Vec<usize>,
    pub placed_after: Vec<usize>,
}

/// Run one defragmentation sweep over the live cluster.
pub fn run_sweep(state: &mut ClusterState, p_max: u32, cfg: &SweepConfig) -> SweepReport {
    let placed_before = state.placed_per_priority(p_max);
    state.events.push(Event::SweepStarted {
        pending: state.pending_pods().len(),
        at_ms: state.time_ms(),
    });

    let mut report = SweepReport {
        placed_after: placed_before.clone(),
        placed_before,
        ..Default::default()
    };

    if let Some(res) = optimize(state, p_max, &cfg.optimizer) {
        if lex_better(&res.placed_per_priority, &report.placed_before) {
            report.improved = true;
            let plan = MovePlan::build(state, &res.target);
            report.moves = plan.disruptions();
            if report.moves <= cfg.eviction_budget {
                plan.execute(state)
                    .expect("sweep plan must apply to the state it was built on");
                report.applied = true;
                report.placed_after = state.placed_per_priority(p_max);
            }
        }
    }

    state.events.push(Event::SweepFinished {
        improved: report.improved,
        applied: report.applied,
        moves: report.moves,
        at_ms: state.time_ms(),
    });
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, NodeId, Pod, PodId, Priority, Resources};

    /// Figure 1 after the default scheduler fragmented it: pods 0 and 1
    /// spread over both nodes, pod 2 stranded pending.
    fn fragmented_figure1() -> ClusterState {
        let nodes = identical_nodes(2, Resources::new(4000, 4096));
        let pods = vec![
            Pod::new(0, "pod-1", Resources::new(10, 2048), Priority(0)),
            Pod::new(1, "pod-2", Resources::new(10, 2048), Priority(0)),
            Pod::new(2, "pod-3", Resources::new(10, 3072), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        st.bind(PodId(1), NodeId(1)).unwrap();
        st
    }

    #[test]
    fn sweep_defragments_within_budget() {
        let mut st = fragmented_figure1();
        let report = run_sweep(&mut st, 0, &SweepConfig::default());
        assert!(report.improved);
        assert!(report.applied);
        assert_eq!(report.placed_before, vec![2]);
        assert_eq!(report.placed_after, vec![3]);
        assert!(report.moves >= 1);
        st.check_invariants().unwrap();
        assert_eq!(st.pending_pods(), Vec::<PodId>::new());
        // event trail records the sweep
        assert!(st
            .events
            .all()
            .iter()
            .any(|e| matches!(e, Event::SweepFinished { applied: true, .. })));
    }

    #[test]
    fn eviction_budget_vetoes_application() {
        let mut st = fragmented_figure1();
        let cfg = SweepConfig {
            eviction_budget: 0,
            ..Default::default()
        };
        let report = run_sweep(&mut st, 0, &cfg);
        assert!(report.improved, "solver still finds the better packing");
        assert!(!report.applied, "budget 0 must veto the move");
        assert_eq!(report.placed_after, report.placed_before);
        // cluster untouched
        assert_eq!(st.assignment_of(PodId(2)), None);
        st.check_invariants().unwrap();
    }

    #[test]
    fn sweep_is_a_no_op_on_optimal_clusters() {
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "a", Resources::new(400, 400), Priority(0)),
            Pod::new(1, "b", Resources::new(400, 400), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        st.bind(PodId(1), NodeId(1)).unwrap();
        let report = run_sweep(&mut st, 0, &SweepConfig::default());
        assert!(!report.improved);
        assert!(!report.applied);
        assert_eq!(st.assignment_of(PodId(0)), Some(NodeId(0)));
        assert_eq!(st.assignment_of(PodId(1)), Some(NodeId(1)));
    }

    #[test]
    fn sweep_ignores_unready_nodes() {
        // Node 0 is cordoned and holds pod 0. Nodes 1 and 2 fragmented
        // the figure-1 way (two small pods spread, the big one pending):
        // an improving re-pack exists using only ready nodes, so the
        // sweep MUST apply — and must not touch the cordoned node while
        // doing it.
        let nodes = identical_nodes(3, Resources::new(4000, 4096));
        let pods = vec![
            Pod::new(0, "resident", Resources::new(10, 2048), Priority(0)),
            Pod::new(1, "small-1", Resources::new(10, 2048), Priority(0)),
            Pod::new(2, "small-2", Resources::new(10, 2048), Priority(0)),
            Pod::new(3, "big", Resources::new(10, 3072), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        st.bind(PodId(1), NodeId(1)).unwrap();
        st.bind(PodId(2), NodeId(2)).unwrap();
        st.cordon(NodeId(0));

        let report = run_sweep(&mut st, 0, &SweepConfig::default());
        assert!(report.improved, "re-pack on ready nodes is lex-better");
        assert!(report.applied);
        assert_eq!(report.placed_after, vec![4]);
        // the cordoned node kept exactly its resident pod
        assert_eq!(st.pods_on(NodeId(0)), vec![PodId(0)]);
        assert!(st.assignment_of(PodId(3)).is_some());
        st.check_invariants().unwrap();
    }
}
