//! Discrete-event cluster lifecycle simulation.
//!
//! The paper evaluates one-shot allocation: generate pods, drain the
//! queue once, compare placements. Real clusters *evolve* — pods arrive
//! and complete, ReplicaSets scale, nodes drain and join — and
//! fragmentation is a phenomenon of that evolution. This layer adds the
//! missing time axis:
//!
//! * [`clock`]    — monotonic virtual time (the simulator never sleeps).
//! * [`timeline`] — the ordered event queue with deterministic same-tick
//!   ordering (insertion-sequence tie-break).
//! * [`sweep`]    — descheduler-style periodic defragmentation: re-pack
//!   the live cluster with Algorithm 1 under an eviction budget.
//! * [`trace`]    — byte-stable event logs with FNV digests, so replay
//!   determinism is a testable property.
//! * [`churn`]    — the driver: consumes a seeded
//!   [`ChurnTrace`](crate::workload::churn::ChurnTrace) and runs one of
//!   three policies (default-only / fallback / fallback+sweep) over the
//!   same timeline for apples-to-apples comparison.

pub mod churn;
pub mod clock;
pub mod sweep;
pub mod timeline;
pub mod trace;

pub use churn::{
    compare_policies, compare_policies_traced, run_churn, run_churn_traced, ChurnConfig,
    ChurnResult, Policy,
};
pub use clock::SimClock;
pub use sweep::{run_sweep, run_sweep_session, run_sweep_session_traced, SweepConfig, SweepReport};
pub use timeline::{LifecycleEvent, Timeline};
pub use trace::ChurnLog;
