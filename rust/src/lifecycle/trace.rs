//! Deterministic churn event log.
//!
//! Every simulation appends fixed-format lines (virtual timestamps only —
//! never wall-clock), so two runs over the same trace and configuration
//! produce *byte-identical* renderings. The FNV-1a digest gives tests and
//! the CLI a cheap equality check without diffing full logs.

use super::clock::fmt_ms;

/// 64-bit FNV-1a over raw bytes.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Append-only, render-stable event log for churn runs.
#[derive(Clone, Debug, Default)]
pub struct ChurnLog {
    lines: Vec<String>,
}

impl ChurnLog {
    pub fn new() -> Self {
        ChurnLog::default()
    }

    /// Append one timestamped line.
    pub fn push(&mut self, at_ms: u64, msg: impl AsRef<str>) {
        self.lines.push(format!("[{}] {}", fmt_ms(at_ms), msg.as_ref()));
    }

    pub fn len(&self) -> usize {
        self.lines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }

    pub fn lines(&self) -> &[String] {
        &self.lines
    }

    /// The full log as one string (stable across identical runs).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for l in &self.lines {
            out.push_str(l);
            out.push('\n');
        }
        out
    }

    /// FNV-1a digest of the rendering.
    pub fn digest(&self) -> u64 {
        fnv1a64(self.render().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_is_stable_and_digest_matches() {
        let mut a = ChurnLog::new();
        a.push(0, "deploy rs-000 x2");
        a.push(1500, "complete rs-000-1");
        let mut b = ChurnLog::new();
        b.push(0, "deploy rs-000 x2");
        b.push(1500, "complete rs-000-1");
        assert_eq!(a.render(), b.render());
        assert_eq!(a.digest(), b.digest());
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn digest_detects_any_difference() {
        let mut a = ChurnLog::new();
        a.push(0, "deploy rs-000 x2");
        let mut b = ChurnLog::new();
        b.push(0, "deploy rs-000 x3");
        assert_ne!(a.digest(), b.digest());
        let mut c = ChurnLog::new();
        c.push(1, "deploy rs-000 x2"); // same text, different tick
        assert_ne!(a.digest(), c.digest());
    }

    #[test]
    fn fnv_reference_values() {
        // Standard FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
