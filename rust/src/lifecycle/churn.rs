//! The discrete-event cluster lifecycle simulator.
//!
//! Consumes a [`ChurnTrace`] and drives the existing schedulers through
//! virtual time: pods arrive and complete, ReplicaSets scale, nodes
//! drain and join, and — depending on the [`Policy`] — the CP optimiser
//! runs as a pending-pod fallback (paper semantics) and/or as a periodic
//! defragmentation sweep under an eviction budget.
//!
//! Determinism contract: the same `(trace, config)` pair produces a
//! byte-identical [`ChurnLog`] and identical end metrics, because every
//! source of order is pinned — the timeline tie-breaks same-tick events
//! by insertion sequence, schedulers are rebuilt per round (no hidden
//! queue state leaks across ticks), and the log records virtual time
//! only, never wall-clock. One caveat: [`Policy::DefaultOnly`] is
//! unconditionally deterministic, while the solver-backed policies
//! inherit the CP solver's *anytime* behaviour — a solve that hits its
//! wall-clock budget returns the best incumbent found in real time, so
//! replay identity additionally requires every solve to finish within
//! budget (proven optimal), which small incremental models do in
//! practice.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::autoscaler::{
    consolidation_log_line, run_consolidation, AutoscaleConfig, AutoscaleStats, NodePool,
};
use crate::cluster::{ClusterState, Event, EvictCause, NodeId, PodId, ReplicaSet, Resources};
use crate::metrics::{pending_per_priority, TimeSeries, UtilSample};
use crate::optimizer::algorithm::OptimizerConfig;
use crate::optimizer::session::{fingerprint_state, SolveSession};
use crate::optimizer::OptimizingScheduler;
use crate::portfolio::PortfolioConfig;
use crate::scheduler::DefaultScheduler;
use crate::telemetry::Telemetry;
use crate::workload::churn::{ChurnTrace, TraceOp};

use super::clock::SimClock;
use super::sweep::{run_sweep_session_traced, SweepConfig};
use super::timeline::{LifecycleEvent, Timeline};
use super::trace::ChurnLog;

/// How the cluster reacts to pending pods and fragmentation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Default scheduler only (the KWOK baseline).
    DefaultOnly,
    /// Default scheduler + CP optimiser fallback on pending pods.
    Fallback,
    /// Fallback + periodic defragmentation sweeps.
    FallbackSweep,
}

impl Policy {
    pub fn label(self) -> &'static str {
        match self {
            Policy::DefaultOnly => "default-only",
            Policy::Fallback => "fallback",
            Policy::FallbackSweep => "fallback+sweep",
        }
    }
}

/// Lifecycle run configuration.
#[derive(Clone, Debug)]
pub struct ChurnConfig {
    pub policy: Policy,
    /// Sweep period in virtual ms (0 disables sweeps even under
    /// [`Policy::FallbackSweep`]).
    pub sweep_every_ms: u64,
    pub sweep: SweepConfig,
    /// `T_total` handed to each fallback optimisation.
    pub fallback_timeout: Duration,
    /// Portfolio knobs for the fallback optimiser (sweeps carry their
    /// own inside [`SweepConfig`]'s `optimizer`).
    pub fallback_portfolio: PortfolioConfig,
    /// Keep incremental [`SolveSession`]s alive across the run: one for
    /// the fallback optimiser, one for the sweeps. Consecutive solves
    /// over near-identical states replay proven certificates and
    /// warm-start the rest — byte-identical results, less work (the
    /// churn CLI's `--incremental`).
    pub incremental: bool,
    /// Opt-in CP-driven autoscaling (the churn CLI's `--autoscale`):
    /// certified-unplaceable pods trigger min-cost provisioning inside
    /// the fallback pass, and — when the policy is solver-backed and
    /// `consolidate` is set — a consolidation scale-down pass runs at
    /// every sweep tick. Ignored under [`Policy::DefaultOnly`] (both
    /// directions need the solver's certificates). `None` is
    /// byte-identical to the pre-autoscaler simulator.
    pub autoscale: Option<AutoscaleConfig>,
}

impl ChurnConfig {
    pub fn for_policy(policy: Policy) -> ChurnConfig {
        ChurnConfig {
            policy,
            sweep_every_ms: 5_000,
            sweep: SweepConfig::default(),
            fallback_timeout: Duration::from_secs(2),
            fallback_portfolio: PortfolioConfig::default(),
            incremental: false,
            autoscale: None,
        }
    }
}

/// Everything a churn run produces.
#[derive(Clone, Debug)]
pub struct ChurnResult {
    pub policy: Policy,
    /// Distinct pods that were ever bound, per priority tier — the
    /// cumulative service metric the policies are compared on.
    pub served_per_priority: Vec<usize>,
    /// Placement vector at the horizon.
    pub final_placed: Vec<usize>,
    /// Pods still pending at the horizon.
    pub final_pending: usize,
    /// Ready nodes at the horizon — the number the autoscaler grows and
    /// shrinks (cordoned and removed nodes excluded).
    pub final_ready_nodes: usize,
    /// Fingerprint of the solve-relevant end state
    /// ([`fingerprint_state`]) — what the daemon ⇄ simulator
    /// equivalence test compares against [`Engine::digest`].
    ///
    /// [`Engine::digest`]: crate::server::engine::Engine::digest
    pub final_state_digest: u64,
    /// Pods that arrived, per priority tier.
    pub arrivals_per_priority: Vec<usize>,
    pub completions: usize,
    /// Total evictions, all causes — always the sum of the three
    /// attributed counters below.
    pub evictions: usize,
    /// Forced displacements by the fallback optimiser's plan.
    pub evictions_preemption: usize,
    /// Elective moves by the periodic defragmentation sweep.
    pub evictions_sweep: usize,
    /// Drain-ordered evictions (node lifecycle, not the optimiser).
    pub evictions_drain: usize,
    pub solver_invocations: usize,
    pub sweeps_run: usize,
    pub sweeps_applied: usize,
    /// Lifecycle events processed (timeline pops).
    pub events_processed: usize,
    /// Incremental-session counters, summed over the fallback and sweep
    /// sessions (all zero when `incremental` is off): full-state
    /// replays, per-solve cache hits, per-component cache hits, and
    /// warm-start floors seeded.
    pub session_full_hits: u64,
    pub solve_cache_hits: u64,
    pub component_cache_hits: u64,
    pub warm_starts: u64,
    /// Autoscaler activity over the run (all zero with `autoscale` off):
    /// per-cycle scale-up and consolidation decisions, aggregated.
    pub autoscale: AutoscaleStats,
    pub series: TimeSeries,
    pub log: ChurnLog,
}

impl ChurnResult {
    /// Total pods ever served across tiers.
    pub fn served_total(&self) -> usize {
        self.served_per_priority.iter().sum()
    }
}

/// Run one policy over one trace.
pub fn run_churn(trace: &ChurnTrace, cfg: &ChurnConfig) -> ChurnResult {
    run_churn_traced(trace, cfg, &Telemetry::off())
}

/// [`run_churn`] recording onto a caller-owned [`Telemetry`] handle:
/// the run becomes a `churn` span enclosing every per-tick sweep,
/// consolidation, and fallback-solve span, plus `churn_*` counters.
/// Telemetry observes the run and never feeds back — recorded and
/// unrecorded runs produce byte-identical [`ChurnLog`]s.
pub fn run_churn_traced(trace: &ChurnTrace, cfg: &ChurnConfig, tel: &Telemetry) -> ChurnResult {
    ChurnRunner::new(trace, cfg).run(tel)
}

/// Run all three policies over the same trace (the comparison the churn
/// report renders).
pub fn compare_policies(trace: &ChurnTrace, base: &ChurnConfig) -> Vec<ChurnResult> {
    compare_policies_traced(trace, base, &Telemetry::off())
}

/// [`compare_policies`] recording each policy's run onto `tel` (runs are
/// sequential, so spans land in policy order).
pub fn compare_policies_traced(
    trace: &ChurnTrace,
    base: &ChurnConfig,
    tel: &Telemetry,
) -> Vec<ChurnResult> {
    [Policy::DefaultOnly, Policy::Fallback, Policy::FallbackSweep]
        .into_iter()
        .map(|policy| {
            run_churn_traced(
                trace,
                &ChurnConfig {
                    policy,
                    ..base.clone()
                },
                tel,
            )
        })
        .collect()
}

struct ChurnRunner {
    cfg: ChurnConfig,
    p_max: u32,
    horizon_ms: u64,
    /// Events of `state.events` already scanned for binds/evictions.
    seen_events: usize,
    /// Running eviction counts (incremental mirror of the event log, so
    /// per-tick sampling never rescans the whole log), split by driver.
    evictions_total: usize,
    evictions_preemption: usize,
    evictions_sweep: usize,
    evictions_drain: usize,
    /// Incremental solve sessions (alive for the whole run when
    /// `cfg.incremental`); the fallback and the sweep each own one —
    /// they solve under different configs, so their certificates never
    /// interchange.
    fallback_session: Option<SolveSession>,
    sweep_session: Option<SolveSession>,
    /// Memoized non-applied provisioning outcome, carried across the
    /// per-round scheduler rebuilds (like the sessions): an unchanged
    /// cluster replays a proven scale-up failure instead of re-burning
    /// the provisioning window every tick.
    provision_memo: Option<(u64, crate::autoscaler::ScaleUpReport)>,
    state: ClusterState,
    clock: SimClock,
    timeline: Timeline,
    log: ChurnLog,
    series: TimeSeries,
    /// ReplicaSet templates by id (trace-born sets included).
    rs_catalog: BTreeMap<u32, ReplicaSet>,
    /// Pods created per ReplicaSet, in creation order (may contain
    /// already-retired pods; scale-down skips them lazily).
    rs_pods: BTreeMap<u32, Vec<PodId>>,
    rs_next_ordinal: BTreeMap<u32, u32>,
    /// Parallel to the state's pod table: ever bound at least once.
    ever_bound: Vec<bool>,
    served: Vec<usize>,
    arrivals: Vec<usize>,
    completions: usize,
    solver_invocations: usize,
    sweeps_run: usize,
    sweeps_applied: usize,
    events_processed: usize,
    sweep_due: bool,
    autoscale: AutoscaleStats,
}

impl ChurnRunner {
    fn new(trace: &ChurnTrace, cfg: &ChurnConfig) -> ChurnRunner {
        let mut timeline = Timeline::new();
        for (at, op) in &trace.ops {
            timeline.schedule(*at, LifecycleEvent::Trace(op.clone()));
        }
        // Sweep ticks drive the defrag sweep (FallbackSweep) and the
        // autoscaler's consolidation pass (any solver-backed policy with
        // `consolidate` armed) — with autoscale off this is exactly the
        // historical FallbackSweep-only schedule.
        let consolidating = cfg.policy != Policy::DefaultOnly
            && cfg.autoscale.as_ref().is_some_and(|a| a.consolidate);
        if (cfg.policy == Policy::FallbackSweep || consolidating) && cfg.sweep_every_ms > 0 {
            let mut t = cfg.sweep_every_ms;
            while t <= trace.params.horizon_ms {
                timeline.schedule(t, LifecycleEvent::OptimizerSweep);
                t += cfg.sweep_every_ms;
            }
        }
        // Pin the autoscaler's reference capacity to the trace's
        // canonical one: deriving it per-cycle from the live fleet would
        // let an autoscaled `large` node inflate every later scale-up's
        // candidate sizes (same cost, 1.5x capacity, geometrically).
        let mut cfg = cfg.clone();
        if let Some(a) = &mut cfg.autoscale {
            if a.reference.is_none() {
                a.reference = Some(trace.reference_capacity);
            }
        }
        let tiers = trace.p_max as usize + 1;
        ChurnRunner {
            p_max: trace.p_max,
            horizon_ms: trace.params.horizon_ms,
            seen_events: 0,
            evictions_total: 0,
            evictions_preemption: 0,
            evictions_sweep: 0,
            evictions_drain: 0,
            fallback_session: cfg.incremental.then(SolveSession::new),
            sweep_session: cfg.incremental.then(SolveSession::new),
            provision_memo: None,
            cfg: cfg.clone(),
            state: ClusterState::new(trace.nodes.clone(), Vec::new()),
            clock: SimClock::new(),
            timeline,
            log: ChurnLog::new(),
            series: TimeSeries::new(),
            rs_catalog: BTreeMap::new(),
            rs_pods: BTreeMap::new(),
            rs_next_ordinal: BTreeMap::new(),
            ever_bound: Vec::new(),
            served: vec![0; tiers],
            arrivals: vec![0; tiers],
            completions: 0,
            solver_invocations: 0,
            sweeps_run: 0,
            sweeps_applied: 0,
            events_processed: 0,
            sweep_due: false,
            autoscale: AutoscaleStats::default(),
        }
    }

    fn run(mut self, tel: &Telemetry) -> ChurnResult {
        let sp = tel.span("churn");
        sp.arg("policy", self.cfg.policy.label());
        while let Some((t, ev)) = self.timeline.pop_next() {
            if t > self.horizon_ms {
                // The horizon is a hard cut: completions scheduled past it
                // never fire, matching the end metrics' "at the horizon"
                // semantics (and the sweeps, which stop there too).
                break;
            }
            self.clock.advance_to(t);
            self.state.set_time(t);
            self.sweep_due = false;
            self.apply(t, ev);
            // Batch every event sharing this tick before scheduling.
            while self.timeline.peek_ms() == Some(t) {
                let (_, ev) = self.timeline.pop_next().expect("peeked event exists");
                self.apply(t, ev);
            }
            self.schedule_round(t, tel);
            if self.sweep_due {
                if self.cfg.policy == Policy::FallbackSweep {
                    self.defrag_sweep(t, tel);
                }
                // Consolidation runs after the defrag sweep: a freshly
                // compacted cluster is exactly when nodes become
                // provably drainable.
                self.consolidation_pass(t, tel);
            }
            self.absorb_events();
            let (cpu, ram) = self.state.utilization();
            self.series.push(UtilSample {
                at_ms: t,
                cpu,
                ram,
                pending_per_priority: pending_per_priority(&self.state, self.p_max),
                placed_per_priority: self.state.placed_per_priority(self.p_max),
                evictions: self.evictions_total,
            });
        }
        sp.arg("events", self.events_processed);
        sp.arg("solves", self.solver_invocations);
        if tel.enabled() {
            tel.add("churn_events_total", "", self.events_processed as u64);
            tel.add("churn_solver_invocations_total", "", self.solver_invocations as u64);
            tel.add("churn_evictions_total", "", self.evictions_total as u64);
        }
        let (mut full_hits, mut solve_hits, mut component_hits, mut warm) = (0, 0, 0, 0);
        for session in [&self.fallback_session, &self.sweep_session].into_iter().flatten() {
            full_hits += session.stats.full_hits;
            let c = session.cache_stats();
            solve_hits += c.solve_hits;
            component_hits += c.component_hits;
            warm += c.warm_seeds;
        }
        ChurnResult {
            policy: self.cfg.policy,
            served_per_priority: self.served,
            final_placed: self.state.placed_per_priority(self.p_max),
            final_pending: self.state.pending_pods().len(),
            final_ready_nodes: self
                .state
                .nodes()
                .iter()
                .filter(|n| self.state.node_ready(n.id))
                .count(),
            final_state_digest: fingerprint_state(&self.state, self.p_max),
            arrivals_per_priority: self.arrivals,
            completions: self.completions,
            evictions: self.evictions_total,
            evictions_preemption: self.evictions_preemption,
            evictions_sweep: self.evictions_sweep,
            evictions_drain: self.evictions_drain,
            solver_invocations: self.solver_invocations,
            sweeps_run: self.sweeps_run,
            sweeps_applied: self.sweeps_applied,
            events_processed: self.events_processed,
            session_full_hits: full_hits,
            solve_cache_hits: solve_hits,
            component_cache_hits: component_hits,
            warm_starts: warm,
            autoscale: self.autoscale,
            series: self.series,
            log: self.log,
        }
    }

    // ---- event application ------------------------------------------------

    fn apply(&mut self, at: u64, ev: LifecycleEvent) {
        self.events_processed += 1;
        match ev {
            LifecycleEvent::Trace(op) => match op {
                TraceOp::Deploy { rs, lifetimes_ms } => self.deploy(at, rs, &lifetimes_ms),
                TraceOp::Scale {
                    rs,
                    delta,
                    lifetimes_ms,
                } => self.scale(at, rs, delta, &lifetimes_ms),
                TraceOp::Drain { node } => self.apply_drain(at, node),
                TraceOp::Join { capacity, pool } => self.apply_join(at, capacity, pool),
            },
            LifecycleEvent::PodCompletion { pod } => self.complete(at, pod),
            LifecycleEvent::OptimizerSweep => self.sweep_due = true,
        }
    }

    fn deploy(&mut self, at: u64, rs: ReplicaSet, lifetimes_ms: &[u64]) {
        self.log.push(
            at,
            format!(
                "deploy {} x{} ({}, prio {})",
                rs.name, rs.replicas, rs.template_request, rs.priority.0
            ),
        );
        let rs_id = rs.id;
        self.rs_catalog.insert(rs_id, rs);
        self.rs_pods.insert(rs_id, Vec::new());
        self.rs_next_ordinal.insert(rs_id, 0);
        for &life in lifetimes_ms {
            self.spawn_replica(at, rs_id, life);
        }
    }

    /// Create one replica of a catalogued ReplicaSet and schedule its
    /// completion.
    fn spawn_replica(&mut self, at: u64, rs_id: u32, lifetime_ms: u64) {
        let rs = self.rs_catalog.get(&rs_id).cloned().expect("catalogued rs");
        let ord = {
            let o = self.rs_next_ordinal.get_mut(&rs_id).expect("catalogued rs");
            let v = *o;
            *o += 1;
            v
        };
        // Dense id 0 is a placeholder — add_pod reassigns it. The whole
        // template (request, priority, constraint fields) is stamped by
        // the one shared instantiation path.
        let pod = rs.instantiate(0, ord);
        let id = self.state.add_pod(pod);
        self.ever_bound.push(false);
        self.arrivals[rs.priority.0 as usize] += 1;
        self.rs_pods.get_mut(&rs_id).expect("catalogued rs").push(id);
        self.timeline
            .schedule(at + lifetime_ms, LifecycleEvent::PodCompletion { pod: id });
    }

    fn scale(&mut self, at: u64, rs_id: u32, delta: i32, lifetimes_ms: &[u64]) {
        let Some(name) = self.rs_catalog.get(&rs_id).map(|r| r.name.clone()) else {
            self.log.push(at, format!("scale rs#{rs_id} skipped (unknown)"));
            return;
        };
        if delta >= 0 {
            self.log.push(at, format!("scale {name} +{delta}"));
            for &life in lifetimes_ms {
                self.spawn_replica(at, rs_id, life);
            }
        } else {
            // Kubernetes downscale preference: newest replicas first.
            let mut want = (-delta) as usize;
            let mut terminated = 0usize;
            while want > 0 {
                let Some(pod) = self.rs_pods.get_mut(&rs_id).expect("catalogued rs").pop()
                else {
                    break;
                };
                if self.state.is_retired(pod) {
                    continue; // completed earlier; not a live replica
                }
                self.state.terminate(pod).expect("live pod terminates");
                terminated += 1;
                want -= 1;
            }
            self.log
                .push(at, format!("scale {name} {delta} terminated={terminated}"));
        }
    }

    fn complete(&mut self, at: u64, pod: PodId) {
        if self.state.is_retired(pod) {
            return; // already removed by a scale-down
        }
        let node = self.state.terminate(pod).expect("non-retired pod");
        self.completions += 1;
        let name = &self.state.pod(pod).name;
        match node {
            Some(n) => {
                let line = format!("complete {name} (ran on {})", self.state.node(n).name);
                self.log.push(at, line);
            }
            None => {
                let line = format!("complete {name} (never placed)");
                self.log.push(at, line);
            }
        }
    }

    fn apply_drain(&mut self, at: u64, node_ord: u32) {
        let idx = node_ord as usize;
        if idx >= self.state.nodes().len() || !self.state.node_ready(NodeId(node_ord)) {
            self.log.push(at, format!("drain node#{node_ord} skipped"));
            return;
        }
        let node = NodeId(node_ord);
        let victims = self.state.drain(node);
        let line = format!(
            "drain {} evicted={}",
            self.state.node(node).name,
            victims.len()
        );
        self.log.push(at, line);
    }

    fn apply_join(&mut self, at: u64, capacity: Resources, pool: Option<NodePool>) {
        let line = match pool {
            Some(p) => {
                // Pool joins arrive decorated (labels, taints, extended
                // capacities) at the trace's pre-computed capacity —
                // through the pool's one decoration path.
                let id = self
                    .state
                    .join_node_from(&p.node_template_with_capacity(capacity));
                format!("join {} ({})", self.state.node(id).name, p.name)
            }
            None => {
                let id = self.state.join_node(capacity);
                format!("join {}", self.state.node(id).name)
            }
        };
        self.log.push(at, line);
    }

    // ---- scheduling -------------------------------------------------------

    /// One scheduling round at the end of a tick. Schedulers are rebuilt
    /// per round: `ClusterState` is the only carrier of cross-tick truth,
    /// which keeps replay deterministic and avoids stale queue entries.
    fn schedule_round(&mut self, at: u64, tel: &Telemetry) {
        if self.state.pending_pods().is_empty() {
            return;
        }
        match self.cfg.policy {
            Policy::DefaultOnly => {
                let mut sched = DefaultScheduler::kwok_default();
                sched.enqueue_pending(&self.state);
                let stats = sched.run_queue(&mut self.state);
                let line = format!(
                    "schedule bound={} pending={}",
                    stats.bound, stats.unschedulable
                );
                self.log.push(at, line);
            }
            Policy::Fallback | Policy::FallbackSweep => {
                // The scheduler is rebuilt per round (no hidden queue
                // state across ticks); the solve session and the
                // provisioning-failure memo are the deliberate carriers
                // of cross-tick solver knowledge.
                let mut osched = OptimizingScheduler::new(
                    self.p_max,
                    OptimizerConfig {
                        total_timeout: self.cfg.fallback_timeout,
                        portfolio: self.cfg.fallback_portfolio.clone(),
                        autoscale: self.cfg.autoscale.clone(),
                        ..Default::default()
                    },
                );
                osched.set_provision_memo(self.provision_memo.take());
                let report = osched.run_with_session_traced(
                    &mut self.state,
                    self.fallback_session.as_mut(),
                    tel,
                );
                self.provision_memo = osched.take_provision_memo();
                let pending_after = self.state.pending_pods().len();
                if report.solver_invoked {
                    self.solver_invocations += 1;
                    let line = format!(
                        "fallback placed={:?}->{:?} moves={} pending={}",
                        report.placed_before, report.placed_after, report.disruptions, pending_after
                    );
                    self.log.push(at, line);
                    if let Some(up) = &report.autoscale {
                        self.log.push(at, up.log_line());
                        self.autoscale.absorb_scale_up(up);
                    }
                } else {
                    let line = format!(
                        "schedule bound={} pending={pending_after}",
                        report.default_stats.bound
                    );
                    self.log.push(at, line);
                }
            }
        }
    }

    fn defrag_sweep(&mut self, at: u64, tel: &Telemetry) {
        self.sweeps_run += 1;
        let report = run_sweep_session_traced(
            &mut self.state,
            self.p_max,
            &self.cfg.sweep,
            self.sweep_session.as_mut(),
            tel,
        );
        if report.applied {
            self.sweeps_applied += 1;
            let line = format!(
                "sweep applied placed={:?}->{:?} moves={}",
                report.placed_before, report.placed_after, report.moves
            );
            self.log.push(at, line);
        } else if report.improved {
            let line = format!(
                "sweep veto (budget) placed={:?} moves={}",
                report.placed_before, report.moves
            );
            self.log.push(at, line);
        } else {
            self.log
                .push(at, format!("sweep no-gain placed={:?}", report.placed_before));
        }
    }

    /// Autoscaler scale-down at a sweep tick: prove nodes drainable
    /// (certified lossless re-pack within the budget), then drain and
    /// remove them. Reuses the sweep's optimiser config and — under
    /// `--incremental` — the sweep's solve session for warm starts.
    fn consolidation_pass(&mut self, at: u64, tel: &Telemetry) {
        let Some(acfg) = self.cfg.autoscale.clone() else {
            return;
        };
        if !acfg.consolidate || self.cfg.policy == Policy::DefaultOnly {
            return;
        }
        let pass = run_consolidation(
            &mut self.state,
            self.p_max,
            &acfg,
            &self.cfg.sweep.optimizer,
            self.sweep_session.as_mut(),
            tel,
        );
        let names: Vec<String> = pass
            .removed
            .iter()
            .map(|&n| self.state.node(n).name.clone())
            .collect();
        self.log.push(at, consolidation_log_line(&pass, &names));
        self.autoscale.absorb_consolidation(&pass);
    }

    /// Absorb the event-log suffix appended since the last tick: credit
    /// first-time binds to the service metric (every bind — default,
    /// plan, or sweep — lands in the log) and keep the running eviction
    /// count. Suffix-only scanning keeps the per-tick cost proportional
    /// to activity, not to the ever-growing pod table or event log.
    fn absorb_events(&mut self) {
        let events = self.state.events.all();
        for e in &events[self.seen_events..] {
            let pod = match e {
                Event::Bind { pod, .. } | Event::PlanBind { pod, .. } => *pod,
                Event::Evict { cause, .. } => {
                    self.evictions_total += 1;
                    match cause {
                        EvictCause::Preemption => self.evictions_preemption += 1,
                        EvictCause::Sweep => self.evictions_sweep += 1,
                        EvictCause::Drain => self.evictions_drain += 1,
                    }
                    continue;
                }
                _ => continue,
            };
            let i = pod.idx();
            if !self.ever_bound[i] {
                self.ever_bound[i] = true;
                self.served[self.state.pods()[i].priority.0 as usize] += 1;
            }
        }
        self.seen_events = events.len();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::churn::{ChurnParams, ChurnTraceGenerator};
    use crate::workload::GenParams;

    fn tiny_trace(seed: u64) -> ChurnTrace {
        ChurnTraceGenerator::new(
            ChurnParams {
                horizon_ms: 4_000,
                mean_arrival_ms: 400,
                mean_lifetime_ms: 1_500,
                ..ChurnParams::for_cluster(GenParams {
                    nodes: 3,
                    pods_per_node: 3,
                    priority_tiers: 2,
                    usage: 0.9,
                })
            },
            seed,
        )
        .generate()
    }

    #[test]
    fn default_only_run_accounts_for_every_pod() {
        let trace = tiny_trace(1);
        let res = run_churn(&trace, &ChurnConfig::for_policy(Policy::DefaultOnly));
        let arrived: usize = res.arrivals_per_priority.iter().sum();
        assert!(arrived >= trace.params.base.pod_count());
        // every arrival is served at some point, still pending, or
        // completed without ever binding — and served is a superset of
        // what remains placed at the horizon
        assert!(res.served_total() <= arrived);
        let placed: usize = res.final_placed.iter().sum();
        assert!(placed <= res.served_total());
        assert!(res.events_processed >= trace.ops.len());
        assert!(res.solver_invocations == 0);
        assert!(!res.series.is_empty());
        assert!(res.completions > 0, "lifetimes inside the horizon must fire");
    }

    #[test]
    fn replay_is_byte_identical() {
        let trace = tiny_trace(7);
        let a = run_churn(&trace, &ChurnConfig::for_policy(Policy::DefaultOnly));
        let b = run_churn(&trace, &ChurnConfig::for_policy(Policy::DefaultOnly));
        assert_eq!(a.log.render(), b.log.render());
        assert_eq!(a.log.digest(), b.log.digest());
        assert_eq!(a.served_per_priority, b.served_per_priority);
        assert_eq!(a.final_placed, b.final_placed);
    }

    #[test]
    fn different_seeds_diverge() {
        let a = run_churn(&tiny_trace(3), &ChurnConfig::for_policy(Policy::DefaultOnly));
        let b = run_churn(&tiny_trace(4), &ChurnConfig::for_policy(Policy::DefaultOnly));
        assert_ne!(a.log.digest(), b.log.digest());
    }

    #[test]
    fn sweeps_fire_only_under_fallback_sweep() {
        let trace = tiny_trace(5);
        let mut cfg = ChurnConfig::for_policy(Policy::Fallback);
        cfg.sweep_every_ms = 1_000;
        let res = run_churn(&trace, &cfg);
        assert_eq!(res.sweeps_run, 0);

        let mut cfg = ChurnConfig::for_policy(Policy::FallbackSweep);
        cfg.sweep_every_ms = 1_000;
        let res = run_churn(&trace, &cfg);
        assert_eq!(res.sweeps_run, 4, "one sweep per period inside the horizon");
    }

    #[test]
    fn eviction_split_sums_to_total_across_policies() {
        let trace = tiny_trace(9);
        for r in compare_policies(&trace, &ChurnConfig::for_policy(Policy::FallbackSweep)) {
            assert_eq!(
                r.evictions,
                r.evictions_preemption + r.evictions_sweep + r.evictions_drain,
                "split must partition the total for {}",
                r.policy.label()
            );
            if r.policy == Policy::DefaultOnly {
                // no optimiser, no sweeps: only drains may evict
                assert_eq!(r.evictions_preemption + r.evictions_sweep, 0);
            }
        }
    }

    #[test]
    fn eviction_attribution_pins_preemption_vs_sweep_vs_drain() {
        use crate::cluster::{identical_nodes, Pod, Priority};
        use crate::lifecycle::sweep::run_sweep;

        // One event trail that exercises all three drivers in turn.
        // Phase 1 — fallback pre-emption: a high-priority pod displaces
        // a low one (the plugin path).
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "lo-1", Resources::new(600, 600), Priority(1)),
            Pod::new(1, "lo-2", Resources::new(600, 600), Priority(1)),
            Pod::new(2, "hi", Resources::new(900, 900), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        st.bind(PodId(1), NodeId(1)).unwrap();
        let mut osched = OptimizingScheduler::new(1, OptimizerConfig::with_timeout(5.0));
        let report = osched.run(&mut st);
        assert!(report.improved);
        let preempted = st.events.evictions_by(EvictCause::Preemption);
        assert!(preempted >= 1, "fallback displaced a low-priority pod");
        assert_eq!(st.events.evictions_by(EvictCause::Sweep), 0);

        // Phase 2 — sweep move: two joined big nodes fragmented the
        // figure-1 way; the defrag sweep's re-pack move must be
        // attributed to the sweep, leaving the pre-emption count alone.
        st.join_node(Resources::new(4000, 4096));
        st.join_node(Resources::new(4000, 4096));
        let a = st.add_pod(Pod::new(0, "frag-1", Resources::new(10, 2048), Priority(1)));
        let b = st.add_pod(Pod::new(0, "frag-2", Resources::new(10, 2048), Priority(1)));
        let _c = st.add_pod(Pod::new(0, "frag-3", Resources::new(10, 3072), Priority(1)));
        st.bind(a, NodeId(2)).unwrap();
        st.bind(b, NodeId(3)).unwrap();
        let sweep_report = run_sweep(&mut st, 1, &SweepConfig::default());
        assert!(sweep_report.applied, "re-pack places the stranded pod");
        let swept = st.events.evictions_by(EvictCause::Sweep);
        assert!(swept >= 1, "sweep moved a pod");
        assert_eq!(
            st.events.evictions_by(EvictCause::Preemption),
            preempted,
            "sweep moves must not inflate the pre-emption count"
        );

        // Phase 3 — drain: node-lifecycle evictions get their own bucket.
        let victims = st.drain(NodeId(0));
        let drained = st.events.evictions_by(EvictCause::Drain);
        assert_eq!(drained, victims.len());

        // The split partitions the total.
        assert_eq!(st.events.evictions(), preempted + swept + drained);
    }

    #[test]
    fn incremental_churn_is_byte_identical_and_reuses_work() {
        // Quiet trace: long lifetimes, sparse arrivals, frequent sweeps —
        // consecutive re-pack solves see a near-unchanged cluster, which
        // is exactly what the session layer exists to exploit.
        let trace = ChurnTraceGenerator::new(
            ChurnParams {
                horizon_ms: 4_000,
                mean_arrival_ms: 2_000,
                mean_lifetime_ms: 60_000,
                ..ChurnParams::for_cluster(GenParams {
                    nodes: 3,
                    pods_per_node: 3,
                    priority_tiers: 1,
                    usage: 0.9,
                })
            },
            13,
        )
        .generate();
        let mut cold_cfg = ChurnConfig::for_policy(Policy::FallbackSweep);
        cold_cfg.sweep_every_ms = 500;
        cold_cfg.fallback_timeout = Duration::from_secs(5);
        cold_cfg.sweep.optimizer = OptimizerConfig::with_timeout(5.0);
        let warm_cfg = ChurnConfig {
            incremental: true,
            ..cold_cfg.clone()
        };

        let cold = run_churn(&trace, &cold_cfg);
        let warm = run_churn(&trace, &warm_cfg);

        // Determinism contract: sessions change speed, never results.
        assert_eq!(warm.log.render(), cold.log.render());
        assert_eq!(warm.log.digest(), cold.log.digest());
        assert_eq!(warm.served_per_priority, cold.served_per_priority);
        assert_eq!(warm.final_placed, cold.final_placed);
        assert_eq!(warm.evictions, cold.evictions);
        assert_eq!(warm.evictions_sweep, cold.evictions_sweep);

        // And the session actually reused work on this quiet trace.
        assert!(
            warm.session_full_hits + warm.solve_cache_hits + warm.component_cache_hits > 0,
            "no reuse recorded: full={} solve={} comp={}",
            warm.session_full_hits,
            warm.solve_cache_hits,
            warm.component_cache_hits
        );
        assert_eq!(cold.session_full_hits, 0);
        assert_eq!(cold.solve_cache_hits, 0);
    }

    #[test]
    fn same_tick_join_vs_deploy_order_is_pinned_and_both_replay() {
        use crate::cluster::{identical_nodes, Priority};
        use crate::workload::churn::ChurnTrace;
        use crate::workload::GenParams;

        // Node 0 is too small for the pod; a Join at the very same tick
        // provides the only feasible node. Autoscaler-injected joins
        // made this race observable — the contract is: same-tick events
        // apply in insertion order (pinned log), and the scheduling
        // round runs after the whole tick is batched, so the pod binds
        // under either insertion order.
        let base = GenParams {
            nodes: 1,
            pods_per_node: 1,
            priority_tiers: 1,
            usage: 1.0,
        };
        let params = ChurnParams {
            horizon_ms: 1_000,
            ..ChurnParams::for_cluster(base)
        };
        let nodes = identical_nodes(1, Resources::new(100, 100));
        let rs = ReplicaSet::new(0, "rs-000", 1, Resources::new(500, 500), Priority(0));
        let mk = |join_first: bool| {
            let deploy = (
                0u64,
                TraceOp::Deploy {
                    rs: rs.clone(),
                    lifetimes_ms: vec![5_000],
                },
            );
            let join = (
                0u64,
                TraceOp::Join {
                    capacity: Resources::new(1000, 1000),
                    pool: None,
                },
            );
            let ops = if join_first {
                vec![join, deploy]
            } else {
                vec![deploy, join]
            };
            ChurnTrace {
                params,
                seed: 0,
                nodes: nodes.clone(),
                reference_capacity: Resources::new(100, 100),
                p_max: 0,
                ops,
            }
        };
        for join_first in [true, false] {
            let trace = mk(join_first);
            let a = run_churn(&trace, &ChurnConfig::for_policy(Policy::DefaultOnly));
            let b = run_churn(&trace, &ChurnConfig::for_policy(Policy::DefaultOnly));
            assert_eq!(a.log.digest(), b.log.digest(), "replay determinism");
            assert_eq!(a.final_placed, vec![1], "join_first={join_first}");
            assert_eq!(a.final_pending, 0, "join_first={join_first}");
        }
        // The two insertion orders are *different but pinned* logs.
        let ja = run_churn(&mk(true), &ChurnConfig::for_policy(Policy::DefaultOnly));
        let db = run_churn(&mk(false), &ChurnConfig::for_policy(Policy::DefaultOnly));
        assert_ne!(ja.log.digest(), db.log.digest());
        assert!(ja.log.lines()[0].contains("join"), "{}", ja.log.lines()[0]);
        assert!(db.log.lines()[0].contains("deploy"), "{}", db.log.lines()[0]);
    }

    #[test]
    fn autoscale_off_is_the_default_and_records_no_activity() {
        let trace = tiny_trace(21);
        let base = ChurnConfig::for_policy(Policy::FallbackSweep);
        assert!(base.autoscale.is_none(), "autoscaling is strictly opt-in");
        let explicit = ChurnConfig {
            autoscale: None,
            ..base.clone()
        };
        let a = run_churn(&trace, &base);
        let b = run_churn(&trace, &explicit);
        assert_eq!(a.log.digest(), b.log.digest());
        assert_eq!(a.autoscale, crate::autoscaler::AutoscaleStats::default());
        assert_eq!(b.autoscale, crate::autoscaler::AutoscaleStats::default());
    }

    #[test]
    fn compare_policies_runs_all_three_on_the_same_trace() {
        let trace = tiny_trace(11);
        let results = compare_policies(&trace, &ChurnConfig::for_policy(Policy::FallbackSweep));
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].policy, Policy::DefaultOnly);
        assert_eq!(results[2].policy, Policy::FallbackSweep);
        // identical trace: identical arrival accounting across policies
        assert_eq!(
            results[0].arrivals_per_priority,
            results[2].arrivals_per_priority
        );
    }
}
