//! Virtual time for the discrete-event loop.
//!
//! The simulator never sleeps: time jumps from event to event. The clock
//! only enforces monotonicity — an event timeline that tried to move time
//! backwards would silently corrupt every derived time series.

/// Monotonic virtual clock, in milliseconds since simulation start.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimClock {
    now_ms: u64,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock::default()
    }

    pub fn now_ms(&self) -> u64 {
        self.now_ms
    }

    /// Jump to `at_ms`. Panics if time would move backwards (equal is
    /// fine: several events may share a tick).
    pub fn advance_to(&mut self, at_ms: u64) {
        assert!(
            at_ms >= self.now_ms,
            "clock moved backwards: {} -> {}",
            self.now_ms,
            at_ms
        );
        self.now_ms = at_ms;
    }
}

/// Fixed-width render used by the deterministic event log.
pub fn fmt_ms(ms: u64) -> String {
    format!("{ms:>8}ms")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_and_allows_equal() {
        let mut c = SimClock::new();
        assert_eq!(c.now_ms(), 0);
        c.advance_to(10);
        c.advance_to(10);
        c.advance_to(25);
        assert_eq!(c.now_ms(), 25);
    }

    #[test]
    #[should_panic(expected = "backwards")]
    fn rejects_time_travel() {
        let mut c = SimClock::new();
        c.advance_to(10);
        c.advance_to(9);
    }

    #[test]
    fn fixed_width_format() {
        assert_eq!(fmt_ms(0), "       0ms");
        assert_eq!(fmt_ms(12_345), "   12345ms");
    }
}
