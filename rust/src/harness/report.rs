//! Report rendering: ASCII stacked bars, markdown tables, JSON dumps.

use std::fmt::Write as _;

use crate::metrics::categories::Outcome;
use crate::util::json::Json;

use super::grid::CellResult;

/// Legend glyph per category (stacked-bar fill characters).
pub fn glyph(o: Outcome) -> char {
    match o {
        Outcome::BetterOptimal => '#', // paper: green
        Outcome::Better => '+',        // orange
        Outcome::KwokOptimal => '=',   // blue
        Outcome::NoCalls => '.',       // yellow
        Outcome::Failure => 'x',       // grey
    }
}

/// Render one stacked bar of `width` chars from category percentages.
pub fn stacked_bar(cell: &CellResult, width: usize) -> String {
    let mut bar = String::with_capacity(width);
    let mut acc = 0.0;
    let mut drawn = 0usize;
    for &o in &Outcome::ALL {
        acc += cell.pct(o);
        let upto = ((acc / 100.0) * width as f64).round() as usize;
        for _ in drawn..upto.min(width) {
            bar.push(glyph(o));
        }
        drawn = drawn.max(upto.min(width));
    }
    while bar.len() < width {
        bar.push(' ');
    }
    bar
}

/// Legend line for figures.
pub fn legend() -> String {
    Outcome::ALL
        .iter()
        .map(|&o| format!("{}={}", glyph(o), o.label()))
        .collect::<Vec<_>>()
        .join("  ")
}

/// Markdown header + separator for an N-column table.
pub fn md_header(cols: &[&str]) -> String {
    format!(
        "| {} |\n|{}|",
        cols.join(" | "),
        cols.iter().map(|_| "---").collect::<Vec<_>>().join("|")
    )
}

/// One markdown row.
pub fn md_row(cells: &[String]) -> String {
    format!("| {} |", cells.join(" | "))
}

/// Width-aligned markdown table: every column is padded to its widest
/// cell (header included), so the pipes line up however many digits the
/// counters grow — [`md_header`]/[`md_row`] drift apart as soon as one
/// row's cell outgrows its header. Rows shorter than the header are
/// padded with empty cells; longer rows are truncated.
pub fn md_table(cols: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = cols.iter().map(|c| c.chars().count()).collect();
    for row in rows {
        for (i, cell) in row.iter().take(cols.len()).enumerate() {
            widths[i] = widths[i].max(cell.chars().count());
        }
    }
    let render = |cells: &mut dyn Iterator<Item = &str>| -> String {
        let padded: Vec<String> = widths
            .iter()
            .map(|&w| {
                let c = cells.next().unwrap_or("");
                let pad = w.saturating_sub(c.chars().count());
                format!("{c}{}", " ".repeat(pad))
            })
            .collect();
        format!("| {} |", padded.join(" | "))
    };
    let mut out = render(&mut cols.iter().copied());
    out.push('\n');
    out.push_str(&format!(
        "|{}|",
        widths
            .iter()
            .map(|&w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("|")
    ));
    for row in rows {
        out.push('\n');
        out.push_str(&render(&mut row.iter().map(|s| s.as_str())));
    }
    out
}

/// Serialize a cell to JSON (for machine-readable results files).
pub fn cell_to_json(cell: &CellResult) -> Json {
    let mut j = Json::obj();
    j.set("nodes", cell.key.params.nodes)
        .set("pods_per_node", cell.key.params.pods_per_node)
        .set("priority_tiers", cell.key.params.priority_tiers)
        .set("usage", cell.key.params.usage)
        .set("timeout_s", cell.key.timeout_s)
        .set("instances", cell.instances);
    let mut counts = Json::obj();
    for &o in &Outcome::ALL {
        let idx = Outcome::ALL.iter().position(|&x| x == o).unwrap();
        counts.set(o.label(), cell.counts[idx]);
    }
    j.set("counts", counts);
    j.set(
        "mean_solver_duration_s",
        crate::util::stats::mean(&cell.solver_durations),
    );
    j.set("mean_delta_cpu_pp", crate::util::stats::mean(&cell.delta_cpu));
    j.set("mean_delta_mem_pp", crate::util::stats::mean(&cell.delta_mem));
    j
}

/// Dump a result set to a JSON file.
pub fn save_cells(cells: &[CellResult], path: &str) -> anyhow::Result<()> {
    let arr = Json::Arr(cells.iter().map(cell_to_json).collect());
    std::fs::create_dir_all(
        std::path::Path::new(path)
            .parent()
            .unwrap_or(std::path::Path::new(".")),
    )?;
    std::fs::write(path, arr.to_string_pretty())?;
    Ok(())
}

/// Percentage with one decimal, right-aligned to 6 chars.
pub fn fmt_pct(p: f64) -> String {
    format!("{p:5.1}%")
}

/// Human duration (seconds with sub-second precision).
pub fn fmt_secs(s: f64) -> String {
    if s < 0.001 {
        format!("{:.1}ms", s * 1000.0)
    } else if s < 1.0 {
        format!("{:.0}ms", s * 1000.0)
    } else {
        format!("{s:.1}s")
    }
}

/// A titled section box for terminal reports.
pub fn section(title: &str) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "\n{}", "=".repeat(title.len().max(60)));
    let _ = writeln!(s, "{title}");
    let _ = writeln!(s, "{}", "=".repeat(title.len().max(60)));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::grid::CellKey;
    use crate::workload::GenParams;

    fn cell_with(counts: [usize; 5]) -> CellResult {
        let mut c = CellResult {
            key: CellKey {
                params: GenParams {
                    nodes: 4,
                    pods_per_node: 4,
                    priority_tiers: 1,
                    usage: 1.0,
                },
                timeout_s: 1.0,
            },
            counts,
            solver_durations: vec![],
            delta_cpu: vec![],
            delta_mem: vec![],
            disruptions: vec![],
            instances: counts.iter().sum(),
        };
        c.solver_durations.push(0.5);
        c
    }

    #[test]
    fn bar_width_and_composition() {
        let c = cell_with([5, 3, 2, 0, 0]);
        let bar = stacked_bar(&c, 20);
        assert_eq!(bar.len(), 20);
        assert_eq!(bar.chars().filter(|&ch| ch == '#').count(), 10); // 50%
        assert_eq!(bar.chars().filter(|&ch| ch == '+').count(), 6); // 30%
        assert_eq!(bar.chars().filter(|&ch| ch == '=').count(), 4); // 20%
    }

    #[test]
    fn bar_handles_empty_cell() {
        let c = cell_with([0, 0, 0, 0, 0]);
        let bar = stacked_bar(&c, 10);
        assert_eq!(bar, "          ");
    }

    #[test]
    fn markdown_helpers() {
        let h = md_header(&["a", "b"]);
        assert!(h.contains("| a | b |"));
        assert!(h.contains("|---|---|"));
        assert_eq!(md_row(&["1".into(), "2".into()]), "| 1 | 2 |");
    }

    #[test]
    fn md_table_aligns_pipes_across_rows() {
        let rows = vec![
            vec!["x".to_string(), "12345".to_string()],
            vec!["longer".to_string(), "7".to_string()],
        ];
        let t = md_table(&["policy", "n"], &rows);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        let pipes = |s: &str| -> Vec<usize> {
            s.char_indices().filter(|(_, c)| *c == '|').map(|(i, _)| i).collect()
        };
        let expect = pipes(lines[0]);
        for line in &lines[1..] {
            assert_eq!(pipes(line), expect, "misaligned: {line}");
        }
        // cells padded, not truncated
        assert!(lines[2].contains("| x      | 12345 |"));
        assert!(lines[3].contains("| longer | 7     |"));
    }

    #[test]
    fn md_table_pads_short_rows() {
        let t = md_table(&["a", "b", "c"], &[vec!["1".to_string()]]);
        let last = t.lines().last().unwrap();
        assert_eq!(last, "| 1 |   |   |");
    }

    #[test]
    fn json_cell_counts() {
        let c = cell_with([1, 2, 3, 4, 0]);
        let j = cell_to_json(&c);
        assert_eq!(
            j.get("counts").unwrap().get("Better").unwrap().as_i64(),
            Some(2)
        );
        assert_eq!(j.get("instances").unwrap().as_i64(), Some(10));
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_secs(0.0004), "0.4ms");
        assert_eq!(fmt_secs(0.25), "250ms");
        assert_eq!(fmt_secs(2.5), "2.5s");
    }
}
