//! The churn experiment report: default-only vs fallback vs
//! fallback+sweep over one shared trace.

use std::fmt::Write as _;

use crate::lifecycle::{ChurnResult, Policy};
use crate::workload::churn::ChurnTrace;

use super::report::{md_table, section};

fn vec_cell(v: &[usize]) -> String {
    format!(
        "[{}]",
        v.iter()
            .map(|x| x.to_string())
            .collect::<Vec<_>>()
            .join(", ")
    )
}

/// Whether `a` serves at least as many pods as `b` in every tier.
pub fn dominates_per_tier(a: &[usize], b: &[usize]) -> bool {
    a.iter().zip(b).all(|(x, y)| x >= y)
}

/// Render the policy comparison as a markdown report.
pub fn churn_report(trace: &ChurnTrace, results: &[ChurnResult]) -> String {
    let mut out = String::new();
    let (deploys, scales, drains, joins) = trace.op_counts();
    out.push_str(&section(&format!(
        "Churn — {} · horizon {}ms · seed {}",
        trace.params.base.label(),
        trace.params.horizon_ms,
        trace.seed
    )));
    let _ = writeln!(
        out,
        "trace: {} ops (deploy {deploys}, scale {scales}, drain {drains}, join {joins}), up to {} pods, {} tiers\n",
        trace.ops.len(),
        trace.max_pods(),
        trace.p_max + 1
    );

    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            // incremental-session reuse: full-state / per-solve /
            // per-component replays + warm-start floors seeded ("-" when
            // sessions are off or idle)
            let hits = r.session_full_hits + r.solve_cache_hits + r.component_cache_hits;
            let cache_cell = if hits + r.warm_starts == 0 {
                "-".to_string()
            } else {
                format!(
                    "{}/{}/{}+{}w",
                    r.session_full_hits, r.solve_cache_hits, r.component_cache_hits, r.warm_starts
                )
            };
            vec![
                r.policy.label().to_string(),
                vec_cell(&r.served_per_priority),
                vec_cell(&r.final_placed),
                r.final_pending.to_string(),
                r.completions.to_string(),
                // attribution split: elective sweep moves are a different
                // operational cost than forced pre-emptions or drains
                format!(
                    "{} ({}+{}+{})",
                    r.evictions, r.evictions_preemption, r.evictions_sweep, r.evictions_drain
                ),
                r.solver_invocations.to_string(),
                format!("{}/{}", r.sweeps_applied, r.sweeps_run),
                cache_cell,
                // nodes joined / removed by the CP autoscaler and the cost
                // of the provisioned fleet ("-" when autoscaling is off)
                r.autoscale.cell(),
                format!("{:.1}%", r.series.mean_cpu() * 100.0),
                format!("{:016x}", r.log.digest()),
            ]
        })
        .collect();
    out.push_str(&md_table(
        &[
            "policy",
            "served/tier",
            "final placed",
            "pending",
            "completions",
            "evictions (pre+swp+drn)",
            "solver calls",
            "sweeps",
            "cache hits",
            "autoscale",
            "mean cpu",
            "log digest",
        ],
        &rows,
    ));
    out.push('\n');

    // The headline claim: the optimised policies serve at least as many
    // pods per priority tier as the baseline on the identical trace.
    let baseline = results.iter().find(|r| r.policy == Policy::DefaultOnly);
    let sweep = results.iter().find(|r| r.policy == Policy::FallbackSweep);
    if let (Some(base), Some(sweep)) = (baseline, sweep) {
        let ok = dominates_per_tier(&sweep.served_per_priority, &base.served_per_priority);
        let _ = writeln!(
            out,
            "\nfallback+sweep serves >= default-only in every priority tier: {}",
            if ok { "yes" } else { "NO (regression!)" }
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lifecycle::{compare_policies, ChurnConfig, Policy};
    use crate::workload::churn::{ChurnParams, ChurnTraceGenerator};
    use crate::workload::GenParams;

    #[test]
    fn report_renders_all_policies() {
        let trace = ChurnTraceGenerator::new(
            ChurnParams {
                horizon_ms: 3_000,
                mean_arrival_ms: 500,
                mean_lifetime_ms: 1_200,
                ..ChurnParams::for_cluster(GenParams {
                    nodes: 2,
                    pods_per_node: 3,
                    priority_tiers: 1,
                    usage: 0.9,
                })
            },
            3,
        )
        .generate();
        let results = compare_policies(&trace, &ChurnConfig::for_policy(Policy::FallbackSweep));
        let report = churn_report(&trace, &results);
        assert!(report.contains("default-only"));
        assert!(report.contains("fallback+sweep"));
        assert!(report.contains("log digest"));
        assert!(report.contains("serves >= default-only"));
        // the eviction column carries the per-driver attribution split
        assert!(report.contains("evictions (pre+swp+drn)"));
        assert!(report.contains("cache hits"));
        // the autoscale column renders "-" while autoscaling is off
        assert!(report.contains("autoscale"));
    }

    #[test]
    fn report_columns_stay_aligned_with_large_counters() {
        // Regression: the fixed-width header/row pair drifted apart as
        // soon as an eviction or solver-call cell outgrew its header
        // (5+ digit counters on long traces). md_table sizes columns
        // from the widest cell, so every pipe lands on one column.
        let trace = ChurnTraceGenerator::new(
            ChurnParams {
                horizon_ms: 1_000,
                ..ChurnParams::for_cluster(GenParams {
                    nodes: 2,
                    pods_per_node: 2,
                    priority_tiers: 2,
                    usage: 0.5,
                })
            },
            1,
        )
        .generate();
        let mk = |policy: Policy, k: usize| crate::lifecycle::ChurnResult {
            policy,
            served_per_priority: vec![k, 2],
            final_placed: vec![k, 1],
            final_pending: 0,
            final_ready_nodes: 3,
            arrivals_per_priority: vec![k, 2],
            completions: k,
            evictions: 3 * k,
            evictions_preemption: k,
            evictions_sweep: k,
            evictions_drain: k,
            solver_invocations: k,
            sweeps_run: k,
            sweeps_applied: 1,
            events_processed: k,
            session_full_hits: 0,
            solve_cache_hits: 0,
            component_cache_hits: 0,
            warm_starts: 0,
            autoscale: crate::autoscaler::AutoscaleStats::default(),
            series: crate::metrics::TimeSeries::new(),
            log: crate::lifecycle::ChurnLog::new(),
        };
        let results = vec![mk(Policy::DefaultOnly, 7), mk(Policy::FallbackSweep, 123_456)];
        let report = churn_report(&trace, &results);
        let table: Vec<&str> = report
            .lines()
            .filter(|l| l.starts_with('|'))
            .collect();
        assert_eq!(table.len(), 4, "header + separator + two rows");
        let pipes = |s: &str| -> Vec<usize> {
            s.char_indices().filter(|(_, c)| *c == '|').map(|(i, _)| i).collect()
        };
        let expect = pipes(table[0]);
        for line in &table[1..] {
            assert_eq!(pipes(line), expect, "misaligned row: {line}");
        }
        assert!(report.contains("370368 (123456+123456+123456)"));
    }

    #[test]
    fn dominance_check_is_elementwise() {
        assert!(dominates_per_tier(&[3, 2], &[3, 2]));
        assert!(dominates_per_tier(&[4, 2], &[3, 2]));
        assert!(!dominates_per_tier(&[4, 1], &[3, 2]));
    }
}
