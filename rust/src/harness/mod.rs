//! Benchmark harness: regenerate every table and figure of the paper.
//!
//! * [`experiment`] — run one instance end-to-end (KWOK baseline →
//!   optimiser at a timeout) and classify the outcome.
//! * [`grid`]       — sweep parameter grids, with per-cell tallies.
//! * [`figures`]    — the drivers: Figure 3 (outcome distribution by
//!   cluster size × timeout, collated by priority × pods-per-node),
//!   Figure 4 (by usage level), Table 1 (solver duration and
//!   Δcpu/Δmem utilisation).
//! * [`report`]     — ASCII stacked bars, markdown tables, JSON dumps.
//! * [`churn`]      — lifecycle policy comparison (default-only vs
//!   fallback vs fallback+sweep) over one shared churn trace.

pub mod churn;
pub mod experiment;
pub mod figures;
pub mod grid;
pub mod report;

pub use churn::churn_report;
pub use experiment::{
    run_instance, run_instance_probed, run_instance_session, run_instance_traced,
    run_instance_with, InstanceRun,
};
pub use grid::{CellKey, CellResult, GridConfig};
