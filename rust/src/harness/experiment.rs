//! Single-instance experiment: the paper's measurement protocol.
//!
//! "We evaluate our scheduling approach by running the default scheduler
//! (as-is) in KWOK and then our optimisation algorithm, if the default
//! scheduler failed to place all pods. We record the placements of pods
//! and whether the optimiser found an optimal solution or achieved a
//! better allocation than the KWOK baseline (i.e., higher number of
//! higher-priority pods)."

use crate::metrics::categories::{classify, Outcome};
use crate::metrics::utilization_delta;
use crate::optimizer::algorithm::{optimize, OptimizerConfig};
use crate::optimizer::plan::MovePlan;
use crate::simulator::KwokSimulator;
use crate::solver::SolverConfig;
use crate::util::timer::Stopwatch;
use crate::workload::Instance;

/// Everything recorded about one (instance, timeout) run.
#[derive(Clone, Debug)]
pub struct InstanceRun {
    pub outcome: Outcome,
    /// Wall-clock of the whole optimisation incl. model building and
    /// solution extraction — the paper's "solver duration" ("the time
    /// here is the total duration including extraction of the solution
    /// and I/O, which may slightly be above the solver timeout").
    pub solver_duration_s: f64,
    /// Utilisation improvement over the KWOK baseline, in percentage
    /// points (0 when the plan was not applied).
    pub delta_cpu: f64,
    pub delta_mem: f64,
    /// Pods placed per priority: baseline vs optimised.
    pub kwok_placed: Vec<usize>,
    pub opt_placed: Vec<usize>,
    /// Pods whose node changed to realise the improvement.
    pub disruptions: usize,
}

/// Run one instance at one timeout.
pub fn run_instance(inst: &Instance, timeout_s: f64, solver: &SolverConfig) -> InstanceRun {
    let p_max = inst.params.p_max();

    // 1. KWOK baseline (deterministic profile).
    let mut sim = KwokSimulator::new(p_max);
    let (state, base) = sim.run(inst.nodes.clone(), inst.pods.clone());
    let base_util = state.utilization();

    if base.all_placed {
        // Deterministic generation makes this unreachable for challenging
        // datasets, but the paper's yellow category exists because *its*
        // evaluation re-runs a nondeterministic scheduler; keep the path.
        return InstanceRun {
            outcome: Outcome::NoCalls,
            solver_duration_s: 0.0,
            delta_cpu: 0.0,
            delta_mem: 0.0,
            kwok_placed: base.placed_per_priority.clone(),
            opt_placed: base.placed_per_priority,
            disruptions: 0,
        };
    }

    // 2. Optimiser fallback.
    let cfg = OptimizerConfig {
        total_timeout: std::time::Duration::from_secs_f64(timeout_s),
        alpha: 0.8,
        solver: solver.clone(),
        ..Default::default()
    };
    let sw = Stopwatch::start();
    let result = optimize(&state, p_max, &cfg);
    let solver_duration_s = sw.elapsed_secs();

    let (outcome, opt_placed, delta, disruptions) = match &result {
        None => (
            Outcome::Failure,
            base.placed_per_priority.clone(),
            (0.0, 0.0),
            0,
        ),
        Some(res) => {
            let outcome = classify(
                true,
                Some((&res.placed_per_priority, res.proved_optimal)),
                &base.placed_per_priority,
            );
            match outcome {
                Outcome::Better | Outcome::BetterOptimal => {
                    let plan = MovePlan::build(&state, &res.target);
                    let after_util = plan
                        .validate(&state)
                        .expect("solver target must be executable");
                    (
                        outcome,
                        res.placed_per_priority.clone(),
                        utilization_delta(base_util, after_util),
                        plan.disruptions(),
                    )
                }
                _ => (outcome, base.placed_per_priority.clone(), (0.0, 0.0), 0),
            }
        }
    };

    InstanceRun {
        outcome,
        solver_duration_s,
        delta_cpu: delta.0,
        delta_mem: delta.1,
        kwok_placed: base.placed_per_priority,
        opt_placed,
        disruptions,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::GenParams;

    #[test]
    fn challenging_instance_classified() {
        let params = GenParams {
            nodes: 4,
            pods_per_node: 4,
            priority_tiers: 2,
            usage: 1.0,
        };
        let insts = Instance::generate_challenging(params, 3, 11, 300);
        assert!(!insts.is_empty());
        for inst in &insts {
            let run = run_instance(inst, 2.0, &SolverConfig::default());
            // challenging → solver invoked → never NoCalls
            assert_ne!(run.outcome, Outcome::NoCalls);
            if matches!(run.outcome, Outcome::Better | Outcome::BetterOptimal) {
                // improvement must be real: lexicographically more pods
                assert!(crate::metrics::lex_better(&run.opt_placed, &run.kwok_placed));
                // deltas are usually positive but may dip negative when a
                // higher-priority (smaller) pod displaces a bigger one
                assert!(run.delta_cpu.is_finite() && run.delta_mem.is_finite());
                assert!(run.delta_cpu.abs() <= 100.0 && run.delta_mem.abs() <= 100.0);
                assert!(run.disruptions > 0 || run.kwok_placed.iter().sum::<usize>() == 0 ||
                        run.opt_placed.iter().sum::<usize>() > run.kwok_placed.iter().sum::<usize>());
            }
        }
    }

    #[test]
    fn solver_duration_bounded_by_timeout_plus_overhead() {
        let params = GenParams {
            nodes: 8,
            pods_per_node: 8,
            priority_tiers: 4,
            usage: 1.05,
        };
        let insts = Instance::generate_challenging(params, 1, 21, 200);
        if let Some(inst) = insts.first() {
            let run = run_instance(inst, 0.3, &SolverConfig::default());
            // paper: duration may slightly exceed the timeout (extraction,
            // model building) but must stay in the same ballpark.
            assert!(
                run.solver_duration_s < 0.3 * 3.0 + 0.5,
                "duration {} way past timeout",
                run.solver_duration_s
            );
        }
    }
}
