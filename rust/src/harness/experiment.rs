//! Single-instance experiment: the paper's measurement protocol.
//!
//! "We evaluate our scheduling approach by running the default scheduler
//! (as-is) in KWOK and then our optimisation algorithm, if the default
//! scheduler failed to place all pods. We record the placements of pods
//! and whether the optimiser found an optimal solution or achieved a
//! better allocation than the KWOK baseline (i.e., higher number of
//! higher-priority pods)."

use crate::metrics::categories::{classify, Outcome};
use crate::metrics::utilization_delta;
use crate::optimizer::algorithm::{optimize_probed, OptimizerConfig};
use crate::optimizer::plan::MovePlan;
use crate::optimizer::session::SolveSession;
use crate::optimizer::TierReport;
use crate::portfolio::{PortfolioConfig, PortfolioStats};
use crate::simulator::KwokSimulator;
use crate::solver::{Probe, SolverConfig};
use crate::telemetry::{Stopwatch, Telemetry};
use crate::workload::Instance;

/// Everything recorded about one (instance, timeout) run.
#[derive(Clone, Debug)]
pub struct InstanceRun {
    pub outcome: Outcome,
    /// Wall-clock of the whole optimisation incl. model building and
    /// solution extraction — the paper's "solver duration" ("the time
    /// here is the total duration including extraction of the solution
    /// and I/O, which may slightly be above the solver timeout").
    pub solver_duration_s: f64,
    /// Utilisation improvement over the KWOK baseline, in percentage
    /// points (0 when the plan was not applied).
    pub delta_cpu: f64,
    pub delta_mem: f64,
    /// Pods placed per priority: baseline vs optimised.
    pub kwok_placed: Vec<usize>,
    pub opt_placed: Vec<usize>,
    /// Pods whose node changed to realise the improvement.
    pub disruptions: usize,
    /// Per-tier solve reports — carry the per-tier optimality
    /// certificate (status + final bound). Empty when the solver was not
    /// invoked or failed outright.
    pub tiers: Vec<TierReport>,
    /// Portfolio-layer counters of the run.
    pub portfolio: PortfolioStats,
    /// Cluster state after the run: the optimiser's plan applied when it
    /// improved on the baseline, the KWOK baseline otherwise. Feeds the
    /// `solve --explain` rejection census for still-pending pods.
    pub final_state: crate::cluster::ClusterState,
}

/// Run one instance at one timeout with the single-threaded solver
/// (unless `KUBE_PACKD_THREADS` raises the portfolio default).
pub fn run_instance(inst: &Instance, timeout_s: f64, solver: &SolverConfig) -> InstanceRun {
    run_instance_with(inst, timeout_s, solver, &PortfolioConfig::default())
}

/// Run one instance at one timeout with explicit portfolio knobs.
pub fn run_instance_with(
    inst: &Instance,
    timeout_s: f64,
    solver: &SolverConfig,
    portfolio: &PortfolioConfig,
) -> InstanceRun {
    run_instance_session(inst, timeout_s, solver, portfolio, None)
}

/// [`run_instance_with`] plus an optional incremental [`SolveSession`]
/// shared across calls: datasets of near-identical instances (and the
/// re-solves inside one) reuse proven certificates and warm starts —
/// the `solve --incremental` path. `None` solves cold.
pub fn run_instance_session(
    inst: &Instance,
    timeout_s: f64,
    solver: &SolverConfig,
    portfolio: &PortfolioConfig,
    session: Option<&mut SolveSession>,
) -> InstanceRun {
    run_instance_traced(inst, timeout_s, solver, portfolio, session, &Telemetry::off())
}

/// [`run_instance_session`] recording onto a caller-owned [`Telemetry`]
/// handle: the measurement becomes an `instance` span wrapping the KWOK
/// baseline and the optimiser's own span tree (the `solve --trace`
/// path). Telemetry never feeds back into the measurement.
pub fn run_instance_traced(
    inst: &Instance,
    timeout_s: f64,
    solver: &SolverConfig,
    portfolio: &PortfolioConfig,
    session: Option<&mut SolveSession>,
    tel: &Telemetry,
) -> InstanceRun {
    run_instance_probed(
        inst,
        timeout_s,
        solver,
        portfolio,
        session,
        tel,
        &Probe::off(),
    )
}

/// [`run_instance_traced`] with a solve-forensics [`Probe`] (the
/// `solve --profile` path): the optimiser records per-constraint search
/// effort and gap timelines onto it. Like telemetry, the probe observes
/// only — the measurement is byte-identical armed or off.
pub fn run_instance_probed(
    inst: &Instance,
    timeout_s: f64,
    solver: &SolverConfig,
    portfolio: &PortfolioConfig,
    session: Option<&mut SolveSession>,
    tel: &Telemetry,
    prof: &Probe,
) -> InstanceRun {
    let sp = tel.span("instance");
    sp.arg("pods", inst.pods.len());
    sp.arg("nodes", inst.nodes.len());
    let p_max = inst.params.p_max();

    // 1. KWOK baseline (deterministic profile).
    let mut sim = KwokSimulator::new(p_max);
    let (state, base) = sim.run(inst.nodes.clone(), inst.pods.clone());
    let base_util = state.utilization();

    if base.all_placed {
        // Deterministic generation makes this unreachable for challenging
        // datasets, but the paper's yellow category exists because *its*
        // evaluation re-runs a nondeterministic scheduler; keep the path.
        return InstanceRun {
            outcome: Outcome::NoCalls,
            solver_duration_s: 0.0,
            delta_cpu: 0.0,
            delta_mem: 0.0,
            kwok_placed: base.placed_per_priority.clone(),
            opt_placed: base.placed_per_priority,
            disruptions: 0,
            tiers: Vec::new(),
            portfolio: PortfolioStats::default(),
            final_state: state,
        };
    }

    // 2. Optimiser fallback.
    let cfg = OptimizerConfig {
        total_timeout: std::time::Duration::from_secs_f64(timeout_s),
        alpha: 0.8,
        solver: solver.clone(),
        portfolio: portfolio.clone(),
        ..Default::default()
    };
    let sw = Stopwatch::start();
    let result = match session {
        Some(sess) => sess.solve_probed(&state, p_max, &cfg, tel, prof),
        None => optimize_probed(&state, p_max, &cfg, None, tel, prof),
    };
    let solver_duration_s = sw.elapsed_secs();

    let (outcome, opt_placed, delta, disruptions, applied) = match &result {
        None => (
            Outcome::Failure,
            base.placed_per_priority.clone(),
            (0.0, 0.0),
            0,
            None,
        ),
        Some(res) => {
            let outcome = classify(
                true,
                Some((&res.placed_per_priority, res.proved_optimal)),
                &base.placed_per_priority,
            );
            match outcome {
                Outcome::Better | Outcome::BetterOptimal => {
                    let plan = MovePlan::build(&state, &res.target);
                    let mut after = state.clone();
                    plan.execute(&mut after)
                        .expect("solver target must be executable");
                    let after_util = after.utilization();
                    (
                        outcome,
                        res.placed_per_priority.clone(),
                        utilization_delta(base_util, after_util),
                        plan.disruptions(),
                        Some(after),
                    )
                }
                _ => (
                    outcome,
                    base.placed_per_priority.clone(),
                    (0.0, 0.0),
                    0,
                    None,
                ),
            }
        }
    };

    let (tiers, pstats) = match &result {
        Some(res) => (res.tiers.clone(), res.portfolio.clone()),
        None => (Vec::new(), PortfolioStats::default()),
    };

    InstanceRun {
        outcome,
        solver_duration_s,
        delta_cpu: delta.0,
        delta_mem: delta.1,
        kwok_placed: base.placed_per_priority,
        opt_placed,
        disruptions,
        tiers,
        portfolio: pstats,
        final_state: applied.unwrap_or(state),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::GenParams;

    #[test]
    fn challenging_instance_classified() {
        let params = GenParams {
            nodes: 4,
            pods_per_node: 4,
            priority_tiers: 2,
            usage: 1.0,
        };
        let insts = Instance::generate_challenging(params, 3, 11, 300);
        assert!(!insts.is_empty());
        for inst in &insts {
            let run = run_instance(inst, 2.0, &SolverConfig::default());
            // challenging → solver invoked → never NoCalls
            assert_ne!(run.outcome, Outcome::NoCalls);
            if matches!(run.outcome, Outcome::Better | Outcome::BetterOptimal) {
                // improvement must be real: lexicographically more pods
                assert!(crate::metrics::lex_better(&run.opt_placed, &run.kwok_placed));
                // deltas are usually positive but may dip negative when a
                // higher-priority (smaller) pod displaces a bigger one
                assert!(run.delta_cpu.is_finite() && run.delta_mem.is_finite());
                assert!(run.delta_cpu.abs() <= 100.0 && run.delta_mem.abs() <= 100.0);
                assert!(run.disruptions > 0 || run.kwok_placed.iter().sum::<usize>() == 0 ||
                        run.opt_placed.iter().sum::<usize>() > run.kwok_placed.iter().sum::<usize>());
            }
        }
    }

    #[test]
    fn tiers_surface_certificates_through_the_harness() {
        let params = GenParams {
            nodes: 4,
            pods_per_node: 4,
            priority_tiers: 2,
            usage: 1.0,
        };
        let insts = Instance::generate_challenging(params, 1, 99, 300);
        if let Some(inst) = insts.first() {
            let run = run_instance_with(
                inst,
                2.0,
                &SolverConfig::default(),
                &PortfolioConfig::with_threads(2),
            );
            if run.outcome != Outcome::Failure {
                assert_eq!(run.tiers.len(), 2, "one report per priority tier");
                for t in &run.tiers {
                    assert!(
                        t.phase1_bound >= t.phase1_placed,
                        "certificate bound must be admissible"
                    );
                }
                assert!(run.portfolio.solves > 0, "threads=2 must use the portfolio");
            }
        }
    }

    #[test]
    fn solver_duration_bounded_by_timeout_plus_overhead() {
        let params = GenParams {
            nodes: 8,
            pods_per_node: 8,
            priority_tiers: 4,
            usage: 1.05,
        };
        let insts = Instance::generate_challenging(params, 1, 21, 200);
        if let Some(inst) = insts.first() {
            let run = run_instance(inst, 0.3, &SolverConfig::default());
            // paper: duration may slightly exceed the timeout (extraction,
            // model building) but must stay in the same ballpark.
            assert!(
                run.solver_duration_s < 0.3 * 3.0 + 0.5,
                "duration {} way past timeout",
                run.solver_duration_s
            );
        }
    }
}
