//! Parameter-grid sweeps with per-cell tallies.

use crate::metrics::categories::Outcome;
use crate::portfolio::PortfolioConfig;
use crate::solver::SolverConfig;
use crate::util::rng::Rng;
use crate::workload::{GenParams, Instance};

use super::experiment::{run_instance_with, InstanceRun};

/// Sweep configuration. Defaults mirror the paper's grid; the driver
/// binaries scale `instances` and `timeouts` to this testbed (see
/// EXPERIMENTS.md "Scaling").
#[derive(Clone, Debug)]
pub struct GridConfig {
    pub nodes: Vec<usize>,
    pub pods_per_node: Vec<usize>,
    pub priority_tiers: Vec<u32>,
    pub usage: Vec<f64>,
    /// `T_total` values, seconds, per instance.
    pub timeouts: Vec<f64>,
    /// Challenging instances per parameter combination.
    pub instances: usize,
    pub seed: u64,
    pub solver: SolverConfig,
    /// Portfolio knobs for every solve of the sweep (`--threads` on the
    /// figure CLIs).
    pub portfolio: PortfolioConfig,
    /// Cap on generation attempts per cell (low-usage cells may not
    /// yield `instances` failures).
    pub max_gen_attempts: usize,
    /// Print per-cell progress to stderr.
    pub verbose: bool,
}

impl Default for GridConfig {
    fn default() -> Self {
        GridConfig {
            nodes: vec![4, 8, 16, 32],
            pods_per_node: vec![4, 8],
            priority_tiers: vec![1, 2, 4],
            usage: vec![0.90, 0.95, 1.00, 1.05],
            timeouts: vec![0.1, 0.5, 1.0],
            instances: 12,
            seed: 0xC0FFEE,
            solver: SolverConfig::default(),
            portfolio: PortfolioConfig::default(),
            max_gen_attempts: 400,
            verbose: true,
        }
    }
}

/// Identifies one (params, timeout) cell.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CellKey {
    pub params: GenParams,
    pub timeout_s: f64,
}

/// Aggregated results for one cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub key: CellKey,
    /// Outcome counts indexed as `Outcome::ALL`.
    pub counts: [usize; 5],
    pub solver_durations: Vec<f64>,
    pub delta_cpu: Vec<f64>,
    pub delta_mem: Vec<f64>,
    pub disruptions: Vec<usize>,
    pub instances: usize,
}

impl CellResult {
    fn new(key: CellKey) -> Self {
        CellResult {
            key,
            counts: [0; 5],
            solver_durations: Vec::new(),
            delta_cpu: Vec::new(),
            delta_mem: Vec::new(),
            disruptions: Vec::new(),
            instances: 0,
        }
    }

    pub fn record(&mut self, run: &InstanceRun) {
        let idx = Outcome::ALL.iter().position(|&o| o == run.outcome).unwrap();
        self.counts[idx] += 1;
        self.instances += 1;
        self.solver_durations.push(run.solver_duration_s);
        self.delta_cpu.push(run.delta_cpu);
        self.delta_mem.push(run.delta_mem);
        self.disruptions.push(run.disruptions);
    }

    pub fn pct(&self, o: Outcome) -> f64 {
        if self.instances == 0 {
            return 0.0;
        }
        let idx = Outcome::ALL.iter().position(|&x| x == o).unwrap();
        self.counts[idx] as f64 * 100.0 / self.instances as f64
    }

    /// Merge another cell (used to aggregate usage levels in Figure 3).
    pub fn merge(&mut self, other: &CellResult) {
        for i in 0..5 {
            self.counts[i] += other.counts[i];
        }
        self.instances += other.instances;
        self.solver_durations.extend(&other.solver_durations);
        self.delta_cpu.extend(&other.delta_cpu);
        self.delta_mem.extend(&other.delta_mem);
        self.disruptions.extend(&other.disruptions);
    }
}

/// Run the full grid: per parameter combination, generate the
/// challenging dataset once, then evaluate it at every timeout.
pub fn run_grid(cfg: &GridConfig) -> Vec<CellResult> {
    let mut out = Vec::new();
    let mut seed_stream = Rng::new(cfg.seed);
    let total_cells =
        cfg.nodes.len() * cfg.pods_per_node.len() * cfg.priority_tiers.len() * cfg.usage.len();
    let mut done = 0usize;

    for &nodes in &cfg.nodes {
        for &ppn in &cfg.pods_per_node {
            for &tiers in &cfg.priority_tiers {
                for &usage in &cfg.usage {
                    let params = GenParams {
                        nodes,
                        pods_per_node: ppn,
                        priority_tiers: tiers,
                        usage,
                    };
                    let ds_seed = seed_stream.next_u64();
                    let insts = Instance::generate_challenging(
                        params,
                        cfg.instances,
                        ds_seed,
                        cfg.max_gen_attempts,
                    );
                    done += 1;
                    if cfg.verbose {
                        eprintln!(
                            "[grid {done}/{total_cells}] {} — {} challenging instances",
                            params.label(),
                            insts.len()
                        );
                    }
                    for &timeout_s in &cfg.timeouts {
                        let key = CellKey { params, timeout_s };
                        let mut cell = CellResult::new(key);
                        for inst in &insts {
                            let run =
                                run_instance_with(inst, timeout_s, &cfg.solver, &cfg.portfolio);
                            cell.record(&run);
                        }
                        out.push(cell);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_grid_runs() {
        let cfg = GridConfig {
            nodes: vec![4],
            pods_per_node: vec![4],
            priority_tiers: vec![1],
            usage: vec![1.05],
            timeouts: vec![0.2],
            instances: 2,
            max_gen_attempts: 120,
            verbose: false,
            ..Default::default()
        };
        let cells = run_grid(&cfg);
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert!(c.instances >= 1);
        assert_eq!(c.counts.iter().sum::<usize>(), c.instances);
        // percentages sum to 100
        let total: f64 = Outcome::ALL.iter().map(|&o| c.pct(o)).sum();
        assert!((total - 100.0).abs() < 1e-9);
    }

    #[test]
    fn merge_accumulates() {
        let key = CellKey {
            params: GenParams {
                nodes: 4,
                pods_per_node: 4,
                priority_tiers: 1,
                usage: 1.0,
            },
            timeout_s: 1.0,
        };
        let mut a = CellResult::new(key);
        let mut b = CellResult::new(key);
        a.counts[0] = 3;
        a.instances = 3;
        b.counts[2] = 2;
        b.instances = 2;
        a.merge(&b);
        assert_eq!(a.instances, 5);
        assert_eq!(a.counts[0], 3);
        assert_eq!(a.counts[2], 2);
    }
}
