//! Figure/table drivers: each regenerates one artefact of the paper's
//! evaluation section and returns a rendered report (also written to
//! `results/` by the CLI).

use std::fmt::Write as _;

use anyhow::Result;

use crate::metrics::categories::Outcome;
use crate::util::stats;
use crate::workload::GenParams;

use super::grid::{run_grid, CellResult, GridConfig};
use super::report::{fmt_pct, fmt_secs, legend, md_header, md_row, save_cells, section, stacked_bar};

/// Aggregate cells over usage levels, keyed by (nodes, ppn, tiers, timeout)
/// — Figure 3 "aggregating across target usage levels".
fn aggregate_over_usage(cells: &[CellResult]) -> Vec<CellResult> {
    let mut out: Vec<CellResult> = Vec::new();
    for c in cells {
        let k = c.key;
        match out.iter_mut().find(|o| {
            o.key.params.nodes == k.params.nodes
                && o.key.params.pods_per_node == k.params.pods_per_node
                && o.key.params.priority_tiers == k.params.priority_tiers
                && o.key.timeout_s == k.timeout_s
        }) {
            Some(existing) => existing.merge(c),
            None => {
                let mut fresh = c.clone();
                fresh.key.params.usage = 0.0; // aggregated marker
                out.push(fresh);
            }
        }
    }
    out
}

/// **Figure 3**: distribution of solved instances by cluster size, three
/// grouped bars per size (one per timeout), collated by priority tiers
/// (columns) and pods-per-node (rows), aggregated across usage levels.
pub fn fig3(cfg: &GridConfig, out_dir: &str) -> Result<String> {
    let cells = run_grid(cfg);
    save_cells(&cells, &format!("{out_dir}/fig3_cells.json"))?;
    let agg = aggregate_over_usage(&cells);

    let mut s = String::new();
    let _ = write!(s, "{}", section("Figure 3 — outcome distribution by cluster size × solver timeout"));
    let _ = writeln!(s, "{}\n", legend());

    for &ppn in &cfg.pods_per_node {
        for &tiers in &cfg.priority_tiers {
            let _ = writeln!(s, "--- priorities={tiers}  pods-per-node={ppn} ---");
            let _ = writeln!(
                s,
                "{:>6} {:>7}  {:<44} {:>7} {:>7} {:>7} {:>7} {:>7}",
                "nodes", "T_total", "distribution", "Bet&Opt", "Better", "KwokOpt", "NoCalls", "Fail"
            );
            for &nodes in &cfg.nodes {
                for &t in &cfg.timeouts {
                    let Some(cell) = agg.iter().find(|c| {
                        c.key.params.nodes == nodes
                            && c.key.params.pods_per_node == ppn
                            && c.key.params.priority_tiers == tiers
                            && c.key.timeout_s == t
                    }) else {
                        continue;
                    };
                    let _ = writeln!(
                        s,
                        "{:>6} {:>7} [{}] {:>7} {:>7} {:>7} {:>7} {:>7}",
                        nodes,
                        fmt_secs(t),
                        stacked_bar(cell, 44),
                        fmt_pct(cell.pct(Outcome::BetterOptimal)),
                        fmt_pct(cell.pct(Outcome::Better)),
                        fmt_pct(cell.pct(Outcome::KwokOptimal)),
                        fmt_pct(cell.pct(Outcome::NoCalls)),
                        fmt_pct(cell.pct(Outcome::Failure)),
                    );
                }
                let _ = writeln!(s);
            }
        }
    }
    Ok(s)
}

/// **Figure 4**: distribution by target usage level (fixed ppn=4,
/// 4 priorities, one timeout).
pub fn fig4(cfg: &GridConfig, out_dir: &str) -> Result<String> {
    let mut sub = cfg.clone();
    sub.pods_per_node = vec![4];
    sub.priority_tiers = vec![4];
    sub.timeouts = vec![cfg
        .timeouts
        .get(cfg.timeouts.len() / 2)
        .copied()
        .unwrap_or(1.0)];
    let cells = run_grid(&sub);
    save_cells(&cells, &format!("{out_dir}/fig4_cells.json"))?;

    let mut s = String::new();
    let _ = write!(
        s,
        "{}",
        section(&format!(
            "Figure 4 — outcome distribution by target usage (ppn=4, 4 priorities, T={})",
            fmt_secs(sub.timeouts[0])
        ))
    );
    let _ = writeln!(s, "{}\n", legend());
    let _ = writeln!(
        s,
        "{:>6} {:>6}  {:<44} {:>7} {:>7} {:>7} {:>7} {:>7}",
        "usage", "nodes", "distribution", "Bet&Opt", "Better", "KwokOpt", "NoCalls", "Fail"
    );
    for &usage in &sub.usage {
        for &nodes in &sub.nodes {
            let Some(cell) = cells.iter().find(|c| {
                c.key.params.usage == usage && c.key.params.nodes == nodes
            }) else {
                continue;
            };
            let _ = writeln!(
                s,
                "{:>5.0}% {:>6} [{}] {:>7} {:>7} {:>7} {:>7} {:>7}",
                usage * 100.0,
                nodes,
                stacked_bar(cell, 44),
                fmt_pct(cell.pct(Outcome::BetterOptimal)),
                fmt_pct(cell.pct(Outcome::Better)),
                fmt_pct(cell.pct(Outcome::KwokOptimal)),
                fmt_pct(cell.pct(Outcome::NoCalls)),
                fmt_pct(cell.pct(Outcome::Failure)),
            );
        }
        let _ = writeln!(s);
    }
    Ok(s)
}

/// **Table 1**: solver duration and Δcpu/Δmem utilisation vs the default
/// scheduler (4 priorities, one timeout, ppn ∈ {4, 8}).
pub fn table1(cfg: &GridConfig, out_dir: &str) -> Result<String> {
    let mut sub = cfg.clone();
    sub.priority_tiers = vec![4];
    sub.timeouts = vec![cfg
        .timeouts
        .get(cfg.timeouts.len() / 2)
        .copied()
        .unwrap_or(1.0)];
    let cells = run_grid(&sub);
    save_cells(&cells, &format!("{out_dir}/table1_cells.json"))?;

    let find = |usage: f64, ppn: usize, nodes: usize| -> Option<&CellResult> {
        cells.iter().find(|c| {
            c.key.params.usage == usage
                && c.key.params.pods_per_node == ppn
                && c.key.params.nodes == nodes
        })
    };

    let mut s = String::new();
    let _ = write!(
        s,
        "{}",
        section(&format!(
            "Table 1 — solver performance (4 priorities, T={})",
            fmt_secs(sub.timeouts[0])
        ))
    );
    let mut cols: Vec<String> = vec!["util".into(), "metric".into()];
    for &ppn in &sub.pods_per_node {
        for &n in &sub.nodes {
            cols.push(format!("ppn{ppn}/n{n}"));
        }
    }
    let colrefs: Vec<&str> = cols.iter().map(String::as_str).collect();
    let _ = writeln!(s, "{}", md_header(&colrefs));

    for &usage in &sub.usage {
        for (metric, f) in [
            (
                "solver duration (s)",
                Box::new(|c: &CellResult| format!("{:.2}", stats::mean(&c.solver_durations)))
                    as Box<dyn Fn(&CellResult) -> String>,
            ),
            (
                "Δ cpu util (pp)",
                Box::new(|c: &CellResult| format!("{:.1}", stats::mean(&c.delta_cpu))),
            ),
            (
                "Δ mem util (pp)",
                Box::new(|c: &CellResult| format!("{:.1}", stats::mean(&c.delta_mem))),
            ),
        ] {
            let mut row: Vec<String> = vec![format!("{:.0}%", usage * 100.0), metric.to_string()];
            for &ppn in &sub.pods_per_node {
                for &n in &sub.nodes {
                    row.push(match find(usage, ppn, n) {
                        Some(c) if c.instances > 0 => f(c),
                        _ => "—".into(),
                    });
                }
            }
            let _ = writeln!(s, "{}", md_row(&row));
        }
    }
    Ok(s)
}

/// Quick driver used by unit/integration tests: a minimal grid that
/// exercises all three figure paths in seconds.
pub fn tiny_grid() -> GridConfig {
    GridConfig {
        nodes: vec![4],
        pods_per_node: vec![4],
        priority_tiers: vec![1, 4],
        usage: vec![1.0, 1.05],
        timeouts: vec![0.15],
        instances: 2,
        max_gen_attempts: 120,
        verbose: false,
        ..Default::default()
    }
}

/// Default per-cell parameters for one usage-aggregated Figure-3 slot,
/// exposed for the examples.
pub fn default_params() -> GenParams {
    GenParams {
        nodes: 4,
        pods_per_node: 4,
        priority_tiers: 2,
        usage: 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_three_figures_render() {
        let dir = std::env::temp_dir().join("kube-packd-figs");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.to_str().unwrap();
        let cfg = tiny_grid();
        let f3 = fig3(&cfg, out).unwrap();
        assert!(f3.contains("Figure 3"));
        assert!(f3.contains("priorities=1"));
        let f4 = fig4(&cfg, out).unwrap();
        assert!(f4.contains("Figure 4"));
        let t1 = table1(&cfg, out).unwrap();
        assert!(t1.contains("Table 1"));
        assert!(t1.contains("solver duration"));
        // machine-readable dumps exist
        assert!(dir.join("fig3_cells.json").is_file());
        assert!(dir.join("fig4_cells.json").is_file());
        assert!(dir.join("table1_cells.json").is_file());
    }

    #[test]
    fn aggregation_merges_usage_levels() {
        let cfg = tiny_grid();
        let cells = run_grid(&cfg);
        let agg = aggregate_over_usage(&cells);
        // 1 node x 1 ppn x 2 tiers x 1 timeout = 2 aggregated rows
        assert_eq!(agg.len(), 2);
        let total_before: usize = cells.iter().map(|c| c.instances).sum();
        let total_after: usize = agg.iter().map(|c| c.instances).sum();
        assert_eq!(total_before, total_after);
    }
}
