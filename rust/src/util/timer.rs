//! Deprecated location: the monotonic clock moved to
//! [`crate::telemetry::clock`] so spans, deadlines, and budgets share a
//! single time source. This shim re-exports the old names for external
//! callers; new code should import from `telemetry::clock` (or
//! `telemetry`) directly.

pub use crate::telemetry::clock::{Deadline, Stopwatch, TimeBudget};
