//! Deterministic PRNG: xoshiro256++ seeded via splitmix64.
//!
//! All randomness in the project flows through [`Rng`] so that every
//! workload instance, property test, and LNS run is reproducible from a
//! single `u64` seed (the paper forces determinism on KWOK for the same
//! reason).

/// splitmix64 step — used for seeding and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG (Blackman & Vigna). Not cryptographic; fast, with
/// 256-bit state and excellent statistical quality for simulation use.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a single u64 (via splitmix64, per the
    /// xoshiro authors' recommendation).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` using Lemire's nearly-divisionless method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "range_i64({lo}, {hi})");
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Uniform `usize` in `[lo, hi]`.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_i64(lo as i64, hi as i64) as usize
    }

    /// Uniform `f64` in `[0, 1)` (53-bit mantissa).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Uniformly chosen element (panics on empty slice).
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fork an independent stream (for per-instance seeding).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive_endpoints_reachable() {
        let mut r = Rng::new(3);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            match r.range_i64(100, 103) {
                100 => lo_seen = true,
                103 => hi_seen = true,
                101 | 102 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn mean_of_f64_near_half() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let sum: f64 = (0..n).map(|_| r.f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}
