//! Criterion stand-in used by `benches/*.rs` (`harness = false`).
//!
//! Provides warmup + timed iterations with mean/median/p95 reporting and a
//! `black_box` to defeat constant folding. Statistics are intentionally
//! simple (the project's benches measure milliseconds-to-seconds scale
//! end-to-end runs, not nanosecond kernels).

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use super::stats;

pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u32,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Measurement {
    pub fn report(&self) {
        println!(
            "bench {:<44} iters={:<3} mean={:>10} median={:>10} p95={:>10} min={:>10} max={:>10}",
            self.name,
            self.iters,
            fmt_s(self.mean_s),
            fmt_s(self.median_s),
            fmt_s(self.p95_s),
            fmt_s(self.min_s),
            fmt_s(self.max_s),
        );
    }
}

fn fmt_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Bench runner: `warmup` unmeasured runs then `iters` timed runs.
pub struct Bencher {
    warmup: u32,
    iters: u32,
    /// Overall per-benchmark wall-clock cap; iterations stop early once hit
    /// (but at least one timed iteration always runs).
    cap: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: 1,
            iters: 10,
            cap: Duration::from_secs(60),
        }
    }
}

impl Bencher {
    pub fn new(warmup: u32, iters: u32, cap: Duration) -> Self {
        Bencher { warmup, iters, cap }
    }

    /// Quick profile for heavy end-to-end benches.
    pub fn heavy() -> Self {
        Bencher {
            warmup: 0,
            iters: 3,
            cap: Duration::from_secs(120),
        }
    }

    pub fn run<R>(&self, name: &str, mut f: impl FnMut() -> R) -> Measurement {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let started = Instant::now();
        let mut samples = Vec::with_capacity(self.iters as usize);
        for done in 0..self.iters {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed().as_secs_f64());
            if started.elapsed() > self.cap && done >= 1 {
                break;
            }
        }
        let m = Measurement {
            name: name.to_string(),
            iters: samples.len() as u32,
            mean_s: stats::mean(&samples),
            median_s: stats::median(&samples),
            p95_s: stats::percentile(&samples, 95.0),
            min_s: stats::min(&samples),
            max_s: stats::max(&samples),
        };
        m.report();
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher::new(1, 5, Duration::from_secs(5));
        let m = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        });
        assert_eq!(m.iters, 5);
        assert!(m.mean_s > 0.0);
        assert!(m.min_s <= m.median_s && m.median_s <= m.max_s);
    }

    #[test]
    fn cap_stops_early() {
        let b = Bencher::new(0, 1000, Duration::from_millis(20));
        let m = b.run("sleepy", || std::thread::sleep(Duration::from_millis(10)));
        assert!(m.iters < 1000);
        assert!(m.iters >= 1);
    }

    #[test]
    fn format_scales() {
        assert!(fmt_s(2e-9).ends_with("ns"));
        assert!(fmt_s(2e-6).ends_with("µs"));
        assert!(fmt_s(2e-3).ends_with("ms"));
        assert!(fmt_s(2.0).ends_with('s'));
    }
}
