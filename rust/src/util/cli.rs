//! Tiny CLI parser (clap stand-in): one subcommand + `--key value` /
//! `--flag` options. Unknown flags are collected so the caller can reject
//! them with a helpful message.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, options, bare flags, positionals.
#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} wants a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Comma-separated usize list, e.g. `--nodes 4,8,16`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: bad entry {s:?}"))
                })
                .collect(),
        }
    }

    /// Comma-separated f64 list, e.g. `--timeouts 0.1,1,2`.
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: bad entry {s:?}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = args("fig3 --nodes 4,8 --instances 40 --verbose");
        assert_eq!(a.command.as_deref(), Some("fig3"));
        assert_eq!(a.get_usize_list("nodes", &[]), vec![4, 8]);
        assert_eq!(a.get_usize("instances", 100), 40);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = args("run --seed=42 --alpha=0.8");
        assert_eq!(a.get_u64("seed", 0), 42);
        assert_eq!(a.get_f64("alpha", 0.0), 0.8);
    }

    #[test]
    fn defaults() {
        let a = args("table1");
        assert_eq!(a.get_usize("instances", 100), 100);
        assert_eq!(a.get_str("out", "results"), "results");
        assert_eq!(a.get_f64_list("timeouts", &[1.0]), vec![1.0]);
    }

    #[test]
    fn positionals() {
        let a = args("generate out.json extra");
        assert_eq!(a.command.as_deref(), Some("generate"));
        assert_eq!(a.positional, vec!["out.json", "extra"]);
    }

    #[test]
    fn trailing_flag() {
        let a = args("demo --fast");
        assert!(a.flag("fast"));
    }
}
