//! Streaming FNV-1a fingerprints for structured data.
//!
//! The incremental solve-session subsystem (`optimizer::session`,
//! `portfolio::cache`) keys caches on 64-bit content fingerprints of
//! cluster states and solver models. FNV-1a is the same primitive the
//! churn replay digests use (`lifecycle::trace::fnv1a64`); this variant
//! streams typed fields instead of one rendered byte buffer, with a
//! length/tag discipline so distinct field sequences cannot collide by
//! concatenation (e.g. `"ab" + "c"` vs `"a" + "bc"`).
//!
//! A fingerprint is an identity *heuristic*: equal inputs always produce
//! equal fingerprints (that is what cache correctness rests on — a miss
//! is never wrong, merely slow), while a 64-bit collision between
//! *different* inputs is possible in principle. The session layer only
//! ever caches **proven** results and replays them for states whose
//! entire solve-relevant content was hashed, which bounds the blast
//! radius of a collision to the same 2^-64-per-pair odds the replay
//! digests already accept.

/// Streaming 64-bit FNV-1a hasher over typed fields.
#[derive(Clone, Debug)]
pub struct Fnv64 {
    state: u64,
}

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64::new()
    }
}

impl Fnv64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    pub fn new() -> Self {
        Fnv64 {
            state: Self::OFFSET,
        }
    }

    #[inline]
    pub fn write_bytes(&mut self, bytes: &[u8]) -> &mut Self {
        // Length prefix keeps adjacent variable-length fields unambiguous.
        self.mix_raw(&(bytes.len() as u64).to_le_bytes());
        self.mix_raw(bytes)
    }

    #[inline]
    pub fn write_str(&mut self, s: &str) -> &mut Self {
        self.write_bytes(s.as_bytes())
    }

    #[inline]
    pub fn write_u64(&mut self, v: u64) -> &mut Self {
        self.mix_raw(&v.to_le_bytes())
    }

    #[inline]
    pub fn write_i64(&mut self, v: i64) -> &mut Self {
        self.mix_raw(&v.to_le_bytes())
    }

    #[inline]
    pub fn write_u32(&mut self, v: u32) -> &mut Self {
        self.mix_raw(&v.to_le_bytes())
    }

    #[inline]
    pub fn write_usize(&mut self, v: usize) -> &mut Self {
        self.write_u64(v as u64)
    }

    #[inline]
    pub fn write_bool(&mut self, v: bool) -> &mut Self {
        self.mix_raw(&[v as u8])
    }

    /// Hash an `f64` by bit pattern (exact, NaN-stable).
    #[inline]
    pub fn write_f64(&mut self, v: f64) -> &mut Self {
        self.write_u64(v.to_bits())
    }

    /// Small discriminant tag separating heterogeneous field groups.
    #[inline]
    pub fn tag(&mut self, t: u8) -> &mut Self {
        self.mix_raw(&[t])
    }

    #[inline]
    fn mix_raw(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(Self::PRIME);
        }
        self
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_inputs_equal_fingerprints() {
        let mut a = Fnv64::new();
        a.write_str("pod-1").write_i64(2048).write_bool(true);
        let mut b = Fnv64::new();
        b.write_str("pod-1").write_i64(2048).write_bool(true);
        assert_eq!(a.finish(), b.finish());
    }

    #[test]
    fn field_order_matters() {
        let mut a = Fnv64::new();
        a.write_i64(1).write_i64(2);
        let mut b = Fnv64::new();
        b.write_i64(2).write_i64(1);
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn string_boundaries_are_unambiguous() {
        let mut a = Fnv64::new();
        a.write_str("ab").write_str("c");
        let mut b = Fnv64::new();
        b.write_str("a").write_str("bc");
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn tags_separate_field_groups() {
        let mut a = Fnv64::new();
        a.tag(1).write_u64(7);
        let mut b = Fnv64::new();
        b.tag(2).write_u64(7);
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn f64_hashes_by_bit_pattern() {
        let mut a = Fnv64::new();
        a.write_f64(0.1 + 0.2);
        let mut b = Fnv64::new();
        b.write_f64(0.3);
        // 0.1 + 0.2 != 0.3 in binary64: distinct bits, distinct hashes.
        assert_ne!(a.finish(), b.finish());
    }
}
