//! Summary statistics for benches and experiment reports.

/// Mean of a sample (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation over the sorted *finite* samples,
/// `q` in [0,100]. Non-finite cells (a failed timing measurement) are
/// dropped instead of panicking the sort or bleeding NaN into high
/// percentiles, so one bad cell cannot poison a whole report; with no
/// finite sample at all the result clamps to 0.0 (matching `mean`).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Minimum *finite* sample. Empty (or all-non-finite) input clamps to
/// 0.0 — matching `mean` / `percentile` — instead of leaking
/// `±INFINITY` into emitted `BENCH_*.json` files, whose schema admits
/// finite numbers only.
pub fn min(xs: &[f64]) -> f64 {
    let m = xs
        .iter()
        .copied()
        .filter(|x| x.is_finite())
        .fold(f64::INFINITY, f64::min);
    if m.is_finite() {
        m
    } else {
        0.0
    }
}

/// Maximum *finite* sample (0.0 when none — see [`min`]).
pub fn max(xs: &[f64]) -> f64 {
    let m = xs
        .iter()
        .copied()
        .filter(|x| x.is_finite())
        .fold(f64::NEG_INFINITY, f64::max);
    if m.is_finite() {
        m
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[1.0, 2.0, 100.0]), 2.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 95.0), 95.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        // min/max clamp to 0.0 instead of leaking ±INFINITY into reports
        assert_eq!(min(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // A failed timing cell (NaN/inf) must neither panic the sort nor
        // bleed into any percentile: the summary covers the finite cells.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert_eq!(percentile(&xs, 100.0), 3.0, "high percentiles stay finite");
        assert_eq!(median(&[f64::NAN, 5.0, 1.0]), 3.0);
        // degenerate all-bad samples clamp like empty input
        assert_eq!(percentile(&[f64::NAN], 50.0), 0.0);
    }

    #[test]
    fn min_max_ignore_non_finite_samples() {
        assert_eq!(min(&[f64::NAN, 2.0, 5.0]), 2.0);
        assert_eq!(max(&[f64::NEG_INFINITY, 2.0, 5.0]), 5.0);
        assert_eq!(min(&[f64::INFINITY, 4.0]), 4.0);
        // all-non-finite behaves like empty: clamp to 0.0, never ±inf
        assert_eq!(min(&[f64::NAN]), 0.0);
        assert_eq!(max(&[f64::NAN, f64::INFINITY]), 0.0);
    }

    #[test]
    fn stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }
}
