//! Summary statistics for benches and experiment reports.

/// Mean of a sample (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on the sorted sample, `q` in [0,100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Min/max helpers tolerant of NaN-free inputs.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[1.0, 2.0, 100.0]), 2.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 95.0), 95.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(stddev(&[]), 0.0);
    }

    #[test]
    fn stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }
}
