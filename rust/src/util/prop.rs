//! Seeded property-testing mini-framework (proptest stand-in; see
//! DESIGN.md "Substitutions").
//!
//! A property is checked against `iters` cases generated from a
//! deterministic per-case RNG. On failure, the harness retries the case
//! with progressively "smaller" seeds derived from simple shrink
//! heuristics is *not* attempted (shrinking arbitrary generators without
//! integrated shrinking is unsound); instead the failing *seed* and case
//! `Debug` dump are reported, which reproduces the case exactly:
//!
//! ```text
//! property 'solver_respects_capacity' failed at iter 17 (seed 0xDEADBEEF):
//!   case: Instance { .. }
//!   error: node 3 over capacity
//! ```

use super::rng::Rng;

/// Check `property` on `iters` generated cases. Panics on first failure
/// with the reproducing seed.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    base_seed: u64,
    iters: u32,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut property: impl FnMut(&T) -> Result<(), String>,
) {
    for i in 0..iters {
        // Per-case seed: independent of iteration order, reproducible alone.
        let case_seed = base_seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        let case = generate(&mut rng);
        if let Err(msg) = property(&case) {
            panic!(
                "property '{name}' failed at iter {i} (seed {case_seed:#x}):\n  case: {case:?}\n  error: {msg}"
            );
        }
    }
}

/// Re-run a single case by seed (for debugging a reported failure).
pub fn replay<T: std::fmt::Debug>(
    seed: u64,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut property: impl FnMut(&T) -> Result<(), String>,
) -> Result<(), String> {
    let mut rng = Rng::new(seed);
    let case = generate(&mut rng);
    property(&case)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "sum_commutes",
            1,
            64,
            |r| (r.range_i64(-100, 100), r.range_i64(-100, 100)),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always_fails'")]
    fn failing_property_reports_seed() {
        check("always_fails", 2, 8, |r| r.next_u64(), |_| Err("nope".into()));
    }

    #[test]
    fn replay_reproduces() {
        let mut failures = Vec::new();
        for i in 0..32u64 {
            let seed = 99 ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let r = replay(seed, |r| r.below(10), |&v| if v < 5 { Ok(()) } else { Err(format!("{v}")) });
            if let Err(e) = r {
                failures.push((seed, e));
            }
        }
        // Replaying the same seed yields the same verdict.
        for (seed, e) in &failures {
            let again = replay(*seed, |r| r.below(10), |&v| if v < 5 { Ok(()) } else { Err(format!("{v}")) });
            assert_eq!(again.unwrap_err(), *e);
        }
    }
}
