//! Minimal JSON: value tree, writer (compact + pretty), and a
//! recursive-descent parser. serde_json stand-in for dataset and result
//! serialization (see DESIGN.md "Substitutions").
//!
//! Numbers are stored as `f64`; integers round-trip exactly up to 2^53,
//! far beyond anything this project serializes (milli-CPU counts, MiB).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if self is not an object).
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * level {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for ParseError {}

/// Parse a JSON document (trailing whitespace allowed, nothing else).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let mut j = Json::obj();
        j.set("name", "node-01")
            .set("cpu", 4000i64)
            .set("ok", true)
            .set("list", vec![1i64, 2, 3]);
        let s = j.to_string_compact();
        assert_eq!(parse(&s).unwrap(), j);
    }

    #[test]
    fn roundtrip_pretty() {
        let mut j = Json::obj();
        j.set("a", Json::Arr(vec![Json::Null, Json::Bool(false)]));
        let s = j.to_string_pretty();
        assert_eq!(parse(&s).unwrap(), j);
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let s = j.to_string_compact();
        assert_eq!(parse(&s).unwrap(), j);
    }

    #[test]
    fn numbers() {
        for v in ["0", "-1", "3.5", "1e3", "-2.5E-2", "9007199254740992"] {
            let parsed = parse(v).unwrap();
            let f: f64 = v.parse().unwrap();
            assert_eq!(parsed, Json::Num(f), "{v}");
        }
    }

    #[test]
    fn integers_roundtrip_exactly() {
        let j = Json::from(123456789i64);
        assert_eq!(j.to_string_compact(), "123456789");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} x").is_err());
        assert!(parse("\"abc").is_err());
    }

    #[test]
    fn unicode_strings() {
        let j = Json::Str("π ≈ 3.14, 日本語".to_string());
        assert_eq!(parse(&j.to_string_compact()).unwrap(), j);
        assert_eq!(parse("\"\\u00e9\"").unwrap(), Json::Str("é".to_string()));
    }

    #[test]
    fn nested_structures() {
        let s = r#"{"a":{"b":[{"c":[1,2,{"d":null}]}]}}"#;
        let j = parse(s).unwrap();
        assert_eq!(parse(&j.to_string_compact()).unwrap(), j);
    }
}
