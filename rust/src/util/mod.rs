//! In-house substrates forced by the offline build environment.
//!
//! The baked cargo registry only carries `xla` and `anyhow`, so the usual
//! ecosystem crates (rand, serde_json, clap, criterion, proptest) are
//! re-implemented here at the scale this project needs. Each module is a
//! small, fully tested, dependency-free building block:
//!
//! * [`rng`]   — xoshiro256++ / splitmix64 deterministic PRNG (rand-like).
//! * [`fingerprint`] — streaming FNV-1a fingerprints for the solve caches.
//! * [`json`]  — JSON value tree, writer, and recursive-descent parser.
//! * [`cli`]   — flag/subcommand parser for the `kube-packd` binary.
//! * [`timer`] — deprecated shim re-exporting the clock that moved to
//!   [`crate::telemetry::clock`] (the crate's single monotonic source).
//! * [`stats`] — mean/median/percentile helpers for benches and reports.
//! * [`prop`]  — seeded property-testing mini-framework (proptest stand-in).
//! * [`bench`] — criterion stand-in used by `benches/*.rs` (harness=false).

pub mod bench;
pub mod cli;
pub mod fingerprint;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
