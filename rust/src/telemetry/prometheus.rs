//! Prometheus text-exposition exporter for telemetry counters and
//! latency histograms.
//!
//! Output follows the text format a `/metrics` endpoint would serve:
//! one `# TYPE` comment per metric followed by its sample lines, every
//! metric prefixed `kube_packd_`. Scalar families (counters/gauges)
//! render first, histogram families after — within each section,
//! iteration over the underlying `BTreeMap` makes the dump byte-stable
//! for a fixed run, the property the snapshot tests pin.
//!
//! Histograms render the standard triplet: cumulative
//! `<name>_bucket{le="..."}` series ending at `le="+Inf"`, then
//! `<name>_sum` and `<name>_count`. Bucket bounds come from the fixed
//! [`BUCKET_BOUNDS_US`] table (stored in microseconds, exposed in
//! seconds per Prometheus convention), so the bucket *structure* is
//! identical across runs even though observed wall-clock values vary.

use super::counters::{CounterSet, HistogramSet, BUCKET_BOUNDS_US};

/// Namespace prefix on every exported metric.
pub const PREFIX: &str = "kube_packd_";

/// Render a microsecond quantity in seconds, using Rust's shortest
/// round-trip float formatting (never scientific notation), e.g.
/// `1 → "0.000001"`, `16777216 → "16.777216"`.
fn secs(us: u64) -> String {
    (us as f64 / 1e6).to_string()
}

/// Render the counter set, then the histogram set, as Prometheus text
/// exposition.
pub fn render(counters: &CounterSet, histograms: &HistogramSet) -> String {
    let mut out = String::new();
    let mut last_metric: Option<String> = None;
    for (metric, labels, kind, value) in counters.iter() {
        if last_metric.as_deref() != Some(metric) {
            out.push_str("# TYPE ");
            out.push_str(PREFIX);
            out.push_str(metric);
            out.push(' ');
            out.push_str(kind.label());
            out.push('\n');
            last_metric = Some(metric.to_string());
        }
        out.push_str(PREFIX);
        out.push_str(metric);
        if !labels.is_empty() {
            out.push('{');
            out.push_str(labels);
            out.push('}');
        }
        out.push(' ');
        out.push_str(&value.to_string());
        out.push('\n');
    }
    last_metric = None;
    for (metric, labels, hist) in histograms.iter() {
        if last_metric.as_deref() != Some(metric) {
            out.push_str("# TYPE ");
            out.push_str(PREFIX);
            out.push_str(metric);
            out.push_str(" histogram\n");
            last_metric = Some(metric.to_string());
        }
        let cum = hist.cumulative();
        for (i, count) in cum.iter().enumerate() {
            let le = if i < BUCKET_BOUNDS_US.len() {
                secs(BUCKET_BOUNDS_US[i])
            } else {
                "+Inf".to_string()
            };
            out.push_str(PREFIX);
            out.push_str(metric);
            out.push_str("_bucket{");
            if !labels.is_empty() {
                out.push_str(labels);
                out.push(',');
            }
            out.push_str("le=\"");
            out.push_str(&le);
            out.push_str("\"} ");
            out.push_str(&count.to_string());
            out.push('\n');
        }
        for (suffix, value) in [
            ("_sum", secs(hist.sum_us())),
            ("_count", hist.count().to_string()),
        ] {
            out.push_str(PREFIX);
            out.push_str(metric);
            out.push_str(suffix);
            if !labels.is_empty() {
                out.push('{');
                out.push_str(labels);
                out.push('}');
            }
            out.push(' ');
            out.push_str(&value);
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_types_once_and_sorted() {
        let mut c = CounterSet::default();
        c.add("solver_decisions_total", "strategy=\"default\"", 10);
        c.add("solver_decisions_total", "strategy=\"easiest\"", 4);
        c.gauge_max("solver_max_depth", "", 6);
        let text = render(&c, &HistogramSet::default());
        let expected = "# TYPE kube_packd_solver_decisions_total counter\n\
                        kube_packd_solver_decisions_total{strategy=\"default\"} 10\n\
                        kube_packd_solver_decisions_total{strategy=\"easiest\"} 4\n\
                        # TYPE kube_packd_solver_max_depth gauge\n\
                        kube_packd_solver_max_depth 6\n";
        assert_eq!(text, expected);
    }

    #[test]
    fn empty_set_renders_empty() {
        assert_eq!(render(&CounterSet::default(), &HistogramSet::default()), "");
    }

    #[test]
    fn histograms_render_cumulative_buckets_sum_and_count() {
        let mut h = HistogramSet::default();
        h.observe("serve_window_solve_seconds", "", 2); // ≤ 4µs
        h.observe("serve_window_solve_seconds", "", 2_000_000); // ≤ 4.194304s
        let text = render(&CounterSet::default(), &h);
        assert!(text.starts_with("# TYPE kube_packd_serve_window_solve_seconds histogram\n"));
        assert!(text
            .contains("kube_packd_serve_window_solve_seconds_bucket{le=\"0.000004\"} 1\n"));
        assert!(text.contains("kube_packd_serve_window_solve_seconds_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("kube_packd_serve_window_solve_seconds_sum 2.000002\n"));
        assert!(text.ends_with("kube_packd_serve_window_solve_seconds_count 2\n"));
        // Cumulative monotonicity across the whole bucket series.
        let mut prev = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket{")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "buckets must be cumulative: {line}");
            prev = v;
        }
    }

    #[test]
    fn histogram_labels_compose_with_le() {
        let mut h = HistogramSet::default();
        h.observe("race_task_seconds", "strategy=\"default\"", 100);
        let text = render(&CounterSet::default(), &h);
        assert!(text
            .contains("kube_packd_race_task_seconds_bucket{strategy=\"default\",le=\"+Inf\"} 1\n"));
        assert!(text.contains("kube_packd_race_task_seconds_sum{strategy=\"default\"} 0.0001\n"));
        assert!(text.contains("kube_packd_race_task_seconds_count{strategy=\"default\"} 1\n"));
    }
}
