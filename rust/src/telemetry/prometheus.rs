//! Prometheus text-exposition exporter for telemetry counters.
//!
//! Output follows the text format a `/metrics` endpoint would serve:
//! one `# TYPE` comment per metric followed by its sample lines, every
//! metric prefixed `kube_packd_`. Iteration over the underlying
//! `BTreeMap` makes the dump byte-stable for a fixed run — the property
//! the snapshot tests pin.

use super::counters::CounterSet;

/// Namespace prefix on every exported metric.
pub const PREFIX: &str = "kube_packd_";

/// Render the counter set as Prometheus text exposition.
pub fn render(counters: &CounterSet) -> String {
    let mut out = String::new();
    let mut last_metric: Option<String> = None;
    for (metric, labels, kind, value) in counters.iter() {
        if last_metric.as_deref() != Some(metric) {
            out.push_str("# TYPE ");
            out.push_str(PREFIX);
            out.push_str(metric);
            out.push(' ');
            out.push_str(kind.label());
            out.push('\n');
            last_metric = Some(metric.to_string());
        }
        out.push_str(PREFIX);
        out.push_str(metric);
        if !labels.is_empty() {
            out.push('{');
            out.push_str(labels);
            out.push('}');
        }
        out.push(' ');
        out.push_str(&value.to_string());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_types_once_and_sorted() {
        let mut c = CounterSet::default();
        c.add("solver_decisions_total", "strategy=\"default\"", 10);
        c.add("solver_decisions_total", "strategy=\"easiest\"", 4);
        c.gauge_max("solver_max_depth", "", 6);
        let text = render(&c);
        let expected = "# TYPE kube_packd_solver_decisions_total counter\n\
                        kube_packd_solver_decisions_total{strategy=\"default\"} 10\n\
                        kube_packd_solver_decisions_total{strategy=\"easiest\"} 4\n\
                        # TYPE kube_packd_solver_max_depth gauge\n\
                        kube_packd_solver_max_depth 6\n";
        assert_eq!(text, expected);
    }

    #[test]
    fn empty_set_renders_empty() {
        assert_eq!(render(&CounterSet::default()), "");
    }
}
