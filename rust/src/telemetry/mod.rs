//! Structured telemetry: spans, counters, events, and exportable solve
//! traces — zero-overhead when off, determinism-preserving when on.
//!
//! The paper's headline claims are time-budget claims ("within a
//! 1-second scheduling window…"), so the pipeline must be able to say
//! *where* inside a solve window the time goes: search, propagation,
//! LNS, decomposition, warm-start projection, provisioning. A
//! [`Telemetry`] handle threads through solver → portfolio → session →
//! autoscaler → lifecycle and records three kinds of data:
//!
//! * **Spans** — RAII-guarded wall-clock intervals
//!   (`tel.span("phase1")`, or the [`span!`](crate::span) macro) kept as
//!   a per-handle stack, exported as a Chrome-trace timeline
//!   ([`chrome`], the `--trace FILE` CLI flag) that opens directly in
//!   Perfetto / `chrome://tracing`.
//! * **Counters** — deterministic solver/portfolio/session/autoscaler
//!   accounting ([`counters`]), exported in Prometheus text exposition
//!   ([`prometheus`], the `--metrics FILE` flag) — the dump a future
//!   serve daemon's `/metrics` endpoint mounts directly.
//! * **Events** — structured messages replacing the old
//!   `KUBE_PACKD_DEBUG` eprintlns; echoed to stderr at
//!   [`Verbosity::Debug`] and embedded in the trace as instant events.
//!
//! # Determinism contract
//!
//! Telemetry *observes* the pipeline; it never feeds back into it. Span
//! timestamps are wall-clock and live strictly outside the determinism
//! boundary: plans, objective vectors, and certificates are
//! byte-identical with telemetry on or off at any thread count (pinned
//! by the `telemetry` proptests). Counters recorded from completed
//! solves are themselves deterministic; only span/event *timestamps*
//! vary run to run. Exports are byte-stable given a fixed recorded run:
//! ordering derives from recording order, lane ids, and sorted maps —
//! never from timing races.
//!
//! # Concurrency model
//!
//! A handle is single-threaded by construction (`RefCell` inside). The
//! portfolio race gives each task a [`child`](Telemetry::child) handle
//! on its own timeline lane, created in deterministic task order before
//! the workers spawn, and [`absorb`](Telemetry::absorb)s them back in
//! task-index order after the race — so the merged record is a pure
//! function of the task list, not of thread scheduling.
//!
//! The clock ([`clock`]) is the crate's single monotonic-time source;
//! `Deadline`/`TimeBudget`/`Stopwatch` live here (re-exported through
//! the deprecated `util::timer` shim for older call sites).

pub mod chrome;
pub mod clock;
pub mod counters;
pub mod prometheus;

pub use clock::{Deadline, Stopwatch, TimeBudget};
pub use counters::{CounterKind, CounterSet, Histogram, HistogramSet, BUCKET_BOUNDS_US};

use std::cell::RefCell;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// How chatty the pipeline is. `Off` disables telemetry entirely;
/// `Info` records spans/counters/events; `Debug` and `Trace`
/// additionally echo events to stderr (the old `KUBE_PACKD_DEBUG=1`
/// behaviour, now a config knob: `OptimizerConfig.verbosity`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Verbosity {
    #[default]
    Off,
    Info,
    Debug,
    Trace,
}

impl Verbosity {
    /// Parse a CLI spelling; `None` on unknown input.
    pub fn parse(s: &str) -> Option<Verbosity> {
        match s {
            "off" => Some(Verbosity::Off),
            "info" => Some(Verbosity::Info),
            "debug" => Some(Verbosity::Debug),
            "trace" => Some(Verbosity::Trace),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Verbosity::Off => "off",
            Verbosity::Info => "info",
            Verbosity::Debug => "debug",
            Verbosity::Trace => "trace",
        }
    }
}

/// One recorded span: a named wall-clock interval on a timeline lane.
/// `parent` indexes into the owning handle's span vec (fixed up on
/// absorb), giving the exporter the nesting forest without re-deriving
/// it from timestamps.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub name: &'static str,
    pub lane: u32,
    pub parent: Option<usize>,
    pub start_us: u64,
    /// `u64::MAX` while the span is open.
    pub end_us: u64,
    pub args: Vec<(&'static str, String)>,
}

/// One structured event (the old debug eprintlns, kept as data).
#[derive(Clone, Debug)]
pub struct EventRecord {
    pub lane: u32,
    pub ts_us: u64,
    pub scope: &'static str,
    pub msg: String,
}

#[derive(Debug)]
struct Recorder {
    /// Echo events to stderr as they are recorded (Verbosity::Debug+).
    echo: bool,
    /// Shared time origin: all lanes timestamp against the root
    /// handle's creation instant, so a merged trace is coherent.
    origin: Instant,
    lane: u32,
    /// Root-shared lane allocator. Children are only ever created on
    /// the thread owning the parent handle, before workers spawn, so
    /// allocation order — hence lane numbering — is deterministic.
    lane_alloc: Arc<AtomicU32>,
    lane_names: Vec<(u32, String)>,
    spans: Vec<SpanRecord>,
    /// Indices of currently-open spans (stack discipline).
    stack: Vec<usize>,
    events: Vec<EventRecord>,
    counters: CounterSet,
    histograms: HistogramSet,
}

/// The telemetry handle. `Telemetry::off()` (or `default()`) is a
/// no-op shell: every method early-returns without reading the clock or
/// allocating, which is what "zero overhead when off" means here.
#[derive(Debug, Default)]
pub struct Telemetry {
    inner: Option<RefCell<Recorder>>,
}

impl Telemetry {
    /// Disabled handle — all operations are no-ops.
    pub fn off() -> Telemetry {
        Telemetry { inner: None }
    }

    /// Enabled handle that records silently (the `--trace`/`--metrics`
    /// CLI path).
    pub fn recording() -> Telemetry {
        Telemetry::with_echo(false)
    }

    /// Handle matching a configured verbosity: `Off` disables,
    /// `Info` records, `Debug`/`Trace` record *and* echo events to
    /// stderr (successor of the `KUBE_PACKD_DEBUG` env toggle).
    pub fn from_verbosity(v: Verbosity) -> Telemetry {
        match v {
            Verbosity::Off => Telemetry::off(),
            Verbosity::Info => Telemetry::with_echo(false),
            Verbosity::Debug | Verbosity::Trace => Telemetry::with_echo(true),
        }
    }

    fn with_echo(echo: bool) -> Telemetry {
        Telemetry {
            inner: Some(RefCell::new(Recorder {
                echo,
                origin: Instant::now(),
                lane: 0,
                lane_alloc: Arc::new(AtomicU32::new(0)),
                lane_names: vec![(0, "main".to_string())],
                spans: Vec::new(),
                stack: Vec::new(),
                events: Vec::new(),
                counters: CounterSet::default(),
                histograms: HistogramSet::default(),
            })),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Open a named span; the returned guard closes it on drop.
    pub fn span(&self, name: &'static str) -> Span<'_> {
        let idx = match &self.inner {
            None => usize::MAX,
            Some(cell) => {
                let mut r = cell.borrow_mut();
                let now = r.origin.elapsed().as_micros() as u64;
                let parent = r.stack.last().copied();
                let lane = r.lane;
                r.spans.push(SpanRecord {
                    name,
                    lane,
                    parent,
                    start_us: now,
                    end_us: u64::MAX,
                    args: Vec::new(),
                });
                let idx = r.spans.len() - 1;
                r.stack.push(idx);
                idx
            }
        };
        Span { tel: self, idx }
    }

    fn close_span(&self, idx: usize) {
        if idx == usize::MAX {
            return;
        }
        if let Some(cell) = &self.inner {
            let mut r = cell.borrow_mut();
            let now = r.origin.elapsed().as_micros() as u64;
            if let Some(s) = r.spans.get_mut(idx) {
                if s.end_us == u64::MAX {
                    s.end_us = now.max(s.start_us);
                }
            }
            // Pop through idx: guards dropped out of order still leave a
            // consistent stack.
            if let Some(pos) = r.stack.iter().rposition(|&i| i == idx) {
                r.stack.truncate(pos);
            }
        }
    }

    fn annotate(&self, idx: usize, key: &'static str, value: String) {
        if let Some(cell) = &self.inner {
            let mut r = cell.borrow_mut();
            if let Some(s) = r.spans.get_mut(idx) {
                s.args.push((key, value));
            }
        }
    }

    /// Add to a counter (see [`CounterSet::add`]).
    pub fn add(&self, metric: &'static str, labels: &str, delta: u64) {
        if let Some(cell) = &self.inner {
            cell.borrow_mut().counters.add(metric, labels, delta);
        }
    }

    /// Raise a gauge (see [`CounterSet::gauge_max`]).
    pub fn gauge_max(&self, metric: &'static str, labels: &str, value: u64) {
        if let Some(cell) = &self.inner {
            cell.borrow_mut().counters.gauge_max(metric, labels, value);
        }
    }

    /// Record one latency observation (microseconds) into a histogram
    /// series (see [`HistogramSet::observe`]). Observed values are
    /// wall-clock and sit outside the byte-identity boundary, exactly
    /// like span timestamps; only the bucket *bounds* are fixed.
    pub fn observe_us(&self, metric: &'static str, labels: &str, us: u64) {
        if let Some(cell) = &self.inner {
            cell.borrow_mut().histograms.observe(metric, labels, us);
        }
    }

    /// Record a structured event. The message closure only runs when the
    /// handle is enabled — disabled handles pay nothing for formatting.
    pub fn event(&self, scope: &'static str, msg: impl FnOnce() -> String) {
        if self.inner.is_none() {
            return;
        }
        let m = msg();
        let cell = self.inner.as_ref().unwrap();
        let mut r = cell.borrow_mut();
        if r.echo {
            eprintln!("[{scope}] {m}");
        }
        let lane = r.lane;
        let ts_us = r.origin.elapsed().as_micros() as u64;
        r.events.push(EventRecord {
            lane,
            ts_us,
            scope,
            msg: m,
        });
    }

    /// Spawn a handle on a fresh timeline lane sharing this handle's
    /// time origin — one per portfolio task / churn policy. Call on the
    /// owning thread *before* spawning workers so lane numbering stays
    /// deterministic; hand the result back via [`absorb`](Self::absorb).
    pub fn child(&self, label: &str) -> Telemetry {
        match &self.inner {
            None => Telemetry::off(),
            Some(cell) => {
                let r = cell.borrow();
                let lane = r.lane_alloc.fetch_add(1, Ordering::Relaxed) + 1;
                Telemetry {
                    inner: Some(RefCell::new(Recorder {
                        echo: false,
                        origin: r.origin,
                        lane,
                        lane_alloc: r.lane_alloc.clone(),
                        lane_names: vec![(lane, label.to_string())],
                        spans: Vec::new(),
                        stack: Vec::new(),
                        events: Vec::new(),
                        counters: CounterSet::default(),
                        histograms: HistogramSet::default(),
                    })),
                }
            }
        }
    }

    /// Merge a child handle's record into this one. Deterministic as
    /// long as callers absorb in a deterministic order (the race absorbs
    /// by task index, the churn comparator by policy order).
    pub fn absorb(&self, child: Telemetry) {
        let cell = match &self.inner {
            Some(c) => c,
            None => return,
        };
        let ccell = match child.inner {
            Some(c) => c,
            None => return,
        };
        let c = ccell.into_inner();
        let mut r = cell.borrow_mut();
        let offset = r.spans.len();
        for mut s in c.spans {
            s.parent = s.parent.map(|p| p + offset);
            if s.end_us == u64::MAX {
                s.end_us = s.start_us; // absorbed while open: zero-length
            }
            r.spans.push(s);
        }
        r.events.extend(c.events);
        r.lane_names.extend(c.lane_names);
        r.counters.merge(&c.counters);
        r.histograms.merge(&c.histograms);
    }

    /// Snapshot of the counter set (tests, reports).
    pub fn counters(&self) -> CounterSet {
        match &self.inner {
            None => CounterSet::default(),
            Some(cell) => cell.borrow().counters.clone(),
        }
    }

    /// Snapshot of the histogram set (latency summaries, tests).
    pub fn histograms(&self) -> HistogramSet {
        match &self.inner {
            None => HistogramSet::default(),
            Some(cell) => cell.borrow().histograms.clone(),
        }
    }

    /// Number of recorded spans (tests).
    pub fn span_count(&self) -> usize {
        match &self.inner {
            None => 0,
            Some(cell) => cell.borrow().spans.len(),
        }
    }

    /// Chrome-trace JSON of everything recorded so far.
    pub fn export_chrome(&self) -> String {
        match &self.inner {
            None => chrome::render(&[], &[], &[]),
            Some(cell) => {
                let r = cell.borrow();
                chrome::render(&r.spans, &r.events, &r.lane_names)
            }
        }
    }

    /// Prometheus text exposition of the counter and histogram sets.
    pub fn export_prometheus(&self) -> String {
        match &self.inner {
            None => prometheus::render(&CounterSet::default(), &HistogramSet::default()),
            Some(cell) => {
                let r = cell.borrow();
                prometheus::render(&r.counters, &r.histograms)
            }
        }
    }
}

/// RAII span guard: closes its span when dropped. Obtained from
/// [`Telemetry::span`]; annotate with [`Span::arg`].
pub struct Span<'a> {
    tel: &'a Telemetry,
    idx: usize,
}

impl Span<'_> {
    /// Attach a key/value argument (shown in the trace viewer). Free
    /// when telemetry is off — the value is never formatted.
    pub fn arg(&self, key: &'static str, value: impl std::fmt::Display) {
        if self.idx != usize::MAX {
            self.tel.annotate(self.idx, key, value.to_string());
        }
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        self.tel.close_span(self.idx);
    }
}

/// Open an RAII span on a [`Telemetry`] handle held for the rest of the
/// enclosing block: `span!(tel, "phase1_solve")`.
#[macro_export]
macro_rules! span {
    ($tel:expr, $name:literal) => {
        let _telemetry_span = $tel.span($name);
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_handle_is_inert() {
        let tel = Telemetry::off();
        assert!(!tel.enabled());
        {
            let sp = tel.span("nothing");
            sp.arg("k", 1u64);
        }
        tel.add("x_total", "", 5);
        tel.event("scope", || unreachable!("must not format when off"));
        assert_eq!(tel.span_count(), 0);
        assert!(tel.counters().is_empty());
        assert_eq!(tel.export_prometheus(), "");
    }

    #[test]
    fn spans_nest_and_close_on_drop() {
        let tel = Telemetry::recording();
        {
            let _outer = tel.span("outer");
            {
                let inner = tel.span("inner");
                inner.arg("tier", 0u64);
            }
        }
        assert_eq!(tel.span_count(), 2);
        let trace = tel.export_chrome();
        assert!(trace.contains("\"outer\""));
        assert!(trace.contains("\"inner\""));
    }

    #[test]
    fn verbosity_parses_and_orders() {
        assert_eq!(Verbosity::parse("debug"), Some(Verbosity::Debug));
        assert_eq!(Verbosity::parse("bogus"), None);
        assert!(Verbosity::Off < Verbosity::Info);
        assert!(Verbosity::Info < Verbosity::Debug);
        assert_eq!(Verbosity::default(), Verbosity::Off);
        assert!(!Telemetry::from_verbosity(Verbosity::Off).enabled());
        assert!(Telemetry::from_verbosity(Verbosity::Info).enabled());
    }

    #[test]
    fn children_merge_in_absorb_order() {
        let tel = Telemetry::recording();
        let c1 = tel.child("task-0");
        let c2 = tel.child("task-1");
        {
            span!(c2, "b");
        }
        {
            span!(c1, "a");
        }
        c1.add("n_total", "", 1);
        c2.add("n_total", "", 2);
        tel.absorb(c1);
        tel.absorb(c2);
        assert_eq!(tel.span_count(), 2);
        assert_eq!(tel.counters().get("n_total", ""), Some(3));
        // Lanes were allocated in creation order: task-0 → 1, task-1 → 2.
        let trace = tel.export_chrome();
        assert!(trace.contains("task-0"));
        assert!(trace.contains("task-1"));
    }

    #[test]
    fn events_are_recorded_with_scope() {
        let tel = Telemetry::recording();
        tel.event("optimize", || "tier 0 phase1: placed 3".to_string());
        let trace = tel.export_chrome();
        assert!(trace.contains("tier 0 phase1: placed 3"));
        assert!(trace.contains("\"optimize\""));
    }

    #[test]
    fn exports_are_byte_stable() {
        let tel = Telemetry::recording();
        {
            let sp = tel.span("solve");
            sp.arg("tier", 1u64);
        }
        tel.add("solver_decisions_total", "strategy=\"default\"", 42);
        tel.observe_us("serve_window_solve_seconds", "", 123);
        assert_eq!(tel.export_chrome(), tel.export_chrome());
        assert_eq!(tel.export_prometheus(), tel.export_prometheus());
    }

    #[test]
    fn histograms_record_absorb_and_export() {
        let off = Telemetry::off();
        off.observe_us("x_seconds", "", 1);
        assert!(off.histograms().is_empty());

        let tel = Telemetry::recording();
        let child = tel.child("task-0");
        child.observe_us("race_task_seconds", "strategy=\"a\"", 10);
        tel.observe_us("race_task_seconds", "strategy=\"a\"", 20);
        tel.absorb(child);
        let h = tel.histograms();
        assert_eq!(h.get("race_task_seconds", "strategy=\"a\"").unwrap().count(), 2);
        let text = tel.export_prometheus();
        assert!(text.contains("# TYPE kube_packd_race_task_seconds histogram"));
        assert!(text.contains("race_task_seconds_count{strategy=\"a\"} 2"));
    }
}
