//! The single monotonic-time source: deadlines, hierarchical time
//! budgets, and stopwatches.
//!
//! Every wall-clock measurement in the crate — solver deadlines, the
//! paper's per-tier α budget, harness timings, and telemetry span
//! timestamps — flows through this module so there is exactly one place
//! where `Instant` is read. Code outside `telemetry` should not call
//! `Instant::now()` directly; use [`Stopwatch`] / [`Deadline`] (or a
//! telemetry span) instead.
//!
//! The paper's Algorithm 1 divides a total wall-clock budget `T_total`
//! across priority tiers: each tier is *reserved* `α·T_total/(p_max+1)`,
//! and any reserved-but-unused time rolls into the next solver call
//! (`get_timeout() = α·T_total/(p_max+1) + unused`). [`TimeBudget`]
//! implements exactly that accounting; [`Deadline`] is the cheap
//! per-search check the solver polls.

use std::time::{Duration, Instant};

/// A fixed point in time the solver must not run past.
#[derive(Clone, Copy, Debug)]
pub struct Deadline {
    end: Instant,
}

impl Deadline {
    pub fn after(d: Duration) -> Self {
        Deadline {
            end: Instant::now() + d,
        }
    }

    /// A deadline so far out it never fires (for "solve to optimality").
    pub fn unlimited() -> Self {
        Deadline {
            end: Instant::now() + Duration::from_secs(86_400 * 365),
        }
    }

    #[inline]
    pub fn expired(&self) -> bool {
        Instant::now() >= self.end
    }

    pub fn remaining(&self) -> Duration {
        self.end.saturating_duration_since(Instant::now())
    }

    /// [`Deadline::remaining`] against a caller-provided `now` — saves a
    /// second `Instant::now()` on hot poll paths that already hold one.
    pub fn remaining_from(&self, now: Instant) -> Duration {
        self.end.saturating_duration_since(now)
    }

    /// The earlier of two deadlines.
    pub fn min(self, other: Deadline) -> Deadline {
        Deadline {
            end: self.end.min(other.end),
        }
    }
}

/// Paper's per-tier time accounting (Implementation §Optimisation problem).
///
/// `T_total` is the overall wall-clock limit; a fraction `α` of it is
/// pre-partitioned evenly across `p_max + 1` priority tiers, and the
/// remaining `(1-α)·T_total` plus any unused reservations are consumed
/// opportunistically. Each tier's reservation is further split in half
/// between its two solve phases (maximise placements / minimise moves).
#[derive(Debug)]
pub struct TimeBudget {
    started: Instant,
    total: Duration,
    tier_reservation: Duration,
    /// Reserved-but-unused time carried across solver calls.
    unused: Duration,
}

impl TimeBudget {
    pub fn new(total: Duration, alpha: f64, num_tiers: u32) -> Self {
        assert!((0.0..=1.0).contains(&alpha), "alpha in [0,1]");
        assert!(num_tiers > 0);
        let tier_reservation = total.mul_f64(alpha / num_tiers as f64);
        TimeBudget {
            started: Instant::now(),
            total,
            tier_reservation,
            unused: Duration::ZERO,
        }
    }

    /// Wall-clock elapsed since the budget was opened.
    pub fn elapsed(&self) -> Duration {
        self.started.elapsed()
    }

    /// Hard overall deadline (`T_total` from the start).
    pub fn overall_deadline(&self) -> Deadline {
        Deadline {
            end: self.started + self.total,
        }
    }

    /// Time granted to the next solver call within one tier *phase*
    /// (half the tier reservation, per the paper) plus all carried
    /// `unused` time — clipped to the overall remaining budget.
    pub fn grant_phase(&mut self) -> Duration {
        let want = self.tier_reservation / 2 + self.unused;
        let remaining = self.total.saturating_sub(self.started.elapsed());
        let granted = want.min(remaining);
        // The grant is handed out; the carry is re-credited on `report_used`.
        self.unused = Duration::ZERO;
        granted
    }

    /// Report how much of a `granted` slice a solve actually consumed;
    /// the difference is carried forward (paper's `unused`).
    pub fn report_used(&mut self, granted: Duration, used: Duration) {
        self.unused += granted.saturating_sub(used.min(granted));
    }

    /// Whether the overall budget is exhausted.
    pub fn exhausted(&self) -> bool {
        self.started.elapsed() >= self.total
    }
}

/// Simple stopwatch for measurements.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_expires() {
        let d = Deadline::after(Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(5));
        assert!(d.expired());
        assert_eq!(d.remaining(), Duration::ZERO);
    }

    #[test]
    fn deadline_unlimited_does_not_expire() {
        assert!(!Deadline::unlimited().expired());
    }

    #[test]
    fn deadline_min_picks_earlier() {
        let a = Deadline::after(Duration::from_secs(1));
        let b = Deadline::after(Duration::from_secs(10));
        let m = a.min(b);
        assert!(m.remaining() <= Duration::from_secs(1));
    }

    #[test]
    fn budget_partitions_alpha_evenly() {
        let mut b = TimeBudget::new(Duration::from_secs(10), 0.8, 4);
        // tier reservation = 0.8*10/4 = 2s; phase grant = 1s (+unused 0)
        let g = b.grant_phase();
        assert!((g.as_secs_f64() - 1.0).abs() < 0.05, "{g:?}");
    }

    #[test]
    fn unused_time_carries_forward() {
        let mut b = TimeBudget::new(Duration::from_secs(10), 0.8, 4);
        let g1 = b.grant_phase();
        b.report_used(g1, Duration::from_millis(100)); // used 0.1 of 1s
        let g2 = b.grant_phase();
        // g2 = 1s + 0.9s carry ≈ 1.9s
        assert!(g2 > Duration::from_millis(1700), "{g2:?}");
    }

    #[test]
    fn grant_clipped_by_overall_budget() {
        let mut b = TimeBudget::new(Duration::from_millis(5), 1.0, 1);
        std::thread::sleep(Duration::from_millis(10));
        assert!(b.exhausted());
        assert_eq!(b.grant_phase(), Duration::ZERO);
    }
}
