//! Chrome Trace Event Format exporter (`chrome://tracing` / Perfetto).
//!
//! Renders the recorded span forest as paired `B`/`E` duration events
//! plus `i` instant events, one timeline lane (`tid`) per telemetry
//! handle. Spans within a lane were recorded under strict stack
//! discipline by one thread, so a depth-first emission per lane yields a
//! well-formed stream: every `B` has a matching `E`, and timestamps are
//! non-decreasing within a lane. The output is byte-stable for a fixed
//! recorded run: event order is derived from recording order and lane
//! ids only, and the JSON writer sorts object keys.

use crate::util::json::Json;

use super::{EventRecord, SpanRecord};

/// Render a complete trace document (compact JSON).
pub fn render(spans: &[SpanRecord], events: &[EventRecord], lanes: &[(u32, String)]) -> String {
    let mut out: Vec<Json> = Vec::new();

    // Lane metadata first: Perfetto names each tid row from these.
    let mut lanes_sorted: Vec<(u32, String)> = lanes.to_vec();
    lanes_sorted.sort();
    for (lane, name) in &lanes_sorted {
        let mut args = Json::obj();
        args.set("name", name.as_str());
        let mut m = Json::obj();
        m.set("ph", "M")
            .set("name", "thread_name")
            .set("pid", 1u64)
            .set("tid", *lane)
            .set("args", args);
        out.push(m);
    }

    // Build the span forest: children in recording order, roots per lane.
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); spans.len()];
    let mut roots: Vec<usize> = Vec::new();
    for (i, s) in spans.iter().enumerate() {
        match s.parent {
            Some(p) => children[p].push(i),
            None => roots.push(i),
        }
    }
    for &(lane, _) in &lanes_sorted {
        for &r in &roots {
            if spans[r].lane == lane {
                emit_span(r, spans, &children, &mut out);
            }
        }
    }
    // Roots on lanes that never got a name still must render.
    for &r in &roots {
        if !lanes_sorted.iter().any(|&(l, _)| l == spans[r].lane) {
            emit_span(r, spans, &children, &mut out);
        }
    }

    // Instant events, grouped per lane in timestamp order.
    let mut inst: Vec<&EventRecord> = events.iter().collect();
    inst.sort_by_key(|e| (e.lane, e.ts_us));
    for e in inst {
        let mut args = Json::obj();
        args.set("message", e.msg.as_str());
        let mut j = Json::obj();
        j.set("ph", "i")
            .set("name", e.scope)
            .set("cat", "kube-packd")
            .set("s", "t")
            .set("ts", e.ts_us)
            .set("pid", 1u64)
            .set("tid", e.lane)
            .set("args", args);
        out.push(j);
    }

    let mut doc = Json::obj();
    doc.set("displayTimeUnit", "ms")
        .set("traceEvents", Json::Arr(out));
    doc.to_string_compact()
}

/// Depth-first `B` … children … `E` emission of one span.
fn emit_span(i: usize, spans: &[SpanRecord], children: &[Vec<usize>], out: &mut Vec<Json>) {
    let s = &spans[i];
    // A span absorbed while still open reads as zero-length.
    let end = if s.end_us == u64::MAX { s.start_us } else { s.end_us };

    let mut b = Json::obj();
    b.set("ph", "B")
        .set("name", s.name)
        .set("cat", "kube-packd")
        .set("ts", s.start_us)
        .set("pid", 1u64)
        .set("tid", s.lane);
    if !s.args.is_empty() {
        let mut args = Json::obj();
        for (k, v) in &s.args {
            args.set(k, v.as_str());
        }
        b.set("args", args);
    }
    out.push(b);

    for &c in &children[i] {
        emit_span(c, spans, children, out);
    }

    let mut e = Json::obj();
    e.set("ph", "E")
        .set("name", s.name)
        .set("cat", "kube-packd")
        .set("ts", end.max(s.start_us))
        .set("pid", 1u64)
        .set("tid", s.lane);
    out.push(e);
}
