//! Ordered counter/gauge/histogram storage behind the [`Telemetry`]
//! handle.
//!
//! Keys are `(metric, labels)` pairs kept in `BTreeMap`s, so iteration
//! — and therefore every export — is deterministic regardless of the
//! order metrics were touched in. Counters add on merge; gauges take
//! the maximum (the only gauge today is `solver_max_depth`); histogram
//! buckets and sums add.
//!
//! Histograms use one **fixed** log-spaced bucket table
//! ([`BUCKET_BOUNDS_US`]) shared by every latency family, so the
//! exported bucket *structure* is byte-stable across runs and thread
//! counts even though the observed wall-clock values are not — the same
//! carve-out span timestamps already have in the determinism contract.
//!
//! [`Telemetry`]: super::Telemetry

use std::collections::BTreeMap;

/// Histogram bucket upper bounds in microseconds: powers of 4 from 1 µs
/// to ~16.8 s, plus an implicit `+Inf` overflow bucket. Log-spaced so a
/// single table covers sub-microsecond plumbing and multi-second solver
/// windows with constant relative error.
pub const BUCKET_BOUNDS_US: [u64; 13] = [
    1,
    4,
    16,
    64,
    256,
    1_024,
    4_096,
    16_384,
    65_536,
    262_144,
    1_048_576,
    4_194_304,
    16_777_216,
];

/// One latency histogram over the fixed [`BUCKET_BOUNDS_US`] table.
/// Bucket counts are stored per-bucket (non-cumulative); the Prometheus
/// exporter renders the cumulative `_bucket` form.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Histogram {
    /// Per-bucket counts; index i counts observations ≤ bounds[i].
    buckets: [u64; BUCKET_BOUNDS_US.len()],
    /// Observations above the last finite bound (`+Inf` bucket).
    overflow: u64,
    /// Sum of all observed values, microseconds.
    sum_us: u64,
    count: u64,
}

impl Histogram {
    /// Record one observation of `us` microseconds.
    pub fn observe(&mut self, us: u64) {
        match BUCKET_BOUNDS_US.iter().position(|&b| us <= b) {
            Some(i) => self.buckets[i] += 1,
            None => self.overflow += 1,
        }
        self.sum_us += us;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    /// Cumulative counts per finite bound, then the `+Inf` total — the
    /// exact sequence a Prometheus `_bucket` series carries.
    pub fn cumulative(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.buckets.len() + 1);
        let mut acc = 0u64;
        for &b in &self.buckets {
            acc += b;
            out.push(acc);
        }
        out.push(acc + self.overflow);
        out
    }

    /// Fold another histogram in (bucket-wise addition).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.overflow += other.overflow;
        self.sum_us += other.sum_us;
        self.count += other.count;
    }

    /// Estimate the `q`-quantile (q in [0,1]) in microseconds, with
    /// `histogram_quantile`-style linear interpolation inside the
    /// containing bucket. Observations in the `+Inf` bucket clamp to
    /// the largest finite bound. Returns 0.0 on an empty histogram.
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = q.clamp(0.0, 1.0) * self.count as f64;
        let mut acc = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            let next = acc + b;
            if (next as f64) >= rank && b > 0 {
                let lo = if i == 0 { 0.0 } else { BUCKET_BOUNDS_US[i - 1] as f64 };
                let hi = BUCKET_BOUNDS_US[i] as f64;
                let into = (rank - acc as f64) / b as f64;
                return lo + (hi - lo) * into.clamp(0.0, 1.0);
            }
            acc = next;
        }
        *BUCKET_BOUNDS_US.last().expect("non-empty bounds") as f64
    }
}

/// A deterministic map of labelled histograms, mirroring [`CounterSet`]:
/// keys are `(metric, labels)` with pre-rendered label bodies, iteration
/// is sorted, merge is bucket-wise addition.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistogramSet {
    entries: BTreeMap<(String, String), Histogram>,
}

impl HistogramSet {
    /// Record one observation, creating the series on first touch.
    pub fn observe(&mut self, metric: &str, labels: &str, us: u64) {
        self.entries
            .entry((metric.to_string(), labels.to_string()))
            .or_default()
            .observe(us);
    }

    pub fn get(&self, metric: &str, labels: &str) -> Option<&Histogram> {
        self.entries.get(&(metric.to_string(), labels.to_string()))
    }

    /// Merge one metric across all label sets into a single histogram
    /// (for summary quantiles over e.g. every strategy).
    pub fn total(&self, metric: &str) -> Histogram {
        let mut out = Histogram::default();
        for ((m, _), h) in &self.entries {
            if m == metric {
                out.merge(h);
            }
        }
        out
    }

    /// Fold another set in: histograms add bucket-wise.
    pub fn merge(&mut self, other: &HistogramSet) {
        for ((metric, labels), h) in &other.entries {
            self.entries
                .entry((metric.clone(), labels.clone()))
                .or_default()
                .merge(h);
        }
    }

    /// Sorted iteration: `(metric, labels, histogram)`.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &str, &Histogram)> {
        self.entries
            .iter()
            .map(|((m, l), h)| (m.as_str(), l.as_str(), h))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// How a metric merges and how it is typed in the Prometheus export.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CounterKind {
    /// Monotone sum; merges by addition.
    Counter,
    /// Level; merges by maximum.
    Gauge,
}

impl CounterKind {
    pub fn label(self) -> &'static str {
        match self {
            CounterKind::Counter => "counter",
            CounterKind::Gauge => "gauge",
        }
    }
}

/// A deterministic multiset of named counters and gauges.
///
/// `labels` is a pre-rendered Prometheus label body (without braces),
/// e.g. `strategy="default",component="2"`, or `""` for none. The caller
/// renders it so the hot path stays a single map lookup.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CounterSet {
    entries: BTreeMap<(String, String), (CounterKind, u64)>,
}

impl CounterSet {
    /// Add `delta` to a counter, creating it at zero first. A zero delta
    /// still creates the entry, so exports list every metric a run
    /// touched.
    pub fn add(&mut self, metric: &str, labels: &str, delta: u64) {
        let e = self
            .entries
            .entry((metric.to_string(), labels.to_string()))
            .or_insert((CounterKind::Counter, 0));
        e.1 += delta;
    }

    /// Raise a gauge to at least `value`.
    pub fn gauge_max(&mut self, metric: &str, labels: &str, value: u64) {
        let e = self
            .entries
            .entry((metric.to_string(), labels.to_string()))
            .or_insert((CounterKind::Gauge, 0));
        e.0 = CounterKind::Gauge;
        e.1 = e.1.max(value);
    }

    pub fn get(&self, metric: &str, labels: &str) -> Option<u64> {
        self.entries
            .get(&(metric.to_string(), labels.to_string()))
            .map(|&(_, v)| v)
    }

    /// Sum of one metric across all label sets.
    pub fn total(&self, metric: &str) -> u64 {
        self.entries
            .iter()
            .filter(|((m, _), _)| m == metric)
            .map(|(_, &(_, v))| v)
            .sum()
    }

    /// Fold another set in: counters add, gauges max.
    pub fn merge(&mut self, other: &CounterSet) {
        for ((metric, labels), (kind, value)) in &other.entries {
            match kind {
                CounterKind::Counter => self.add(metric, labels, *value),
                CounterKind::Gauge => self.gauge_max(metric, labels, *value),
            }
        }
    }

    /// Sorted iteration: `(metric, labels, kind, value)`.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &str, CounterKind, u64)> {
        self.entries
            .iter()
            .map(|((m, l), &(k, v))| (m.as_str(), l.as_str(), k, v))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_accumulates_and_zero_creates() {
        let mut c = CounterSet::default();
        c.add("x_total", "", 0);
        c.add("x_total", "", 3);
        c.add("x_total", "", 4);
        assert_eq!(c.get("x_total", ""), Some(7));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn labels_separate_series() {
        let mut c = CounterSet::default();
        c.add("wins_total", "strategy=\"a\"", 1);
        c.add("wins_total", "strategy=\"b\"", 2);
        assert_eq!(c.get("wins_total", "strategy=\"a\""), Some(1));
        assert_eq!(c.total("wins_total"), 3);
    }

    #[test]
    fn gauges_merge_by_max_counters_by_sum() {
        let mut a = CounterSet::default();
        a.add("n_total", "", 5);
        a.gauge_max("depth", "", 7);
        let mut b = CounterSet::default();
        b.add("n_total", "", 2);
        b.gauge_max("depth", "", 3);
        a.merge(&b);
        assert_eq!(a.get("n_total", ""), Some(7));
        assert_eq!(a.get("depth", ""), Some(7));
    }

    #[test]
    fn iteration_is_sorted() {
        let mut c = CounterSet::default();
        c.add("b_total", "", 1);
        c.add("a_total", "z=\"1\"", 1);
        c.add("a_total", "a=\"1\"", 1);
        let keys: Vec<(String, String)> = c
            .iter()
            .map(|(m, l, _, _)| (m.to_string(), l.to_string()))
            .collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
    }

    #[test]
    fn histogram_bucket_edges_are_inclusive_upper_bounds() {
        let mut h = Histogram::default();
        // Exactly on a bound lands in that bound's bucket; one past it
        // spills into the next — the `le` (less-or-equal) contract.
        h.observe(1);
        h.observe(2); // > 1, ≤ 4
        h.observe(4);
        h.observe(5); // > 4, ≤ 16
        let cum = h.cumulative();
        assert_eq!(cum[0], 1); // ≤ 1µs
        assert_eq!(cum[1], 3); // ≤ 4µs
        assert_eq!(cum[2], 4); // ≤ 16µs
        assert_eq!(*cum.last().unwrap(), 4); // +Inf == count
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum_us(), 12);
    }

    #[test]
    fn histogram_overflow_goes_to_inf_bucket() {
        let mut h = Histogram::default();
        let top = *BUCKET_BOUNDS_US.last().unwrap();
        h.observe(top);
        h.observe(top + 1);
        let cum = h.cumulative();
        assert_eq!(cum[BUCKET_BOUNDS_US.len() - 1], 1); // last finite
        assert_eq!(*cum.last().unwrap(), 2); // +Inf
    }

    #[test]
    fn histogram_merge_adds_bucketwise() {
        let mut a = Histogram::default();
        a.observe(3);
        let mut b = Histogram::default();
        b.observe(3);
        b.observe(100);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.sum_us(), 106);
        assert_eq!(*a.cumulative().last().unwrap(), 3);
    }

    #[test]
    fn histogram_quantiles_interpolate() {
        let mut h = Histogram::default();
        assert_eq!(h.quantile_us(0.5), 0.0); // empty
        for _ in 0..100 {
            h.observe(10); // all in the (4, 16] bucket
        }
        let p50 = h.quantile_us(0.5);
        assert!(
            (4.0..=16.0).contains(&p50),
            "p50 must interpolate inside the containing bucket, got {p50}"
        );
        // Quantiles are monotone in q.
        assert!(h.quantile_us(0.99) >= h.quantile_us(0.5));
    }

    #[test]
    fn histogram_set_labels_separate_and_total_merges() {
        let mut hs = HistogramSet::default();
        hs.observe("race_task_seconds", "strategy=\"a\"", 10);
        hs.observe("race_task_seconds", "strategy=\"b\"", 20);
        assert_eq!(hs.len(), 2);
        assert_eq!(
            hs.get("race_task_seconds", "strategy=\"a\"").unwrap().count(),
            1
        );
        let total = hs.total("race_task_seconds");
        assert_eq!(total.count(), 2);
        assert_eq!(total.sum_us(), 30);
        let mut other = HistogramSet::default();
        other.observe("race_task_seconds", "strategy=\"a\"", 5);
        hs.merge(&other);
        assert_eq!(
            hs.get("race_task_seconds", "strategy=\"a\"").unwrap().count(),
            2
        );
    }
}
