//! Ordered counter/gauge storage behind the [`Telemetry`] handle.
//!
//! Keys are `(metric, labels)` pairs kept in a `BTreeMap`, so iteration
//! — and therefore every export — is deterministic regardless of the
//! order counters were touched in. Counters add on merge; gauges take
//! the maximum (the only gauge today is `solver_max_depth`).
//!
//! [`Telemetry`]: super::Telemetry

use std::collections::BTreeMap;

/// How a metric merges and how it is typed in the Prometheus export.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CounterKind {
    /// Monotone sum; merges by addition.
    Counter,
    /// Level; merges by maximum.
    Gauge,
}

impl CounterKind {
    pub fn label(self) -> &'static str {
        match self {
            CounterKind::Counter => "counter",
            CounterKind::Gauge => "gauge",
        }
    }
}

/// A deterministic multiset of named counters and gauges.
///
/// `labels` is a pre-rendered Prometheus label body (without braces),
/// e.g. `strategy="default",component="2"`, or `""` for none. The caller
/// renders it so the hot path stays a single map lookup.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CounterSet {
    entries: BTreeMap<(String, String), (CounterKind, u64)>,
}

impl CounterSet {
    /// Add `delta` to a counter, creating it at zero first. A zero delta
    /// still creates the entry, so exports list every metric a run
    /// touched.
    pub fn add(&mut self, metric: &str, labels: &str, delta: u64) {
        let e = self
            .entries
            .entry((metric.to_string(), labels.to_string()))
            .or_insert((CounterKind::Counter, 0));
        e.1 += delta;
    }

    /// Raise a gauge to at least `value`.
    pub fn gauge_max(&mut self, metric: &str, labels: &str, value: u64) {
        let e = self
            .entries
            .entry((metric.to_string(), labels.to_string()))
            .or_insert((CounterKind::Gauge, 0));
        e.0 = CounterKind::Gauge;
        e.1 = e.1.max(value);
    }

    pub fn get(&self, metric: &str, labels: &str) -> Option<u64> {
        self.entries
            .get(&(metric.to_string(), labels.to_string()))
            .map(|&(_, v)| v)
    }

    /// Sum of one metric across all label sets.
    pub fn total(&self, metric: &str) -> u64 {
        self.entries
            .iter()
            .filter(|((m, _), _)| m == metric)
            .map(|(_, &(_, v))| v)
            .sum()
    }

    /// Fold another set in: counters add, gauges max.
    pub fn merge(&mut self, other: &CounterSet) {
        for ((metric, labels), (kind, value)) in &other.entries {
            match kind {
                CounterKind::Counter => self.add(metric, labels, *value),
                CounterKind::Gauge => self.gauge_max(metric, labels, *value),
            }
        }
    }

    /// Sorted iteration: `(metric, labels, kind, value)`.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &str, CounterKind, u64)> {
        self.entries
            .iter()
            .map(|((m, l), &(k, v))| (m.as_str(), l.as_str(), k, v))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_accumulates_and_zero_creates() {
        let mut c = CounterSet::default();
        c.add("x_total", "", 0);
        c.add("x_total", "", 3);
        c.add("x_total", "", 4);
        assert_eq!(c.get("x_total", ""), Some(7));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn labels_separate_series() {
        let mut c = CounterSet::default();
        c.add("wins_total", "strategy=\"a\"", 1);
        c.add("wins_total", "strategy=\"b\"", 2);
        assert_eq!(c.get("wins_total", "strategy=\"a\""), Some(1));
        assert_eq!(c.total("wins_total"), 3);
    }

    #[test]
    fn gauges_merge_by_max_counters_by_sum() {
        let mut a = CounterSet::default();
        a.add("n_total", "", 5);
        a.gauge_max("depth", "", 7);
        let mut b = CounterSet::default();
        b.add("n_total", "", 2);
        b.gauge_max("depth", "", 3);
        a.merge(&b);
        assert_eq!(a.get("n_total", ""), Some(7));
        assert_eq!(a.get("depth", ""), Some(7));
    }

    #[test]
    fn iteration_is_sorted() {
        let mut c = CounterSet::default();
        c.add("b_total", "", 1);
        c.add("a_total", "z=\"1\"", 1);
        c.add("a_total", "a=\"1\"", 1);
        let keys: Vec<(String, String)> = c
            .iter()
            .map(|(m, l, _, _)| (m.to_string(), l.to_string()))
            .collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
    }
}
