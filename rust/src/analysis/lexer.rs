//! Hand-rolled token-level lexer for the detlint pass.
//!
//! Deliberately *not* a full Rust grammar: the rules in
//! [`super::rules`] only need a faithful token stream — identifiers,
//! punctuation, literals — with comments and string contents kept out
//! of the way (so `"Instant::now"` inside a string or a doc comment
//! never fires a finding). The lexer handles the corners that would
//! otherwise cause misfires: nested block comments, escaped and raw
//! (byte) strings, char literals vs. lifetimes, and `#[cfg(test)]`
//! regions (test code may panic and read clocks freely; the pass marks
//! those tokens and every rule skips them).
//!
//! It also extracts waiver *directives* from line comments:
//!
//! ```text
//! // detlint: allow(<rule>[, <rule>]*) — <mandatory reason>
//! ```
//!
//! A trailing directive waives findings on its own line; a standalone
//! comment line waives the next token-bearing line. The reason text is
//! not optional — a directive without one is itself reported (the
//! `bad-directive` rule in [`super`]).

/// Token class. The rules only ever distinguish identifiers,
/// single-char punctuation, string literals (for the wire-parity
/// extraction), and "everything else literal".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
    /// String literal (plain, byte, or raw); `text` holds the content.
    Str,
    /// Char / byte-char / numeric literal.
    Lit,
    /// `'a`, `'static` — kept distinct so `'a'` vs `'a` never confuse
    /// the punctuation stream.
    Lifetime,
}

/// One lexed token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    /// Inside a `#[cfg(test)]` item — every rule skips these.
    pub in_test: bool,
}

impl Tok {
    pub fn is_ident(&self, name: &str) -> bool {
        self.kind == TokKind::Ident && self.text == name
    }

    pub fn is_punct(&self, ch: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == 1 && self.text.starts_with(ch)
    }
}

/// A `// detlint: allow(...)` comment, as written (validated later).
#[derive(Clone, Debug)]
pub struct Directive {
    pub line: u32,
    /// No token precedes the comment on its line: the directive targets
    /// the next token-bearing line instead of its own.
    pub standalone: bool,
    /// Rule slugs listed inside `allow(...)`.
    pub rules: Vec<String>,
    /// The comment matched the `allow(...)` grammar at all.
    pub parse_ok: bool,
    /// Non-empty reason text followed the closing paren.
    pub reason_ok: bool,
}

/// Lexer output: the token stream plus every directive comment.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub directives: Vec<Directive>,
}

/// Lex one source file. Never fails: unterminated constructs consume
/// to end of input (the pass is a linter, not a compiler).
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also doc `///` and `//!`): scan for a directive.
        if c == '/' && b.get(i + 1) == Some(&'/') {
            let start = i + 2;
            let mut j = start;
            while j < b.len() && b[j] != '\n' {
                j += 1;
            }
            let body: String = b[start..j].iter().collect();
            let standalone = out.toks.last().map_or(true, |t| t.line != line);
            if let Some(d) = parse_directive(&body, line, standalone) {
                out.directives.push(d);
            }
            i = j;
            continue;
        }
        // Block comment, nesting like rustc.
        if c == '/' && b.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        // String literals, including `b"…"`, `r"…"`, `r#"…"#`, `br#"…"#`.
        if c == '"' || ((c == 'r' || c == 'b') && string_prefix(&b, i).is_some()) {
            let (content, next, nl) = lex_string(&b, i);
            out.toks.push(Tok {
                kind: TokKind::Str,
                text: content,
                line,
                in_test: false,
            });
            line += nl;
            i = next;
            continue;
        }
        // Char literal vs. lifetime.
        if c == '\'' {
            let (kind, text, next, nl) = lex_quote(&b, i);
            out.toks.push(Tok {
                kind,
                text,
                line,
                in_test: false,
            });
            line += nl;
            i = next;
            continue;
        }
        if c == '_' || c.is_alphabetic() {
            let start = i;
            while i < b.len() && (b[i] == '_' || b[i].is_alphanumeric()) {
                i += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text: b[start..i].iter().collect(),
                line,
                in_test: false,
            });
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            while i < b.len() && (b[i] == '_' || b[i].is_alphanumeric()) {
                i += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Lit,
                text: b[start..i].iter().collect(),
                line,
                in_test: false,
            });
            continue;
        }
        out.toks.push(Tok {
            kind: TokKind::Punct,
            text: c.to_string(),
            line,
            in_test: false,
        });
        i += 1;
    }
    mark_test_regions(&mut out.toks);
    out
}

/// Does position `i` (at `r`/`b`) start a string literal? Returns the
/// offset of the opening quote and the `#` count for raw strings.
fn string_prefix(b: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if b.get(j) == Some(&'b') {
        j += 1;
    }
    if b.get(j) == Some(&'r') {
        let mut hashes = 0usize;
        let mut k = j + 1;
        while b.get(k) == Some(&'#') {
            hashes += 1;
            k += 1;
        }
        if b.get(k) == Some(&'"') {
            return Some((k, hashes));
        }
        return None;
    }
    // Only `b"…"` remains (`"` alone is handled by the caller).
    if j > i && b.get(j) == Some(&'"') {
        return Some((j, 0));
    }
    None
}

/// Lex a string starting at `i` (at the quote or at an `r`/`b`
/// prefix). Returns (content, next index, newlines consumed).
fn lex_string(b: &[char], i: usize) -> (String, usize, u32) {
    let (quote, hashes) = match b[i] {
        '"' => (i, 0),
        _ => string_prefix(b, i).unwrap_or((i, 0)),
    };
    let raw = hashes > 0 || (quote > i && b[quote - 1] == 'r');
    let mut j = quote + 1;
    let mut content = String::new();
    let mut nl = 0u32;
    while j < b.len() {
        if b[j] == '\\' && !raw {
            if let Some(&esc) = b.get(j + 1) {
                content.push(esc);
                if esc == '\n' {
                    nl += 1;
                }
            }
            j += 2;
            continue;
        }
        if b[j] == '"' {
            // Raw strings close only on `"` followed by the right
            // number of `#`s.
            let mut k = j + 1;
            let mut seen = 0usize;
            while seen < hashes && b.get(k) == Some(&'#') {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return (content, k, nl);
            }
        }
        if b[j] == '\n' {
            nl += 1;
        }
        content.push(b[j]);
        j += 1;
    }
    (content, j, nl)
}

/// Lex from a `'`: either a char literal (`'x'`, `'\n'`, `'\u{1F4}'`)
/// or a lifetime (`'a`, `'static`).
fn lex_quote(b: &[char], i: usize) -> (TokKind, String, usize, u32) {
    if b.get(i + 1) == Some(&'\\') {
        // Escaped char literal: scan to the closing quote. `'\''` puts
        // the quote directly after the backslash.
        let mut j = i + 2;
        if b.get(j) == Some(&'\'') {
            j += 1;
        }
        while j < b.len() && b[j] != '\'' {
            j += 1;
        }
        let text: String = b[i..(j + 1).min(b.len())].iter().collect();
        return (TokKind::Lit, text, (j + 1).min(b.len()), 0);
    }
    if b.get(i + 2) == Some(&'\'') {
        let nl = u32::from(b.get(i + 1) == Some(&'\n'));
        let text: String = b[i..i + 3].iter().collect();
        return (TokKind::Lit, text, i + 3, nl);
    }
    let mut j = i + 1;
    while j < b.len() && (b[j] == '_' || b[j].is_alphanumeric()) {
        j += 1;
    }
    let text: String = b[i..j].iter().collect();
    (TokKind::Lifetime, text, j, 0)
}

/// Parse a line-comment body as a directive, if it is one. Leading doc
/// markers (`/`, `!`) are stripped so `/// detlint: …` also works.
fn parse_directive(body: &str, line: u32, standalone: bool) -> Option<Directive> {
    let text = body.trim_start_matches(['/', '!']).trim();
    let rest = text.strip_prefix("detlint:")?.trim_start();
    let mut d = Directive {
        line,
        standalone,
        rules: Vec::new(),
        parse_ok: false,
        reason_ok: false,
    };
    let Some(list) = rest.strip_prefix("allow(") else {
        return Some(d); // `detlint:` without `allow(…)` — bad-directive
    };
    let Some(close) = list.find(')') else {
        return Some(d);
    };
    d.rules = list[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    d.parse_ok = !d.rules.is_empty();
    let reason = list[close + 1..].trim_start_matches(['—', '–', '-', ':', ' ', '\t']);
    d.reason_ok = reason.chars().any(char::is_alphanumeric);
    Some(d)
}

/// Mark every token inside a `#[cfg(test)]` item (`mod tests { … }`,
/// a lone `#[cfg(test)] fn`, or a `use`): rules skip test code.
fn mark_test_regions(toks: &mut [Tok]) {
    let mut i = 0usize;
    while i < toks.len() {
        if !is_cfg_test_attr(toks, i) {
            i += 1;
            continue;
        }
        let start = i;
        let mut j = i + 7;
        // Skip further attributes between the cfg and the item.
        while j < toks.len() && toks[j].is_punct('#') {
            if j + 1 < toks.len() && toks[j + 1].is_punct('[') {
                j = match_close(toks, j + 1, '[', ']') + 1;
            } else {
                j += 1;
            }
        }
        // Scan to the item's body (or a `;` for body-less items).
        while j < toks.len() && !toks[j].is_punct('{') && !toks[j].is_punct(';') {
            j += 1;
        }
        if j < toks.len() && toks[j].is_punct('{') {
            j = match_close(toks, j, '{', '}');
        }
        let end = j.min(toks.len().saturating_sub(1));
        for t in &mut toks[start..=end] {
            t.in_test = true;
        }
        i = end + 1;
    }
}

/// Token sequence `#[cfg(test)]` at `i`.
fn is_cfg_test_attr(toks: &[Tok], i: usize) -> bool {
    i + 6 < toks.len()
        && toks[i].is_punct('#')
        && toks[i + 1].is_punct('[')
        && toks[i + 2].is_ident("cfg")
        && toks[i + 3].is_punct('(')
        && toks[i + 4].is_ident("test")
        && toks[i + 5].is_punct(')')
        && toks[i + 6].is_punct(']')
}

/// Index of the token closing the bracket opened at `open_idx`
/// (depth-matched). Unbalanced input answers the last index.
pub fn match_close(toks: &[Tok], open_idx: usize, open: char, close: char) -> usize {
    let mut depth = 0usize;
    for (j, t) in toks.iter().enumerate().skip(open_idx) {
        if t.is_punct(open) {
            depth += 1;
        } else if t.is_punct(close) {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
    }
    toks.len().saturating_sub(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn comments_and_strings_hide_their_contents() {
        let src = r##"
            // Instant::now in a comment
            /* HashMap in /* a nested */ block */
            let s = "Instant::now()";
            let r = r#"HashMap "quoted" inside"#;
        "##;
        let ids = idents(src);
        assert!(!ids.contains(&"Instant".to_string()), "{ids:?}");
        assert!(!ids.contains(&"HashMap".to_string()), "{ids:?}");
        assert!(ids.contains(&"let".to_string()));
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let lx = lex("fn f<'a>(x: &'a str) -> char { 'x' }");
        let lifetimes: Vec<_> = lx
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.clone())
            .collect();
        assert_eq!(lifetimes, vec!["'a", "'a"]);
        assert!(lx.toks.iter().any(|t| t.kind == TokKind::Lit && t.text == "'x'"));
    }

    #[test]
    fn escaped_char_literals_do_not_derail() {
        let ids = idents(r"let c = '\''; let n = '\n'; let u = '\u{1F600}'; done();");
        assert!(ids.contains(&"done".to_string()), "{ids:?}");
    }

    #[test]
    fn directive_trailing_and_standalone() {
        let lx = lex(concat!(
            "let t = now(); // detlint: allow(wall-clock) — deadline anchor\n",
            "// detlint: allow(hash-iter, float-order) — twin reasons\n",
            "let m = build();\n",
        ));
        assert_eq!(lx.directives.len(), 2);
        let d0 = &lx.directives[0];
        assert!(!d0.standalone && d0.parse_ok && d0.reason_ok);
        assert_eq!(d0.rules, vec!["wall-clock"]);
        let d1 = &lx.directives[1];
        assert!(d1.standalone && d1.parse_ok && d1.reason_ok);
        assert_eq!(d1.rules, vec!["hash-iter", "float-order"]);
    }

    #[test]
    fn directive_without_reason_is_flagged() {
        let lx = lex("let t = now(); // detlint: allow(wall-clock)\n");
        assert_eq!(lx.directives.len(), 1);
        assert!(lx.directives[0].parse_ok);
        assert!(!lx.directives[0].reason_ok);
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { panic!(\"x\") }\n}\n";
        let lx = lex(src);
        let panic_tok = lx.toks.iter().find(|t| t.is_ident("panic")).unwrap();
        assert!(panic_tok.in_test);
        let live_tok = lx.toks.iter().find(|t| t.is_ident("live")).unwrap();
        assert!(!live_tok.in_test);
    }

    #[test]
    fn line_numbers_survive_multiline_constructs() {
        let src = "let a = \"two\nlines\";\nlet b = 1;\n";
        let lx = lex(src);
        let b_tok = lx.toks.iter().find(|t| t.is_ident("b")).unwrap();
        assert_eq!(b_tok.line, 3);
    }
}
