//! The determinism-zone manifest.
//!
//! Every file under `rust/src` maps to exactly one zone; a file that
//! matches no manifest entry is itself a finding (`no-zone`), so new
//! modules cannot silently escape analysis — adding a file forces an
//! explicit placement decision here.
//!
//! * **Core** — the byte-identity boundary: everything whose outputs
//!   must be reproducible across runs and thread counts (solver,
//!   optimizer, portfolio, cluster state, lifecycle, autoscaler, and
//!   the server's batcher/engine/journal/protocol). Wall clocks,
//!   hash-ordered containers, and telemetry *reads* are forbidden here
//!   without a reasoned waiver.
//! * **Periphery** — observers and drivers around the core (telemetry
//!   itself, the experiment harness, the load generator, the bench
//!   harness). May read clocks; still subject to the universal rules
//!   (e.g. `float-order`).
//! * **Exempt** — everything else: legacy scheduler re-implementation,
//!   simulator, metrics, workload generation, runtime, utilities, CLI,
//!   and this analysis pass. Universal rules still apply.

/// Which determinism contract a file lives under.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Zone {
    Core,
    Periphery,
    Exempt,
}

impl Zone {
    pub fn name(self) -> &'static str {
        match self {
            Zone::Core => "core",
            Zone::Periphery => "periphery",
            Zone::Exempt => "exempt",
        }
    }
}

/// Directories (top-level under `rust/src`) in the deterministic core.
pub const CORE_DIRS: &[&str] = &[
    "autoscaler",
    "cluster",
    "lifecycle",
    "optimizer",
    "portfolio",
    "solver",
];

/// Individual core files (the server splits across zones: the wire
/// protocol, batcher, engine, and journal are inside the byte-identity
/// boundary; the accept loop and load generator are not).
pub const CORE_FILES: &[&str] = &[
    "server/batcher.rs",
    "server/engine.rs",
    "server/journal.rs",
    "server/protocol.rs",
];

/// Periphery directories: observers and experiment drivers.
pub const PERIPHERY_DIRS: &[&str] = &["harness", "telemetry"];

/// Periphery files carved out of otherwise-exempt (or core) parents.
pub const PERIPHERY_FILES: &[&str] = &["server/loadgen.rs", "util/bench.rs"];

/// Exempt directories (universal rules still apply).
pub const EXEMPT_DIRS: &[&str] = &[
    "analysis",
    "metrics",
    "runtime",
    "scheduler",
    "simulator",
    "util",
    "workload",
];

/// Exempt files at the tree root / in split directories.
pub const EXEMPT_FILES: &[&str] = &["lib.rs", "main.rs", "server/mod.rs"];

/// Zone of a file given its path relative to the source root (e.g.
/// `solver/search.rs`). Exact file entries win over directory entries
/// (`util/bench.rs` is periphery although `util/` is exempt). `None`
/// means the manifest has no opinion — report it, don't guess.
pub fn zone_of(rel: &str) -> Option<Zone> {
    for (files, zone) in [
        (CORE_FILES, Zone::Core),
        (PERIPHERY_FILES, Zone::Periphery),
        (EXEMPT_FILES, Zone::Exempt),
    ] {
        if files.contains(&rel) {
            return Some(zone);
        }
    }
    let (dir, rest) = rel.split_once('/')?;
    if rest.is_empty() {
        return None;
    }
    for (dirs, zone) in [
        (CORE_DIRS, Zone::Core),
        (PERIPHERY_DIRS, Zone::Periphery),
        (EXEMPT_DIRS, Zone::Exempt),
    ] {
        if dirs.contains(&dir) {
            return Some(zone);
        }
    }
    None
}

/// Source-root-relative path of `path`: the suffix after the last
/// `src/` component. Paths with no `src/` component pass through
/// unchanged (fixture snippets hand relative paths in directly).
pub fn rel_from(path: &str) -> String {
    if let Some(idx) = path.rfind("/src/") {
        return path[idx + "/src/".len()..].to_string();
    }
    if let Some(rest) = path.strip_prefix("src/") {
        return rest.to_string();
    }
    path.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_spot_checks() {
        assert_eq!(zone_of("solver/search.rs"), Some(Zone::Core));
        assert_eq!(zone_of("server/engine.rs"), Some(Zone::Core));
        assert_eq!(zone_of("server/mod.rs"), Some(Zone::Exempt));
        assert_eq!(zone_of("server/loadgen.rs"), Some(Zone::Periphery));
        assert_eq!(zone_of("util/bench.rs"), Some(Zone::Periphery));
        assert_eq!(zone_of("util/stats.rs"), Some(Zone::Exempt));
        assert_eq!(zone_of("telemetry/clock.rs"), Some(Zone::Periphery));
        assert_eq!(zone_of("main.rs"), Some(Zone::Exempt));
    }

    #[test]
    fn unknown_files_have_no_zone() {
        assert_eq!(zone_of("brand_new_dir/x.rs"), None);
        assert_eq!(zone_of("stray.rs"), None);
    }

    #[test]
    fn rel_path_extraction() {
        assert_eq!(rel_from("rust/src/solver/search.rs"), "solver/search.rs");
        assert_eq!(rel_from("/root/repo/rust/src/lib.rs"), "lib.rs");
        assert_eq!(rel_from("solver/search.rs"), "solver/search.rs");
    }
}
