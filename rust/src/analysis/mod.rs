//! detlint — the self-hosted determinism-boundary static analysis
//! pass behind `kube-packd lint [PATH]`.
//!
//! Every contract this reproduction rests on — byte-identical plans
//! across thread counts, certificates that mean what they say,
//! telemetry that observes but never feeds back — is otherwise only
//! *sampled* by proptests. This pass makes the boundary structural:
//! a zone manifest ([`zones`]) places every source file inside or
//! outside the byte-identity core, and token-pattern rules ([`rules`])
//! forbid the known nondeterminism sources inside it (wall clocks,
//! hash-ordered iteration, NaN-partial float comparisons, panics on
//! server connection paths, telemetry read-backs), plus a
//! cross-language `wire-parity` check pinning the Python client to the
//! Rust wire protocol.
//!
//! Violations are waivable only in the source itself:
//!
//! ```text
//! // detlint: allow(wall-clock) — solve-deadline anchor; see …
//! ```
//!
//! with a mandatory reason (a reason-less or unknown-slug directive is
//! its own finding, `bad-directive`). The CLI exits nonzero on any
//! unwaived finding; CI runs it as a blocking gate next to clippy/fmt.

pub mod lexer;
pub mod report;
pub mod rules;
pub mod zones;

use std::path::{Path, PathBuf};

pub use report::Report;
pub use rules::Finding;

use lexer::Directive;
use zones::Zone;

/// Findings and waiver tally for one file.
#[derive(Debug, Default)]
pub struct ScanResult {
    pub findings: Vec<Finding>,
    pub waived: usize,
}

/// Scan one file's source. `rel` is the source-root-relative path the
/// zone manifest keys on (e.g. `solver/search.rs`).
pub fn scan_source(rel: &str, src: &str) -> ScanResult {
    let lx = lexer::lex(src);
    let mut findings = Vec::new();
    let zone = match zones::zone_of(rel) {
        Some(z) => z,
        None => {
            findings.push(Finding {
                rule: "no-zone",
                path: rel.to_string(),
                line: 1,
                msg: "file matches no zone-manifest entry — place it in \
                      analysis/zones.rs (core, periphery, or exempt)"
                    .to_string(),
            });
            // Still scan: the universal rules apply to every zone.
            Zone::Exempt
        }
    };
    findings.extend(rules::scan_tokens(rel, zone, &lx.toks));

    // Validate directives; invalid ones waive nothing and are findings
    // themselves.
    let mut active: Vec<(u32, &Directive)> = Vec::new();
    for d in &lx.directives {
        if let Some(msg) = directive_problem(d) {
            findings.push(Finding {
                rule: "bad-directive",
                path: rel.to_string(),
                line: d.line,
                msg,
            });
            continue;
        }
        let target = if d.standalone {
            lx.toks.iter().find(|t| t.line > d.line).map(|t| t.line)
        } else {
            Some(d.line)
        };
        if let Some(t) = target {
            active.push((t, d));
        }
    }
    let before = findings.len();
    findings.retain(|f| {
        !(f.waivable()
            && active
                .iter()
                .any(|(t, d)| *t == f.line && d.rules.iter().any(|r| r == f.rule)))
    });
    ScanResult {
        waived: before - findings.len(),
        findings,
    }
}

/// Why this directive is invalid, if it is.
fn directive_problem(d: &Directive) -> Option<String> {
    if !d.parse_ok {
        return Some(
            "malformed directive — expected `detlint: allow(<rule>[, <rule>]*) — <reason>`"
                .to_string(),
        );
    }
    if let Some(bad) = d.rules.iter().find(|r| !rules::RULES.contains(&r.as_str())) {
        return Some(format!(
            "unknown rule `{bad}` in directive (known: {})",
            rules::RULES.join(", ")
        ));
    }
    if !d.reason_ok {
        return Some(
            "directive is missing its reason — waivers must say *why* the \
             violation is sound"
                .to_string(),
        );
    }
    None
}

/// Lint a tree (or a single `.rs` file): scan every Rust source, then
/// run the `wire-parity` drift check when the wire protocol is in
/// scope. Deterministic: files are visited in sorted order and
/// findings sorted by (path, line, rule).
pub fn lint_tree(root: &Path) -> anyhow::Result<Report> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    if files.is_empty() {
        anyhow::bail!("no .rs files under {}", root.display());
    }
    let mut rep = Report {
        files: files.len(),
        ..Report::default()
    };
    let mut protocol: Option<PathBuf> = None;
    for path in &files {
        let src = std::fs::read_to_string(path)?;
        let rel = zones::rel_from(&path.to_string_lossy());
        if rel == "server/protocol.rs" {
            protocol = Some(path.clone());
        }
        let r = scan_source(&rel, &src);
        rep.findings.extend(r.findings);
        rep.waived += r.waived;
    }
    if let Some(proto) = protocol {
        rep.findings.extend(wire_parity_for(&proto)?);
    }
    rep.findings
        .sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Ok(rep)
}

/// Run the wire-parity check for a scanned `server/protocol.rs`: the
/// Python client lives at `<repo>/python/client.py`, where `<repo>` is
/// four directories above the protocol file (server → src → rust →
/// repo). A missing client is a finding, not a skip — the drift check
/// must not rot off.
fn wire_parity_for(proto: &Path) -> anyhow::Result<Vec<Finding>> {
    let repo = proto
        .ancestors()
        .nth(4)
        .map(Path::to_path_buf)
        .unwrap_or_default();
    let client = repo.join("python/client.py");
    let proto_src = std::fs::read_to_string(proto)?;
    let Ok(client_src) = std::fs::read_to_string(&client) else {
        return Ok(vec![Finding {
            rule: "wire-parity",
            path: client.to_string_lossy().into_owned(),
            line: 1,
            msg: "python client not found — wire-parity cannot verify the op/error \
                  registries"
                .to_string(),
        }]);
    };
    Ok(rules::wire_parity(
        "server/protocol.rs",
        &proto_src,
        &client.to_string_lossy(),
        &client_src,
    ))
}

/// Recursively gather `.rs` files (also accepts a single-file root).
fn collect_rs(path: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    if path.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    let entries = std::fs::read_dir(path)
        .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
    for entry in entries {
        collect_rs(&entry?.path(), out)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waiver_lifecycle() {
        let fired = scan_source("solver/x.rs", "fn f() { let t = Instant::now(); }");
        assert_eq!(fired.findings.len(), 1);
        assert_eq!(fired.findings[0].rule, "wall-clock");

        let waived = scan_source(
            "solver/x.rs",
            "fn f() { let t = Instant::now(); // detlint: allow(wall-clock) — anchor\n}",
        );
        assert!(waived.findings.is_empty(), "{:?}", waived.findings);
        assert_eq!(waived.waived, 1);
    }

    #[test]
    fn reasonless_directive_waives_nothing_and_fires() {
        let r = scan_source(
            "solver/x.rs",
            "fn f() { let t = Instant::now(); // detlint: allow(wall-clock)\n}",
        );
        let rules: Vec<&str> = r.findings.iter().map(|f| f.rule).collect();
        assert!(rules.contains(&"wall-clock"), "{rules:?}");
        assert!(rules.contains(&"bad-directive"), "{rules:?}");
    }

    #[test]
    fn unknown_slug_is_a_bad_directive() {
        let r = scan_source("solver/x.rs", "// detlint: allow(wibble) — because\nfn f() {}");
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].rule, "bad-directive");
    }

    #[test]
    fn unzoned_file_is_reported() {
        let r = scan_source("mystery/new.rs", "fn f() {}");
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].rule, "no-zone");
    }
}
