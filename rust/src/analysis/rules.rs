//! The detlint rule set.
//!
//! Token-pattern rules over one file's lexed stream, scoped by the
//! zone manifest ([`super::zones`]), plus the cross-language
//! `wire-parity` check. Stable slugs (these appear in directives, CI
//! logs, and the JSON report — never rename, only add):
//!
//! | slug                 | scope            | forbids |
//! |----------------------|------------------|---------|
//! | `wall-clock`         | core             | `Instant::now()`, `SystemTime` |
//! | `hash-iter`          | core             | `HashMap` / `HashSet` (iteration order) |
//! | `float-order`        | every zone       | `partial_cmp().unwrap()`, float sorts without `total_cmp` |
//! | `panic-on-wire`      | `server/*`       | `unwrap`/`expect`/`panic!` on connection paths |
//! | `telemetry-feedback` | core             | telemetry/probe read-API calls (observe, never feed back) |
//! | `wire-parity`        | protocol ⇄ client| op/error-slug drift between Rust and Python |
//! | `bad-directive`      | everywhere       | malformed / reason-less / unknown-rule waivers |
//! | `no-zone`            | everywhere       | files the zone manifest doesn't place |

use std::collections::BTreeSet;

use super::lexer::{lex, match_close, Tok, TokKind};
use super::zones::Zone;

/// Every rule slug a directive may waive or reference.
pub const RULES: &[&str] = &[
    "wall-clock",
    "hash-iter",
    "float-order",
    "panic-on-wire",
    "telemetry-feedback",
    "wire-parity",
    "bad-directive",
    "no-zone",
];

/// Telemetry read-API method names: calling any of these outside the
/// telemetry/periphery zones lets observed data influence behaviour.
/// (`span`/`add`/`event` are write APIs and stay legal everywhere.)
/// The solve-forensics [`Probe`](crate::solver::Probe) read/export
/// surface rides the same contract: the probe records search effort,
/// and reading it back inside the core would let forensics steer
/// placement.
const TELEMETRY_READS: &[&str] = &[
    "export_chrome",
    "export_prometheus",
    "histograms",
    "span_count",
    "export_profile_json",
    "export_folded",
    "module_effort",
    "gap_samples",
];

/// Comparator-taking sort/extremum methods checked by `float-order`.
const SORT_FAMILY: &[&str] = &["sort_by", "sort_unstable_by", "max_by", "min_by"];

/// Callees whose `.expect()` propagates an *existing* panic (lock
/// poisoning) rather than originating a new one — structurally allowed
/// under `panic-on-wire`.
const POISON_SOURCES: &[&str] = &[
    "lock",
    "read",
    "write",
    "wait",
    "wait_timeout",
    "wait_timeout_while",
    "wait_while",
];

/// One lint finding, pre- or post-waiver.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Stable slug from [`RULES`].
    pub rule: &'static str,
    /// Path as reported (source-root-relative for Rust files).
    pub path: String,
    /// 1-based line.
    pub line: u32,
    pub msg: String,
}

impl Finding {
    fn new(rule: &'static str, path: &str, line: u32, msg: String) -> Finding {
        Finding {
            rule,
            path: path.to_string(),
            line,
            msg,
        }
    }

    /// Inline directives waive token-pattern findings only; manifest
    /// gaps, malformed directives, and cross-file drift stay fatal.
    pub fn waivable(&self) -> bool {
        !matches!(self.rule, "bad-directive" | "no-zone" | "wire-parity")
    }
}

/// Run every token-pattern rule over one file.
pub fn scan_tokens(rel: &str, zone: Zone, toks: &[Tok]) -> Vec<Finding> {
    let mut out = Vec::new();
    if zone == Zone::Core {
        wall_clock(rel, toks, &mut out);
        hash_iter(rel, toks, &mut out);
        telemetry_feedback(rel, toks, &mut out);
    }
    float_order(rel, toks, &mut out);
    if rel.starts_with("server/") && rel != "server/loadgen.rs" {
        panic_on_wire(rel, toks, &mut out);
    }
    out
}

fn wall_clock(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    for (i, t) in toks.iter().enumerate() {
        if t.in_test {
            continue;
        }
        if t.is_ident("Instant")
            && punct_at(toks, i + 1, ':')
            && punct_at(toks, i + 2, ':')
            && ident_at(toks, i + 3, "now")
        {
            out.push(Finding::new(
                "wall-clock",
                rel,
                t.line,
                "Instant::now() in the deterministic core — route time through \
                 telemetry::clock::Deadline or waive with a reason"
                    .to_string(),
            ));
        }
        if t.is_ident("SystemTime") {
            out.push(Finding::new(
                "wall-clock",
                rel,
                t.line,
                "SystemTime in the deterministic core".to_string(),
            ));
        }
    }
}

fn hash_iter(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    for t in toks {
        if t.in_test {
            continue;
        }
        if t.is_ident("HashMap") || t.is_ident("HashSet") {
            out.push(Finding::new(
                "hash-iter",
                rel,
                t.line,
                format!(
                    "{} in the deterministic core — iteration order is seeded per \
                     process; use BTreeMap/BTreeSet or sorted access",
                    t.text
                ),
            ));
        }
    }
}

fn telemetry_feedback(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    for (i, t) in toks.iter().enumerate() {
        if t.in_test || t.kind != TokKind::Ident {
            continue;
        }
        if TELEMETRY_READS.contains(&t.text.as_str())
            && i > 0
            && toks[i - 1].is_punct('.')
            && punct_at(toks, i + 1, '(')
        {
            out.push(Finding::new(
                "telemetry-feedback",
                rel,
                t.line,
                format!(
                    "telemetry read-API `{}()` in the deterministic core — telemetry \
                     observes and must never feed back into placement",
                    t.text
                ),
            ));
        }
    }
}

fn float_order(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    // `.partial_cmp(…).unwrap()` / `.expect(…)`: panics the moment a
    // NaN reaches the comparator.
    for (i, t) in toks.iter().enumerate() {
        if t.in_test || !t.is_ident("partial_cmp") {
            continue;
        }
        // `fn partial_cmp` (a PartialOrd impl) is not a call site.
        if i == 0 || !toks[i - 1].is_punct('.') || !punct_at(toks, i + 1, '(') {
            continue;
        }
        if unwrap_follows(toks, i) {
            out.push(Finding::new(
                "float-order",
                rel,
                t.line,
                "partial_cmp().unwrap() panics on NaN — use f64::total_cmp".to_string(),
            ));
        }
    }
    // Comparator regions that order floats without `total_cmp`: even a
    // non-panicking fallback (`unwrap_or(Equal)`) silently breaks sort
    // totality when a NaN slips in.
    for (i, t) in toks.iter().enumerate() {
        if t.in_test || t.kind != TokKind::Ident || !SORT_FAMILY.contains(&t.text.as_str()) {
            continue;
        }
        if !punct_at(toks, i + 1, '(') {
            continue;
        }
        let close = match_close(toks, i + 1, '(', ')');
        let region = &toks[i + 1..=close.min(toks.len() - 1)];
        let has_total = region.iter().any(|r| r.is_ident("total_cmp"));
        let soft_partial = region.iter().enumerate().any(|(j, r)| {
            r.is_ident("partial_cmp") && !unwrap_follows(region, j)
        });
        if soft_partial && !has_total {
            out.push(Finding::new(
                "float-order",
                rel,
                t.line,
                format!(
                    "{}() comparator uses partial_cmp without total_cmp — NaN breaks \
                     ordering totality",
                    t.text
                ),
            ));
        }
    }
}

/// Does `.unwrap()` / `.expect(…)` follow the call whose callee ident
/// sits at `i` (skipping its argument parens)?
fn unwrap_follows(toks: &[Tok], i: usize) -> bool {
    if !punct_at(toks, i + 1, '(') {
        return false;
    }
    let close = match_close(toks, i + 1, '(', ')');
    punct_at(toks, close + 1, '.')
        && (ident_at(toks, close + 2, "unwrap") || ident_at(toks, close + 2, "expect"))
}

fn panic_on_wire(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    for (i, t) in toks.iter().enumerate() {
        if t.in_test {
            continue;
        }
        if t.is_ident("panic") && punct_at(toks, i + 1, '!') {
            out.push(Finding::new(
                "panic-on-wire",
                rel,
                t.line,
                "panic! on a server path — a panic here drops the client; return a \
                 structured WireError instead"
                    .to_string(),
            ));
            continue;
        }
        if (t.is_ident("unwrap") || t.is_ident("expect"))
            && i > 0
            && toks[i - 1].is_punct('.')
            && punct_at(toks, i + 1, '(')
            && !propagates_poison(toks, i - 1)
        {
            out.push(Finding::new(
                "panic-on-wire",
                rel,
                t.line,
                format!(
                    ".{}() on a server path — a panic here drops the client; handle \
                     the None/Err arm or waive with a reason",
                    t.text
                ),
            ));
        }
    }
}

/// `.lock().expect(…)` and friends: the receiver's callee is a
/// mutex/condvar acquisition whose Err arm *is* an earlier panic
/// (poisoning). Propagating it does not originate a new failure mode.
fn propagates_poison(toks: &[Tok], dot_idx: usize) -> bool {
    if dot_idx == 0 || !toks[dot_idx - 1].is_punct(')') {
        return false;
    }
    // Walk back over the balanced argument list of the receiver call.
    let mut depth = 0isize;
    let mut j = dot_idx - 1;
    loop {
        if toks[j].is_punct(')') {
            depth += 1;
        } else if toks[j].is_punct('(') {
            depth -= 1;
            if depth == 0 {
                break;
            }
        }
        if j == 0 {
            return false;
        }
        j -= 1;
    }
    j > 0
        && toks[j - 1].kind == TokKind::Ident
        && POISON_SOURCES.contains(&toks[j - 1].text.as_str())
}

fn punct_at(toks: &[Tok], i: usize, ch: char) -> bool {
    toks.get(i).is_some_and(|t| t.is_punct(ch))
}

fn ident_at(toks: &[Tok], i: usize, name: &str) -> bool {
    toks.get(i).is_some_and(|t| t.is_ident(name))
}

// ---------------------------------------------------------------------------
// wire-parity: protocol.rs ⇄ client.py drift
// ---------------------------------------------------------------------------

/// Cross-language drift check. Extracts the wire op names from
/// `WireOp::name` and the error slugs from `WireError::code` in the
/// protocol source, and the `WIRE_OPS` / `ERROR_CODES` registries from
/// the Python client, then requires set equality in both directions.
/// `proto_path` / `client_path` only label the findings.
pub fn wire_parity(
    proto_path: &str,
    proto_src: &str,
    client_path: &str,
    client_src: &str,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let toks = lex(proto_src).toks;
    let ops = fn_body_strings(&toks, "name");
    let errs = fn_body_strings(&toks, "code");
    if ops.is_empty() {
        out.push(Finding::new(
            "wire-parity",
            proto_path,
            1,
            "could not extract any op names from `fn name` — the extraction anchor \
             moved; update analysis/rules.rs"
                .to_string(),
        ));
    }
    if errs.is_empty() {
        out.push(Finding::new(
            "wire-parity",
            proto_path,
            1,
            "could not extract any error slugs from `fn code` — the extraction \
             anchor moved; update analysis/rules.rs"
                .to_string(),
        ));
    }
    for (marker, rust_side) in [("WIRE_OPS", &ops), ("ERROR_CODES", &errs)] {
        check_registry(marker, rust_side, proto_path, client_path, client_src, &mut out);
    }
    out
}

fn check_registry(
    marker: &str,
    rust_side: &[(String, u32)],
    proto_path: &str,
    client_path: &str,
    client_src: &str,
    out: &mut Vec<Finding>,
) {
    let Some((py_set, py_line)) = py_registry(client_src, marker) else {
        out.push(Finding::new(
            "wire-parity",
            client_path,
            1,
            format!("client defines no `{marker} = frozenset({{…}})` registry"),
        ));
        return;
    };
    let rust_set: BTreeSet<&str> = rust_side.iter().map(|(s, _)| s.as_str()).collect();
    for (slug, line) in rust_side {
        if !py_set.contains(slug) {
            out.push(Finding::new(
                "wire-parity",
                proto_path,
                *line,
                format!("`{slug}` is on the Rust wire but missing from {marker} in {client_path}"),
            ));
        }
    }
    for slug in &py_set {
        if !rust_set.contains(slug.as_str()) {
            out.push(Finding::new(
                "wire-parity",
                client_path,
                py_line,
                format!("`{slug}` is in {marker} but the Rust protocol never speaks it"),
            ));
        }
    }
}

/// String literals (with lines) inside the body of `fn <name>`,
/// skipping `#[cfg(test)]` regions.
fn fn_body_strings(toks: &[Tok], name: &str) -> Vec<(String, u32)> {
    for (i, t) in toks.iter().enumerate() {
        if t.in_test || !t.is_ident("fn") || !ident_at(toks, i + 1, name) {
            continue;
        }
        let mut j = i + 2;
        while j < toks.len() && !toks[j].is_punct('{') {
            j += 1;
        }
        if j >= toks.len() {
            return Vec::new();
        }
        let close = match_close(toks, j, '{', '}');
        return toks[j..=close]
            .iter()
            .filter(|t| t.kind == TokKind::Str)
            .map(|t| (t.text.clone(), t.line))
            .collect();
    }
    Vec::new()
}

/// The string members of `MARKER = frozenset({ "…", … })` in Python
/// source, plus the registry's line.
fn py_registry(src: &str, marker: &str) -> Option<(BTreeSet<String>, u32)> {
    let needle = format!("{marker} = frozenset(");
    let idx = src.find(&needle)?;
    let line = (src[..idx].matches('\n').count() + 1) as u32;
    let mut set = BTreeSet::new();
    let mut cur: Option<String> = None;
    for c in src[idx + needle.len()..].chars() {
        match (&mut cur, c) {
            (Some(s), '"') => {
                set.insert(std::mem::take(s));
                cur = None;
            }
            (Some(s), _) => s.push(c),
            (None, '"') => cur = Some(String::new()),
            (None, '}') => break,
            (None, _) => {}
        }
    }
    Some((set, line))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(rel: &str, zone: Zone, src: &str) -> Vec<Finding> {
        scan_tokens(rel, zone, &lex(src).toks)
    }

    #[test]
    fn poison_propagation_is_allowed() {
        let src = "fn f(&self) { let q = self.q.lock().expect(\"lock\"); \
                   let (g, r) = self.cv.wait_timeout_while(q, t, |q| q.is_empty())\
                   .expect(\"wait\"); }";
        assert!(scan("server/batcher.rs", Zone::Core, src).is_empty());
    }

    #[test]
    fn plain_expect_on_server_path_fires() {
        let f = scan("server/engine.rs", Zone::Core, "fn f() { x.expect(\"boom\"); }");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "panic-on-wire");
    }

    #[test]
    fn partial_ord_impl_is_not_a_call_site() {
        let src = "impl PartialOrd for E { fn partial_cmp(&self, o: &Self) -> \
                   Option<Ordering> { Some(self.cmp(o)) } }";
        assert!(scan("lifecycle/timeline.rs", Zone::Core, src).is_empty());
    }

    #[test]
    fn wire_parity_agrees_and_drifts() {
        let proto = r#"
            impl WireOp { pub fn name(&self) -> &'static str { match self {
                WireOp::Submit(_) => "submit", WireOp::Query { .. } => "query",
            } } }
            impl WireError { pub fn code(&self) -> &'static str { match self {
                WireError::BadJson(_) => "bad-json",
            } } }
        "#;
        let client_ok = "WIRE_OPS = frozenset({\"submit\", \"query\"})\n\
                         ERROR_CODES = frozenset({\"bad-json\"})\n";
        assert!(wire_parity("p.rs", proto, "c.py", client_ok).is_empty());
        let client_drift = "WIRE_OPS = frozenset({\"submit\", \"vanished\"})\n\
                            ERROR_CODES = frozenset({\"bad-json\"})\n";
        let f = wire_parity("p.rs", proto, "c.py", client_drift);
        assert_eq!(f.len(), 2, "{f:?}"); // query missing + vanished extra
        assert!(f.iter().all(|x| x.rule == "wire-parity"));
    }
}
