//! Rendering for lint results: the human console listing and the
//! `--json FILE` machine report (same [`crate::util::json::Json`]
//! envelope the bench artefacts use — BTreeMap-backed, byte-stable).

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::rules::Finding;

/// One lint run over a tree: surviving findings plus tallies.
#[derive(Debug, Default)]
pub struct Report {
    /// Unwaived findings, sorted by (path, line, rule).
    pub findings: Vec<Finding>,
    /// Files scanned.
    pub files: usize,
    /// Findings waived by a valid `detlint: allow` directive.
    pub waived: usize,
}

impl Report {
    /// No unwaived findings: the gate passes.
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Console listing: one line per finding plus a summary.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "[{}] {}:{} — {}\n",
                f.rule, f.path, f.line, f.msg
            ));
        }
        out.push_str(&format!(
            "detlint: {} finding(s), {} file(s) scanned, {} waived\n",
            self.findings.len(),
            self.files,
            self.waived
        ));
        out
    }

    /// Machine report for `--json FILE`.
    pub fn to_json(&self) -> Json {
        let mut counts: BTreeMap<&str, u64> = BTreeMap::new();
        for f in &self.findings {
            *counts.entry(f.rule).or_insert(0) += 1;
        }
        let mut by_rule = Json::obj();
        for (rule, n) in counts {
            by_rule.set(rule, n);
        }
        let mut doc = Json::obj();
        doc.set("schema", "kube-packd/detlint/v1")
            .set("files_scanned", self.files as u64)
            .set("waived", self.waived as u64)
            .set("clean", self.clean())
            .set("counts", by_rule)
            .set(
                "findings",
                Json::Arr(
                    self.findings
                        .iter()
                        .map(|f| {
                            let mut o = Json::obj();
                            o.set("rule", f.rule)
                                .set("path", f.path.as_str())
                                .set("line", f.line as u64)
                                .set("message", f.msg.as_str());
                            o
                        })
                        .collect(),
                ),
            );
        doc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_report_shape() {
        let rep = Report {
            findings: vec![Finding {
                rule: "wall-clock",
                path: "solver/x.rs".to_string(),
                line: 3,
                msg: "boom".to_string(),
            }],
            files: 2,
            waived: 1,
        };
        let doc = rep.to_json();
        assert_eq!(doc.get("clean").and_then(Json::as_bool), Some(false));
        assert_eq!(doc.get("files_scanned").and_then(Json::as_i64), Some(2));
        let arr = doc.get("findings").and_then(Json::as_arr).unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(
            arr[0].get("rule").and_then(Json::as_str),
            Some("wall-clock")
        );
        let human = rep.render_human();
        assert!(human.contains("[wall-clock] solver/x.rs:3"));
        assert!(human.contains("1 finding(s), 2 file(s) scanned, 1 waived"));
    }
}
