//! Evaluation metrics: placement-vector comparison, utilisation deltas,
//! the paper's five outcome categories, and lifecycle time series.

pub mod categories;
pub mod timeseries;

pub use categories::{lex_better, Outcome};
pub use timeseries::{pending_per_priority, TimeSeries, UtilSample};

/// Mean utilisation improvement between two states, in percentage points
/// (Table 1's Δcpu/Δmem util columns).
pub fn utilization_delta(
    before: (f64, f64),
    after: (f64, f64),
) -> (f64, f64) {
    (
        (after.0 - before.0) * 100.0,
        (after.1 - before.1) * 100.0,
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn delta_in_percentage_points() {
        let d = super::utilization_delta((0.80, 0.75), (0.83, 0.79));
        assert!((d.0 - 3.0).abs() < 1e-9);
        assert!((d.1 - 4.0).abs() < 1e-9);
    }
}
