//! The paper's five outcome categories (Figure 3/4 stacked bars).
//!
//! An allocation A is *better* than B iff A places more higher-priority
//! pods: the per-priority placement vectors (index 0 = highest priority)
//! are compared lexicographically.

/// Outcome of running the optimiser against the default scheduler on one
/// instance. Display names match the paper's legend.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Outcome {
    /// Solver proved optimality and strictly beat the default scheduler.
    BetterOptimal,
    /// Solver improved on the default scheduler but could not prove
    /// optimality before the timeout.
    Better,
    /// Solver proved the default scheduler's placement already optimal.
    KwokOptimal,
    /// Default scheduler placed everything; the solver was never invoked.
    NoCalls,
    /// Solver produced no (improving) solution within the time limit.
    Failure,
}

impl Outcome {
    pub const ALL: [Outcome; 5] = [
        Outcome::BetterOptimal,
        Outcome::Better,
        Outcome::KwokOptimal,
        Outcome::NoCalls,
        Outcome::Failure,
    ];

    /// Paper legend name.
    pub fn label(self) -> &'static str {
        match self {
            Outcome::BetterOptimal => "Better&Optimal",
            Outcome::Better => "Better",
            Outcome::KwokOptimal => "KWOK Optimal",
            Outcome::NoCalls => "No Calls",
            Outcome::Failure => "Failures",
        }
    }
}

/// Lexicographic comparison of placement vectors: `a` beats `b` iff `a`
/// places strictly more pods at the highest priority where they differ.
pub fn lex_better(a: &[usize], b: &[usize]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        if x != y {
            return x > y;
        }
    }
    false
}

/// Classify one instance run (see DESIGN.md for the mapping rules; the
/// only judgement call is feasible-but-not-better, which we conservatively
/// count as Failure — the solver did not deliver an improving solution).
pub fn classify(
    solver_invoked: bool,
    solver_solution: Option<(&[usize], bool)>, // (placement vector, proved optimal)
    kwok_vector: &[usize],
) -> Outcome {
    if !solver_invoked {
        return Outcome::NoCalls;
    }
    match solver_solution {
        None => Outcome::Failure,
        Some((vec, proved)) => {
            if lex_better(vec, kwok_vector) {
                if proved {
                    Outcome::BetterOptimal
                } else {
                    Outcome::Better
                }
            } else if proved {
                // not better and provably can't be: KWOK was optimal
                Outcome::KwokOptimal
            } else {
                Outcome::Failure
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexicographic_priority_comparison() {
        assert!(lex_better(&[3, 0], &[2, 9])); // more high-priority wins
        assert!(!lex_better(&[2, 9], &[3, 0]));
        assert!(lex_better(&[2, 3], &[2, 2]));
        assert!(!lex_better(&[2, 2], &[2, 2])); // equal is not better
    }

    #[test]
    fn classification_matrix() {
        let kwok = vec![2, 2];
        assert_eq!(classify(false, None, &kwok), Outcome::NoCalls);
        assert_eq!(classify(true, None, &kwok), Outcome::Failure);
        assert_eq!(
            classify(true, Some((&[3, 1], true)), &kwok),
            Outcome::BetterOptimal
        );
        assert_eq!(classify(true, Some((&[2, 3], false)), &kwok), Outcome::Better);
        assert_eq!(
            classify(true, Some((&[2, 2], true)), &kwok),
            Outcome::KwokOptimal
        );
        // feasible, no improvement, no proof -> Failure (documented)
        assert_eq!(classify(true, Some((&[2, 2], false)), &kwok), Outcome::Failure);
    }

    #[test]
    fn labels_match_paper_legend() {
        assert_eq!(Outcome::BetterOptimal.label(), "Better&Optimal");
        assert_eq!(Outcome::ALL.len(), 5);
    }
}
