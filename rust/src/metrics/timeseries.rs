//! Time-series samples for lifecycle runs.
//!
//! The paper's metrics are end-state scalars; a churning cluster needs
//! trajectories: utilisation, pending-by-priority, and cumulative
//! evictions sampled at every simulation tick.

use crate::cluster::ClusterState;
use crate::util::json::Json;

/// One sample of the cluster at a virtual timestamp.
#[derive(Clone, Debug)]
pub struct UtilSample {
    pub at_ms: u64,
    /// Mean cpu/ram utilisation over non-removed nodes, in [0, 1].
    pub cpu: f64,
    pub ram: f64,
    /// Pending (schedulable, unbound) pods per priority tier.
    pub pending_per_priority: Vec<usize>,
    /// Placed pods per priority tier.
    pub placed_per_priority: Vec<usize>,
    /// Cumulative evictions since simulation start.
    pub evictions: usize,
}

/// Append-only series ordered by time.
#[derive(Clone, Debug, Default)]
pub struct TimeSeries {
    samples: Vec<UtilSample>,
}

impl TimeSeries {
    pub fn new() -> Self {
        TimeSeries::default()
    }

    pub fn push(&mut self, sample: UtilSample) {
        if let Some(last) = self.samples.last() {
            debug_assert!(sample.at_ms >= last.at_ms, "samples must be time-ordered");
        }
        self.samples.push(sample);
    }

    /// Convenience: sample `state` at its current virtual time.
    pub fn sample(&mut self, state: &ClusterState, p_max: u32) {
        let (cpu, ram) = state.utilization();
        self.push(UtilSample {
            at_ms: state.time_ms(),
            cpu,
            ram,
            pending_per_priority: pending_per_priority(state, p_max),
            placed_per_priority: state.placed_per_priority(p_max),
            evictions: state.events.evictions(),
        });
    }

    pub fn samples(&self) -> &[UtilSample] {
        &self.samples
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn last(&self) -> Option<&UtilSample> {
        self.samples.last()
    }

    /// Time-unweighted mean cpu utilisation across samples.
    pub fn mean_cpu(&self) -> f64 {
        crate::util::stats::mean(&self.samples.iter().map(|s| s.cpu).collect::<Vec<_>>())
    }

    pub fn mean_ram(&self) -> f64 {
        crate::util::stats::mean(&self.samples.iter().map(|s| s.ram).collect::<Vec<_>>())
    }

    /// Largest total pending count seen in any sample.
    pub fn peak_pending(&self) -> usize {
        self.samples
            .iter()
            .map(|s| s.pending_per_priority.iter().sum())
            .max()
            .unwrap_or(0)
    }

    /// Machine-readable dump (one object per sample).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.samples
                .iter()
                .map(|s| {
                    let mut j = Json::obj();
                    j.set("at_ms", s.at_ms)
                        .set("cpu", s.cpu)
                        .set("ram", s.ram)
                        .set("evictions", s.evictions)
                        .set(
                            "pending",
                            Json::Arr(
                                s.pending_per_priority
                                    .iter()
                                    .map(|&p| Json::Num(p as f64))
                                    .collect(),
                            ),
                        )
                        .set(
                            "placed",
                            Json::Arr(
                                s.placed_per_priority
                                    .iter()
                                    .map(|&p| Json::Num(p as f64))
                                    .collect(),
                            ),
                        );
                    j
                })
                .collect(),
        )
    }
}

/// Pending (unbound, unretired) pods per priority tier.
pub fn pending_per_priority(state: &ClusterState, p_max: u32) -> Vec<usize> {
    let mut counts = vec![0usize; p_max as usize + 1];
    for pod in state.pending_pods() {
        counts[state.pod(pod).priority.0 as usize] += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, NodeId, Pod, PodId, Priority, Resources};

    fn state() -> ClusterState {
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "hi", Resources::new(500, 500), Priority(0)),
            Pod::new(1, "lo", Resources::new(500, 500), Priority(1)),
        ];
        ClusterState::new(nodes, pods)
    }

    #[test]
    fn sampling_tracks_cluster_evolution() {
        let mut st = state();
        let mut ts = TimeSeries::new();
        ts.sample(&st, 1);
        st.set_time(100);
        st.bind(PodId(0), NodeId(0)).unwrap();
        ts.sample(&st, 1);
        assert_eq!(ts.len(), 2);
        assert_eq!(ts.samples()[0].pending_per_priority, vec![1, 1]);
        assert_eq!(ts.samples()[1].pending_per_priority, vec![0, 1]);
        assert_eq!(ts.samples()[1].placed_per_priority, vec![1, 0]);
        assert_eq!(ts.samples()[1].at_ms, 100);
        assert!(ts.samples()[1].cpu > ts.samples()[0].cpu);
        assert_eq!(ts.peak_pending(), 2);
    }

    #[test]
    fn pending_counts_exclude_retired() {
        let mut st = state();
        st.terminate(PodId(1)).unwrap();
        assert_eq!(pending_per_priority(&st, 1), vec![1, 0]);
    }

    #[test]
    fn json_dump_has_one_entry_per_sample() {
        let mut ts = TimeSeries::new();
        let st = state();
        ts.sample(&st, 1);
        ts.sample(&st, 1);
        let j = ts.to_json();
        assert_eq!(j.as_arr().map(|a| a.len()), Some(2));
    }
}
