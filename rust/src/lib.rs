//! # kube-packd
//!
//! Reproduction of *"Priority Matters: Optimising Kubernetes Clusters
//! Usage with Constraint-Based Pod Packing"* (Christensen, Giallorenzo,
//! Mauro — 2025) as a three-layer Rust + JAX + Pallas system.
//!
//! The library layers, bottom up:
//!
//! * [`util`]      — offline-environment substrates (PRNG, JSON, CLI,
//!                   timers, stats, property testing, bench harness).
//! * [`cluster`]   — Kubernetes object model: nodes, pods, ReplicaSets,
//!                   allocation state, event log.
//! * [`scheduler`] — kube-scheduler re-implementation: scheduling
//!                   framework with extension points, queue, default
//!                   plugins (NodeResourcesFit, LeastAllocated,
//!                   lexicographic tie-break).
//! * [`simulator`] — KWOK-like deterministic cluster simulator
//!                   (single queue-drain pass).
//! * [`lifecycle`] — discrete-event lifecycle simulator: virtual clock,
//!                   ordered event timeline (arrivals, completions,
//!                   scale-ups/downs, node drain/join), churn policies,
//!                   and periodic CP defragmentation sweeps under an
//!                   eviction budget.
//! * [`solver`]    — from-scratch CP solver (CP-SAT substitute): binary
//!                   variables, linear constraints, branch-and-bound with
//!                   propagation, fractional bounds, hints, timeouts.
//! * [`portfolio`] — parallel portfolio layer between the optimiser and
//!                   the solver core: constraint-graph decomposition
//!                   into independent components plus a deterministic
//!                   multi-threaded strategy race per component.
//! * [`optimizer`] — the paper's contribution: Algorithm 1 per-priority
//!                   optimisation loop + fallback scheduler plugin with
//!                   cross-node pre-emption planning.
//! * [`autoscaler`]— CP-driven cluster autoscaler: certificate-guided
//!                   min-cost scale-up from configurable node pools plus
//!                   consolidation scale-down with provably-drainable
//!                   nodes — the first subsystem that changes the *node*
//!                   side of the instance.
//! * [`telemetry`] — structured observability: RAII spans, solver
//!                   counters, structured events, and byte-stable
//!                   Chrome-trace / Prometheus exporters; also the
//!                   crate's single monotonic clock (deadlines, the α
//!                   time budget, stopwatches). Zero overhead when off,
//!                   determinism-preserving when on.
//! * [`runtime`]   — PJRT (XLA) execution of the AOT-compiled L1/L2
//!                   batch scorer, with a bit-exact native fallback.
//! * [`workload`]  — the paper's random workload generator, dataset
//!                   (de)serialization, and seeded churn-trace generation.
//! * [`metrics`]   — utilisation metrics, the paper's five outcome
//!                   categories, and lifecycle time series.
//! * [`harness`]   — experiment drivers regenerating Figure 3, Figure 4,
//!                   Table 1, and the churn policy-comparison report.
//! * [`server`]    — scheduler-as-a-service: the `serve` daemon (batched
//!                   admission windows over newline-JSON TCP, seq-ordered
//!                   deterministic replies, graceful drain) and its
//!                   closed-loop load generator (`serve-bench`).
//! * [`analysis`]  — detlint, the self-hosted determinism-boundary
//!                   static pass: a token-level lexer, the zone
//!                   manifest, rule set, and the Rust ⇄ Python
//!                   wire-parity drift check, behind `kube-packd lint`.

pub mod analysis;
pub mod autoscaler;
pub mod cluster;
pub mod harness;
pub mod lifecycle;
pub mod metrics;
pub mod optimizer;
pub mod portfolio;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod simulator;
pub mod solver;
pub mod telemetry;
pub mod util;
pub mod workload;
