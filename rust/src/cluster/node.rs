//! Cluster nodes.

use super::constraints::Taint;
use super::resources::Resources;

/// Dense node index. Nodes are kept sorted by `name`, so `NodeId` order is
/// exactly lexicographic name order — the paper's deterministic
/// tie-breaking plugin falls out of that invariant.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl NodeId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// A worker node. The paper assumes identical capacities across nodes
/// ("to reflect typical cloud deployments"), but nothing here requires it.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub capacity: Resources,
    /// Optional labels for (anti-)affinity extensions (paper future work).
    pub labels: Vec<(String, String)>,
    /// Taints (`NoSchedule`): untolerated pods take no new placements here.
    pub taints: Vec<Taint>,
    /// Extended (named) resource capacities, e.g. `[("gpu", 4)]`.
    pub extended: Vec<(String, i64)>,
}

impl Node {
    pub fn new(id: u32, name: impl Into<String>, capacity: Resources) -> Self {
        Node {
            id: NodeId(id),
            name: name.into(),
            capacity,
            labels: Vec::new(),
            taints: Vec::new(),
            extended: Vec::new(),
        }
    }

    pub fn with_label(mut self, key: &str, value: &str) -> Self {
        self.labels.push((key.to_string(), value.to_string()));
        self
    }

    pub fn with_taint(mut self, taint: Taint) -> Self {
        self.taints.push(taint);
        self
    }

    pub fn with_extended(mut self, resource: &str, amount: i64) -> Self {
        assert!(amount > 0, "extended capacity must be positive: {resource}={amount}");
        self.extended.push((resource.to_string(), amount));
        self
    }

    pub fn has_label(&self, key: &str, value: &str) -> bool {
        self.labels.iter().any(|(k, v)| k == key && v == value)
    }

    /// Capacity of an extended resource (0 if the node does not offer it).
    pub fn extended_capacity(&self, resource: &str) -> i64 {
        self.extended
            .iter()
            .filter(|(k, _)| k == resource)
            .map(|&(_, v)| v)
            .sum()
    }
}

/// Build `count` identical nodes named `node-000`, `node-001`, … —
/// zero-padded so lexicographic order equals index order.
pub fn identical_nodes(count: usize, capacity: Resources) -> Vec<Node> {
    (0..count)
        .map(|i| Node::new(i as u32, format!("node-{i:03}"), capacity))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_nodes_sorted_by_name() {
        let nodes = identical_nodes(12, Resources::new(1000, 1000));
        for w in nodes.windows(2) {
            assert!(w[0].name < w[1].name);
            assert!(w[0].id < w[1].id);
        }
        assert_eq!(nodes[10].name, "node-010");
    }

    #[test]
    fn labels() {
        let n = Node::new(0, "n", Resources::ZERO).with_label("disk", "ssd");
        assert!(n.has_label("disk", "ssd"));
        assert!(!n.has_label("disk", "hdd"));
    }
}
