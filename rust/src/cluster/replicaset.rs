//! ReplicaSets: a request to deploy `replicas` identical pods.
//!
//! The paper's workload generator creates random ReplicaSet requests of
//! 1–4 replicas each; pods inherit the template's resource request and
//! priority — and, for constraint-rich scenario families, the template's
//! labels, tolerations, anti-affinity, topology spread, and extended
//! resource requests.

use super::constraints::Toleration;
use super::pod::{Pod, Priority};
use super::resources::Resources;

#[derive(Clone, Debug)]
pub struct ReplicaSet {
    pub id: u32,
    pub name: String,
    pub replicas: u32,
    pub template_request: Resources,
    pub priority: Priority,
    /// Template labels stamped onto every replica.
    pub labels: Vec<(String, String)>,
    /// Template tolerations stamped onto every replica.
    pub tolerations: Vec<Toleration>,
    /// Template anti-affinity selectors stamped onto every replica
    /// (`[("app", <name>)]` + a matching label = "spread my replicas
    /// across nodes, hard").
    pub anti_affinity: Vec<(String, String)>,
    /// Topology spread: max replica-count skew across nodes.
    pub spread_max_skew: Option<i64>,
    /// Extended resource requests per replica, e.g. `[("gpu", 1)]`.
    pub extended: Vec<(String, i64)>,
}

impl ReplicaSet {
    pub fn new(
        id: u32,
        name: impl Into<String>,
        replicas: u32,
        template_request: Resources,
        priority: Priority,
    ) -> Self {
        ReplicaSet {
            id,
            name: name.into(),
            replicas,
            template_request,
            priority,
            labels: Vec::new(),
            tolerations: Vec::new(),
            anti_affinity: Vec::new(),
            spread_max_skew: None,
            extended: Vec::new(),
        }
    }

    pub fn with_label(mut self, key: &str, value: &str) -> Self {
        self.labels.push((key.to_string(), value.to_string()));
        self
    }

    pub fn with_toleration(mut self, tol: Toleration) -> Self {
        self.tolerations.push(tol);
        self
    }

    pub fn with_anti_affinity(mut self, key: &str, value: &str) -> Self {
        self.anti_affinity.push((key.to_string(), value.to_string()));
        self
    }

    pub fn with_spread(mut self, max_skew: i64) -> Self {
        self.spread_max_skew = Some(max_skew);
        self
    }

    pub fn with_extended(mut self, resource: &str, amount: i64) -> Self {
        assert!(amount > 0, "extended request must be positive: {resource}={amount}");
        self.extended.push((resource.to_string(), amount));
        self
    }

    /// Materialise one replica from the template: the single place the
    /// template fields (request, priority, owner, and the whole
    /// constraint vocabulary) are stamped onto a pod. Names follow the
    /// `<rs>-<ordinal>` convention.
    pub fn instantiate(&self, id: u32, ordinal: u32) -> Pod {
        let mut pod = Pod::new(
            id,
            format!("{}-{ordinal}", self.name),
            self.template_request,
            self.priority,
        )
        .with_owner(self.id);
        pod.labels = self.labels.clone();
        pod.tolerations = self.tolerations.clone();
        pod.anti_affinity = self.anti_affinity.clone();
        pod.spread_max_skew = self.spread_max_skew;
        pod.extended = self.extended.clone();
        pod
    }

    /// Expand into pods, continuing the given dense id counter.
    pub fn expand(&self, next_pod_id: &mut u32) -> Vec<Pod> {
        (0..self.replicas)
            .map(|i| {
                let id = *next_pod_id;
                *next_pod_id += 1;
                self.instantiate(id, i)
            })
            .collect()
    }

    /// Total resources this ReplicaSet demands.
    pub fn total_request(&self) -> Resources {
        self.template_request.scaled(self.replicas as i64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expansion() {
        let rs = ReplicaSet::new(3, "web", 3, Resources::new(200, 300), Priority(1));
        let mut next = 10;
        let pods = rs.expand(&mut next);
        assert_eq!(next, 13);
        assert_eq!(pods.len(), 3);
        assert_eq!(pods[0].name, "web-0");
        assert_eq!(pods[2].name, "web-2");
        for p in &pods {
            assert_eq!(p.request, Resources::new(200, 300));
            assert_eq!(p.priority, Priority(1));
            assert_eq!(p.owner, Some(3));
        }
    }

    #[test]
    fn total_request() {
        let rs = ReplicaSet::new(0, "db", 4, Resources::new(100, 250), Priority(0));
        assert_eq!(rs.total_request(), Resources::new(400, 1000));
    }

    #[test]
    fn constraint_template_inherited_by_replicas() {
        let rs = ReplicaSet::new(1, "api", 2, Resources::new(100, 100), Priority(0))
            .with_label("app", "api")
            .with_anti_affinity("app", "api")
            .with_toleration(Toleration::equal("dedicated", "batch"))
            .with_spread(1)
            .with_extended("gpu", 1);
        let mut next = 0;
        let pods = rs.expand(&mut next);
        for p in &pods {
            assert!(p.has_label("app", "api"));
            assert_eq!(p.anti_affinity, vec![("app".to_string(), "api".to_string())]);
            assert_eq!(p.tolerations.len(), 1);
            assert_eq!(p.spread_max_skew, Some(1));
            assert_eq!(p.extended, vec![("gpu".to_string(), 1)]);
        }
        // replicas of one set exclude each other, in both directions
        assert!(pods[0].anti_affine_with(&pods[1]));
        assert!(pods[1].anti_affine_with(&pods[0]));
    }
}
