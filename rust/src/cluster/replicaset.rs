//! ReplicaSets: a request to deploy `replicas` identical pods.
//!
//! The paper's workload generator creates random ReplicaSet requests of
//! 1–4 replicas each; pods inherit the template's resource request and
//! priority.

use super::pod::{Pod, Priority};
use super::resources::Resources;

#[derive(Clone, Debug)]
pub struct ReplicaSet {
    pub id: u32,
    pub name: String,
    pub replicas: u32,
    pub template_request: Resources,
    pub priority: Priority,
}

impl ReplicaSet {
    pub fn new(
        id: u32,
        name: impl Into<String>,
        replicas: u32,
        template_request: Resources,
        priority: Priority,
    ) -> Self {
        ReplicaSet {
            id,
            name: name.into(),
            replicas,
            template_request,
            priority,
        }
    }

    /// Expand into pods, continuing the given dense id counter. Pod names
    /// follow the `<rs>-<ordinal>` convention.
    pub fn expand(&self, next_pod_id: &mut u32) -> Vec<Pod> {
        (0..self.replicas)
            .map(|i| {
                let id = *next_pod_id;
                *next_pod_id += 1;
                Pod::new(
                    id,
                    format!("{}-{i}", self.name),
                    self.template_request,
                    self.priority,
                )
                .with_owner(self.id)
            })
            .collect()
    }

    /// Total resources this ReplicaSet demands.
    pub fn total_request(&self) -> Resources {
        self.template_request.scaled(self.replicas as i64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expansion() {
        let rs = ReplicaSet::new(3, "web", 3, Resources::new(200, 300), Priority(1));
        let mut next = 10;
        let pods = rs.expand(&mut next);
        assert_eq!(next, 13);
        assert_eq!(pods.len(), 3);
        assert_eq!(pods[0].name, "web-0");
        assert_eq!(pods[2].name, "web-2");
        for p in &pods {
            assert_eq!(p.request, Resources::new(200, 300));
            assert_eq!(p.priority, Priority(1));
            assert_eq!(p.owner, Some(3));
        }
    }

    #[test]
    fn total_request() {
        let rs = ReplicaSet::new(0, "db", 4, Resources::new(100, 250), Priority(0));
        assert_eq!(rs.total_request(), Resources::new(400, 1000));
    }
}
