//! Scheduling-constraint vocabulary shared by pods and nodes.
//!
//! The paper defers "labels and (anti-)affinity" to future work; this
//! module supplies the data types that extension uses — taints and
//! tolerations with `NoSchedule` semantics — mirroring the Kubernetes
//! API shapes closely enough that the scheduler filter plugins and the
//! CP constraint modules (`optimizer::constraints`) can share one
//! definition of feasibility.

/// Effect of a taint. Only `NoSchedule` exists in this model: a node
/// with an untolerated `NoSchedule` taint accepts no *new* placements,
/// but pods already resident stay put (the descheduler semantics the
/// optimiser already applies to cordoned nodes). `NoExecute` (evict
/// residents) would be a lifecycle concern, not a packing one.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum TaintEffect {
    #[default]
    NoSchedule,
}

/// A node taint: `key=value:effect`.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Taint {
    pub key: String,
    pub value: String,
    pub effect: TaintEffect,
}

impl Taint {
    pub fn no_schedule(key: impl Into<String>, value: impl Into<String>) -> Self {
        Taint {
            key: key.into(),
            value: value.into(),
            effect: TaintEffect::NoSchedule,
        }
    }
}

/// A pod toleration. `value = None` tolerates every taint with the key
/// (the Kubernetes `Exists` operator); `Some(v)` requires an exact value
/// match (`Equal`).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Toleration {
    pub key: String,
    pub value: Option<String>,
}

impl Toleration {
    /// `Equal`-operator toleration: key and value must both match.
    pub fn equal(key: impl Into<String>, value: impl Into<String>) -> Self {
        Toleration {
            key: key.into(),
            value: Some(value.into()),
        }
    }

    /// `Exists`-operator toleration: any taint with this key is tolerated.
    pub fn exists(key: impl Into<String>) -> Self {
        Toleration {
            key: key.into(),
            value: None,
        }
    }

    /// Whether this toleration covers `taint`.
    pub fn tolerates(&self, taint: &Taint) -> bool {
        self.key == taint.key
            && match &self.value {
                None => true,
                Some(v) => *v == taint.value,
            }
    }
}

/// Whether a pod carrying `tolerations` may be *newly placed* on a node
/// carrying `taints`: every `NoSchedule` taint must be tolerated.
pub fn tolerates_all(tolerations: &[Toleration], taints: &[Taint]) -> bool {
    taints.iter().all(|t| match t.effect {
        TaintEffect::NoSchedule => tolerations.iter().any(|tol| tol.tolerates(t)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_toleration_matches_key_and_value() {
        let t = Taint::no_schedule("dedicated", "batch");
        assert!(Toleration::equal("dedicated", "batch").tolerates(&t));
        assert!(!Toleration::equal("dedicated", "infra").tolerates(&t));
        assert!(!Toleration::equal("team", "batch").tolerates(&t));
    }

    #[test]
    fn exists_toleration_matches_any_value() {
        let t = Taint::no_schedule("dedicated", "batch");
        assert!(Toleration::exists("dedicated").tolerates(&t));
        assert!(!Toleration::exists("team").tolerates(&t));
    }

    #[test]
    fn tolerates_all_requires_every_taint_covered() {
        let taints = vec![
            Taint::no_schedule("dedicated", "batch"),
            Taint::no_schedule("zone", "edge"),
        ];
        assert!(!tolerates_all(&[], &taints));
        assert!(!tolerates_all(
            &[Toleration::equal("dedicated", "batch")],
            &taints
        ));
        assert!(tolerates_all(
            &[
                Toleration::equal("dedicated", "batch"),
                Toleration::exists("zone")
            ],
            &taints
        ));
        // no taints: everything schedules
        assert!(tolerates_all(&[], &[]));
    }
}
