//! Pods: the smallest deployable unit.

use super::constraints::{tolerates_all, Toleration};
use super::resources::Resources;

/// Dense pod index within an instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PodId(pub u32);

impl PodId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Pod priority. Follows the paper's convention: `0` is the *highest*
/// priority and `p_max` the lowest (note this is inverted w.r.t. the
/// Kubernetes API's PriorityClass values; the paper's algorithm iterates
/// `pr = 0..=p_max` from highest to lowest, which this ordering makes a
/// plain ascending loop).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Priority(pub u32);

impl Priority {
    pub const HIGHEST: Priority = Priority(0);
}

/// A pod with its resource request, priority, and (optional) owning
/// ReplicaSet, plus the constraint vocabulary of the paper's future-work
/// extension: node selectors, labels, tolerations, pod anti-affinity,
/// per-ReplicaSet topology spread, and extended (named) resources. All
/// constraint fields default to empty, which makes every paper workload
/// behave exactly as before.
#[derive(Clone, Debug)]
pub struct Pod {
    pub id: PodId,
    pub name: String,
    pub request: Resources,
    pub priority: Priority,
    /// Owning ReplicaSet index, if created through one. Also the
    /// topology-spread group key.
    pub owner: Option<u32>,
    /// Required node labels (AND semantics), e.g. `[("disk","ssd")]`.
    pub node_selector: Vec<(String, String)>,
    /// Pod labels — the match targets of other pods' anti-affinity.
    pub labels: Vec<(String, String)>,
    /// Tolerations against node taints (`NoSchedule` semantics).
    pub tolerations: Vec<Toleration>,
    /// Anti-affinity selectors (OR semantics): this pod refuses to share
    /// a node with any *other* pod carrying one of these labels.
    pub anti_affinity: Vec<(String, String)>,
    /// Max skew of this pod's owner group across nodes (topology spread
    /// over the node topology). `None` = unconstrained.
    pub spread_max_skew: Option<i64>,
    /// Extended (named) resource requests, e.g. `[("gpu", 1)]` —
    /// third/fourth resource dimensions beyond CPU and RAM.
    pub extended: Vec<(String, i64)>,
}

impl Pod {
    pub fn new(id: u32, name: impl Into<String>, request: Resources, priority: Priority) -> Self {
        Pod {
            id: PodId(id),
            name: name.into(),
            request,
            priority,
            owner: None,
            node_selector: Vec::new(),
            labels: Vec::new(),
            tolerations: Vec::new(),
            anti_affinity: Vec::new(),
            spread_max_skew: None,
            extended: Vec::new(),
        }
    }

    pub fn with_owner(mut self, rs: u32) -> Self {
        self.owner = Some(rs);
        self
    }

    pub fn with_selector(mut self, key: &str, value: &str) -> Self {
        self.node_selector.push((key.to_string(), value.to_string()));
        self
    }

    pub fn with_label(mut self, key: &str, value: &str) -> Self {
        self.labels.push((key.to_string(), value.to_string()));
        self
    }

    pub fn with_toleration(mut self, tol: Toleration) -> Self {
        self.tolerations.push(tol);
        self
    }

    /// Refuse to share a node with any other pod labelled `key=value`.
    pub fn with_anti_affinity(mut self, key: &str, value: &str) -> Self {
        self.anti_affinity.push((key.to_string(), value.to_string()));
        self
    }

    pub fn with_spread(mut self, max_skew: i64) -> Self {
        self.spread_max_skew = Some(max_skew);
        self
    }

    pub fn with_extended(mut self, resource: &str, amount: i64) -> Self {
        assert!(amount > 0, "extended request must be positive: {resource}={amount}");
        self.extended.push((resource.to_string(), amount));
        self
    }

    /// Whether this pod's node selector admits `node`.
    pub fn selector_matches(&self, node: &super::node::Node) -> bool {
        self.node_selector
            .iter()
            .all(|(k, v)| node.has_label(k, v))
    }

    /// Whether this pod may be *newly placed* on `node` given its taints.
    pub fn tolerates(&self, node: &super::node::Node) -> bool {
        tolerates_all(&self.tolerations, &node.taints)
    }

    /// Whether this pod carries the label `key=value`.
    pub fn has_label(&self, key: &str, value: &str) -> bool {
        self.labels.iter().any(|(k, v)| k == key && v == value)
    }

    /// Whether this pod's anti-affinity forbids co-location with `other`
    /// (directional; the scheduler and the CP module both check both
    /// directions, matching the Kubernetes InterPodAffinity filter).
    pub fn anti_affine_with(&self, other: &Pod) -> bool {
        self.id != other.id && self.anti_affinity.iter().any(|(k, v)| other.has_label(k, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::constraints::Taint;
    use crate::cluster::node::Node;

    #[test]
    fn priority_zero_is_highest() {
        assert!(Priority(0) < Priority(1));
        assert_eq!(Priority::HIGHEST, Priority(0));
    }

    #[test]
    fn selector_semantics() {
        let pod = Pod::new(0, "p", Resources::ZERO, Priority(0)).with_selector("disk", "ssd");
        let ssd = Node::new(0, "a", Resources::ZERO).with_label("disk", "ssd");
        let hdd = Node::new(1, "b", Resources::ZERO);
        assert!(pod.selector_matches(&ssd));
        assert!(!pod.selector_matches(&hdd));
        // empty selector matches everything
        let any = Pod::new(1, "q", Resources::ZERO, Priority(0));
        assert!(any.selector_matches(&hdd));
    }

    #[test]
    fn toleration_semantics() {
        let tainted =
            Node::new(0, "a", Resources::ZERO).with_taint(Taint::no_schedule("dedicated", "batch"));
        let clean = Node::new(1, "b", Resources::ZERO);
        let plain = Pod::new(0, "p", Resources::ZERO, Priority(0));
        assert!(!plain.tolerates(&tainted));
        assert!(plain.tolerates(&clean));
        let tolerant = Pod::new(1, "q", Resources::ZERO, Priority(0))
            .with_toleration(Toleration::equal("dedicated", "batch"));
        assert!(tolerant.tolerates(&tainted));
    }

    #[test]
    fn anti_affinity_is_directional_and_never_self() {
        let a = Pod::new(0, "a", Resources::ZERO, Priority(0))
            .with_label("app", "web")
            .with_anti_affinity("app", "web");
        let b = Pod::new(1, "b", Resources::ZERO, Priority(0)).with_label("app", "web");
        let c = Pod::new(2, "c", Resources::ZERO, Priority(0)).with_label("app", "db");
        assert!(a.anti_affine_with(&b));
        assert!(!b.anti_affine_with(&a)); // b declares nothing
        assert!(!a.anti_affine_with(&c));
        assert!(!a.anti_affine_with(&a)); // a pod never excludes itself
    }
}
