//! Pods: the smallest deployable unit.

use super::resources::Resources;

/// Dense pod index within an instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PodId(pub u32);

impl PodId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Pod priority. Follows the paper's convention: `0` is the *highest*
/// priority and `p_max` the lowest (note this is inverted w.r.t. the
/// Kubernetes API's PriorityClass values; the paper's algorithm iterates
/// `pr = 0..=p_max` from highest to lowest, which this ordering makes a
/// plain ascending loop).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Priority(pub u32);

impl Priority {
    pub const HIGHEST: Priority = Priority(0);
}

/// A pod with its resource request, priority, and (optional) owning
/// ReplicaSet. `node_selector` supports the paper's future-work
/// affinity extension — empty for all paper workloads.
#[derive(Clone, Debug)]
pub struct Pod {
    pub id: PodId,
    pub name: String,
    pub request: Resources,
    pub priority: Priority,
    /// Owning ReplicaSet index, if created through one.
    pub owner: Option<u32>,
    /// Required node labels (AND semantics), e.g. `[("disk","ssd")]`.
    pub node_selector: Vec<(String, String)>,
}

impl Pod {
    pub fn new(id: u32, name: impl Into<String>, request: Resources, priority: Priority) -> Self {
        Pod {
            id: PodId(id),
            name: name.into(),
            request,
            priority,
            owner: None,
            node_selector: Vec::new(),
        }
    }

    pub fn with_owner(mut self, rs: u32) -> Self {
        self.owner = Some(rs);
        self
    }

    pub fn with_selector(mut self, key: &str, value: &str) -> Self {
        self.node_selector.push((key.to_string(), value.to_string()));
        self
    }

    /// Whether this pod's node selector admits `node`.
    pub fn selector_matches(&self, node: &super::node::Node) -> bool {
        self.node_selector
            .iter()
            .all(|(k, v)| node.has_label(k, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::Node;

    #[test]
    fn priority_zero_is_highest() {
        assert!(Priority(0) < Priority(1));
        assert_eq!(Priority::HIGHEST, Priority(0));
    }

    #[test]
    fn selector_semantics() {
        let pod = Pod::new(0, "p", Resources::ZERO, Priority(0)).with_selector("disk", "ssd");
        let ssd = Node::new(0, "a", Resources::ZERO).with_label("disk", "ssd");
        let hdd = Node::new(1, "b", Resources::ZERO);
        assert!(pod.selector_matches(&ssd));
        assert!(!pod.selector_matches(&hdd));
        // empty selector matches everything
        let any = Pod::new(1, "q", Resources::ZERO, Priority(0));
        assert!(any.selector_matches(&hdd));
    }
}
