//! Cluster model: the Kubernetes objects the scheduler operates on.
//!
//! * [`resources`] — 2-dimensional resource vectors (milli-CPU, MiB RAM).
//! * [`node`]      — cluster nodes with identical-capacity support.
//! * [`pod`]       — pods with resource requests and priorities
//!                   (0 = highest, per the paper's convention).
//! * [`replicaset`]— ReplicaSet requests expanded into pods.
//! * [`state`]     — the mutable allocation state (bindings, residuals)
//!                   with invariant checking.
//! * [`events`]    — append-only event log (bind/evict/move/solver)
//!                   for observability and tests.
//! * [`constraints`] — taints/tolerations and the rest of the shared
//!                   scheduling-constraint vocabulary.

pub mod constraints;
pub mod events;
pub mod node;
pub mod pod;
pub mod replicaset;
pub mod resources;
pub mod state;

pub use constraints::{Taint, TaintEffect, Toleration};
pub use events::{Event, EventLog, EvictCause};
pub use node::{identical_nodes, Node, NodeId};
pub use pod::{Pod, PodId, Priority};
pub use replicaset::ReplicaSet;
pub use resources::Resources;
pub use state::{ClusterState, NodeStatus, StateError};
