//! Two-dimensional resource vectors.
//!
//! Kubernetes expresses CPU in milli-cores ("500m") and memory in bytes;
//! the paper draws both uniformly from `[100, 1000]` abstract units. We
//! keep integer arithmetic throughout (`i64`) — the solver needs exact
//! capacity accounting; floats only appear at the scoring boundary (the
//! L1 kernel contract, f32).

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Sub, SubAssign};

/// A (cpu, ram) request or capacity. Units: milli-CPU and MiB by
/// convention, but the code is unit-agnostic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Resources {
    pub cpu: i64,
    pub ram: i64,
}

impl Resources {
    pub const ZERO: Resources = Resources { cpu: 0, ram: 0 };

    pub fn new(cpu: i64, ram: i64) -> Self {
        Resources { cpu, ram }
    }

    /// Whether a request of `self` fits within `avail` on every dimension.
    #[inline]
    pub fn fits_in(&self, avail: &Resources) -> bool {
        self.cpu <= avail.cpu && self.ram <= avail.ram
    }

    /// Component-wise min / max.
    pub fn min(&self, o: &Resources) -> Resources {
        Resources::new(self.cpu.min(o.cpu), self.ram.min(o.ram))
    }

    pub fn max(&self, o: &Resources) -> Resources {
        Resources::new(self.cpu.max(o.cpu), self.ram.max(o.ram))
    }

    /// True if any dimension is negative (capacity violation marker).
    pub fn any_negative(&self) -> bool {
        self.cpu < 0 || self.ram < 0
    }

    /// Dominant fractional share of `cap` — the solver's branching key
    /// (larger = harder to place).
    pub fn dominant_share(&self, cap: &Resources) -> f64 {
        let c = if cap.cpu > 0 {
            self.cpu as f64 / cap.cpu as f64
        } else {
            f64::INFINITY
        };
        let r = if cap.ram > 0 {
            self.ram as f64 / cap.ram as f64
        } else {
            f64::INFINITY
        };
        c.max(r)
    }

    /// Saturating subtraction (never below zero) — for display only.
    pub fn saturating_sub(&self, o: &Resources) -> Resources {
        Resources::new((self.cpu - o.cpu).max(0), (self.ram - o.ram).max(0))
    }

    /// Scale by an integer factor.
    pub fn scaled(&self, k: i64) -> Resources {
        Resources::new(self.cpu * k, self.ram * k)
    }
}

impl Add for Resources {
    type Output = Resources;
    fn add(self, o: Resources) -> Resources {
        Resources::new(self.cpu + o.cpu, self.ram + o.ram)
    }
}

impl AddAssign for Resources {
    fn add_assign(&mut self, o: Resources) {
        self.cpu += o.cpu;
        self.ram += o.ram;
    }
}

impl Sub for Resources {
    type Output = Resources;
    fn sub(self, o: Resources) -> Resources {
        Resources::new(self.cpu - o.cpu, self.ram - o.ram)
    }
}

impl SubAssign for Resources {
    fn sub_assign(&mut self, o: Resources) {
        self.cpu -= o.cpu;
        self.ram -= o.ram;
    }
}

impl Sum for Resources {
    fn sum<I: Iterator<Item = Resources>>(iter: I) -> Resources {
        iter.fold(Resources::ZERO, |a, b| a + b)
    }
}

impl fmt::Display for Resources {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "cpu={}m ram={}Mi", self.cpu, self.ram)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits() {
        let cap = Resources::new(1000, 2000);
        assert!(Resources::new(1000, 2000).fits_in(&cap));
        assert!(Resources::new(0, 0).fits_in(&cap));
        assert!(!Resources::new(1001, 0).fits_in(&cap));
        assert!(!Resources::new(0, 2001).fits_in(&cap));
    }

    #[test]
    fn arithmetic() {
        let a = Resources::new(100, 200);
        let b = Resources::new(30, 50);
        assert_eq!(a + b, Resources::new(130, 250));
        assert_eq!(a - b, Resources::new(70, 150));
        let mut c = a;
        c += b;
        c -= b;
        assert_eq!(c, a);
    }

    #[test]
    fn sum_over_iter() {
        let total: Resources = [Resources::new(1, 2), Resources::new(3, 4)]
            .into_iter()
            .sum();
        assert_eq!(total, Resources::new(4, 6));
    }

    #[test]
    fn dominant_share_picks_max_dim() {
        let cap = Resources::new(1000, 1000);
        assert_eq!(Resources::new(500, 100).dominant_share(&cap), 0.5);
        assert_eq!(Resources::new(100, 900).dominant_share(&cap), 0.9);
        assert!(Resources::new(1, 1)
            .dominant_share(&Resources::new(0, 10))
            .is_infinite());
    }

    #[test]
    fn negatives_detected() {
        assert!((Resources::new(1, 1) - Resources::new(2, 0)).any_negative());
        assert!(!(Resources::new(1, 1) - Resources::new(1, 1)).any_negative());
    }

    #[test]
    fn scaled_and_saturating() {
        assert_eq!(Resources::new(2, 3).scaled(4), Resources::new(8, 12));
        assert_eq!(
            Resources::new(1, 5).saturating_sub(&Resources::new(3, 2)),
            Resources::new(0, 3)
        );
    }
}
