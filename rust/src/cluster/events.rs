//! Append-only cluster event log.
//!
//! Mirrors (a small slice of) the Kubernetes event stream: every binding,
//! eviction, and optimiser invocation is recorded so tests can assert on
//! *how* a state was reached and examples can narrate what happened.

use super::node::NodeId;
use super::pod::PodId;

/// Who ordered an eviction. Sweep-driven defragmentation moves and
/// fallback pre-emption displacements are different operational costs
/// (a sweep is elective, a pre-emption is forced), so the event log
/// attributes each eviction to its driver instead of conflating them in
/// one counter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictCause {
    /// Cross-node pre-emption on behalf of the optimiser's fallback plan.
    Preemption,
    /// Periodic defragmentation sweep executing a re-pack plan.
    Sweep,
    /// Node drain (cordon + evict residents).
    Drain,
}

impl EvictCause {
    pub fn label(self) -> &'static str {
        match self {
            EvictCause::Preemption => "preemption",
            EvictCause::Sweep => "sweep",
            EvictCause::Drain => "drain",
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// Pod bound to a node by the default scheduler.
    Bind { pod: PodId, node: NodeId },
    /// Pod bound to a node chosen by the optimiser's plan.
    PlanBind { pod: PodId, node: NodeId },
    /// Pod evicted; `cause` attributes the eviction to its driver.
    Evict {
        pod: PodId,
        node: NodeId,
        cause: EvictCause,
    },
    /// Pod marked unschedulable by the scheduling cycle.
    Unschedulable { pod: PodId },
    /// Optimiser invoked over the current cluster state.
    SolverInvoked { pending: usize },
    /// Optimiser finished; `improved` = strictly better than before.
    SolverFinished {
        improved: bool,
        proved_optimal: bool,
        duration_ms: u64,
    },
    /// A queued pod was paused while the solver ran.
    QueuePaused { pod: PodId },
    /// An optimiser plan could not complete: `missing` plan pods were
    /// rejected by a filter plugin after `bound` had already bound. The
    /// run rolls back to ordinary scheduling instead of crashing — the
    /// CP model and the filter set can legitimately disagree when a
    /// custom plugin has no mirroring constraint module (or vice versa).
    PlanAborted { bound: usize, missing: usize },
    /// Pod reached end of life (`node` = where it ran; `None` if it
    /// completed while pending). `at_ms` is virtual lifecycle time.
    PodCompleted {
        pod: PodId,
        node: Option<NodeId>,
        at_ms: u64,
    },
    /// Node marked unschedulable (drain step 1).
    NodeCordoned { node: NodeId, at_ms: u64 },
    /// Node re-admitted to scheduling.
    NodeUncordoned { node: NodeId, at_ms: u64 },
    /// Node drained: cordoned and all its pods evicted.
    NodeDrained {
        node: NodeId,
        evicted: usize,
        at_ms: u64,
    },
    /// Fresh node joined the cluster.
    NodeJoined { node: NodeId, at_ms: u64 },
    /// Empty node removed from the cluster.
    NodeRemoved { node: NodeId, at_ms: u64 },
    /// Periodic defragmentation sweep began.
    SweepStarted { pending: usize, at_ms: u64 },
    /// Sweep finished. `applied` = an improving plan within the eviction
    /// budget was executed (`moves` = pods whose node changed).
    SweepFinished {
        improved: bool,
        applied: bool,
        moves: usize,
        at_ms: u64,
    },
}

/// Growable event log. Cheap to clone for snapshots in tests.
#[derive(Clone, Debug, Default)]
pub struct EventLog {
    events: Vec<Event>,
}

impl EventLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, e: Event) {
        self.events.push(e);
    }

    /// Move every event of `other` onto the end of this log, preserving
    /// order (`other` is left empty). Lets a caller detach a log, run a
    /// trial mutation on a log-free clone, and splice the trial's fresh
    /// events back without ever copying the full history.
    pub fn append(&mut self, other: &mut EventLog) {
        self.events.append(&mut other.events);
    }

    pub fn all(&self) -> &[Event] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Count events matching a predicate.
    pub fn count(&self, pred: impl Fn(&Event) -> bool) -> usize {
        self.events.iter().filter(|e| pred(e)).count()
    }

    /// Number of evictions recorded (disruption metric), all causes.
    pub fn evictions(&self) -> usize {
        self.count(|e| matches!(e, Event::Evict { .. }))
    }

    /// Evictions attributed to one driver (sweep vs pre-emption vs drain).
    pub fn evictions_by(&self, cause: EvictCause) -> usize {
        self.count(|e| matches!(e, Event::Evict { cause: c, .. } if *c == cause))
    }

    /// Number of binds (default + planned).
    pub fn binds(&self) -> usize {
        self.count(|e| matches!(e, Event::Bind { .. } | Event::PlanBind { .. }))
    }

    /// Number of pod completions recorded.
    pub fn completions(&self) -> usize {
        self.count(|e| matches!(e, Event::PodCompleted { .. }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting() {
        let mut log = EventLog::new();
        log.push(Event::Bind {
            pod: PodId(0),
            node: NodeId(0),
        });
        log.push(Event::Evict {
            pod: PodId(0),
            node: NodeId(0),
            cause: EvictCause::Preemption,
        });
        log.push(Event::Evict {
            pod: PodId(1),
            node: NodeId(0),
            cause: EvictCause::Sweep,
        });
        log.push(Event::PlanBind {
            pod: PodId(0),
            node: NodeId(1),
        });
        assert_eq!(log.len(), 4);
        assert_eq!(log.evictions(), 2);
        assert_eq!(log.evictions_by(EvictCause::Preemption), 1);
        assert_eq!(log.evictions_by(EvictCause::Sweep), 1);
        assert_eq!(log.evictions_by(EvictCause::Drain), 0);
        assert_eq!(log.binds(), 2);
    }
}
