//! Mutable cluster allocation state.
//!
//! `ClusterState` is the single source of truth the scheduler, the
//! optimiser, and the metrics all operate on: which pod is bound to which
//! node, and how much free capacity every node retains. All mutations go
//! through `bind` / `evict` so the residual-capacity invariant can never
//! drift (checked in debug builds and by `verify_invariants` in tests).

use super::events::{Event, EventLog};
use super::node::{Node, NodeId};
use super::pod::{Pod, PodId, Priority};
use super::resources::Resources;

/// Errors from state mutations.
#[derive(Clone, Debug, PartialEq)]
pub enum StateError {
    AlreadyBound(PodId),
    NotBound(PodId),
    InsufficientCapacity { pod: PodId, node: NodeId },
    SelectorMismatch { pod: PodId, node: NodeId },
}

impl std::fmt::Display for StateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StateError::AlreadyBound(p) => write!(f, "pod {p:?} already bound"),
            StateError::NotBound(p) => write!(f, "pod {p:?} not bound"),
            StateError::InsufficientCapacity { pod, node } => {
                write!(f, "pod {pod:?} does not fit on node {node:?}")
            }
            StateError::SelectorMismatch { pod, node } => {
                write!(f, "pod {pod:?} selector rejects node {node:?}")
            }
        }
    }
}
impl std::error::Error for StateError {}

/// The cluster's allocation state.
#[derive(Clone, Debug)]
pub struct ClusterState {
    nodes: Vec<Node>,
    pods: Vec<Pod>,
    /// Per-pod binding (`None` = pending/unscheduled).
    assignment: Vec<Option<NodeId>>,
    /// Per-node free capacity (capacity − Σ bound requests).
    free: Vec<Resources>,
    /// Event log of all mutations.
    pub events: EventLog,
}

impl ClusterState {
    /// Build a state with all pods pending. Nodes must arrive sorted by
    /// name (lexicographic NodeId invariant — see [`NodeId`]).
    pub fn new(nodes: Vec<Node>, pods: Vec<Pod>) -> Self {
        for w in nodes.windows(2) {
            assert!(
                w[0].name < w[1].name,
                "nodes must be sorted by name: {:?} !< {:?}",
                w[0].name,
                w[1].name
            );
        }
        for (i, n) in nodes.iter().enumerate() {
            assert_eq!(n.id.idx(), i, "node ids must be dense");
        }
        for (i, p) in pods.iter().enumerate() {
            assert_eq!(p.id.idx(), i, "pod ids must be dense");
        }
        let free = nodes.iter().map(|n| n.capacity).collect();
        let assignment = vec![None; pods.len()];
        ClusterState {
            nodes,
            pods,
            assignment,
            free,
            events: EventLog::new(),
        }
    }

    // ---- accessors -------------------------------------------------------

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn pods(&self) -> &[Pod] {
        &self.pods
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.idx()]
    }

    pub fn pod(&self, id: PodId) -> &Pod {
        &self.pods[id.idx()]
    }

    pub fn assignment_of(&self, pod: PodId) -> Option<NodeId> {
        self.assignment[pod.idx()]
    }

    pub fn assignment(&self) -> &[Option<NodeId>] {
        &self.assignment
    }

    pub fn free(&self, node: NodeId) -> Resources {
        self.free[node.idx()]
    }

    pub fn free_all(&self) -> &[Resources] {
        &self.free
    }

    /// Pods with no binding, in id order.
    pub fn pending_pods(&self) -> Vec<PodId> {
        self.assignment
            .iter()
            .enumerate()
            .filter_map(|(i, a)| a.is_none().then_some(PodId(i as u32)))
            .collect()
    }

    pub fn placed_count(&self) -> usize {
        self.assignment.iter().filter(|a| a.is_some()).count()
    }

    /// Pods bound to `node`, in id order.
    pub fn pods_on(&self, node: NodeId) -> Vec<PodId> {
        self.assignment
            .iter()
            .enumerate()
            .filter_map(|(i, a)| (*a == Some(node)).then_some(PodId(i as u32)))
            .collect()
    }

    // ---- mutations -------------------------------------------------------

    /// Append a pod (e.g. a new arrival); returns its id.
    pub fn add_pod(&mut self, mut pod: Pod) -> PodId {
        let id = PodId(self.pods.len() as u32);
        pod.id = id;
        self.pods.push(pod);
        self.assignment.push(None);
        id
    }

    /// Bind a pending pod to a node, enforcing capacity and selector.
    pub fn bind(&mut self, pod: PodId, node: NodeId) -> Result<(), StateError> {
        if self.assignment[pod.idx()].is_some() {
            return Err(StateError::AlreadyBound(pod));
        }
        let req = self.pods[pod.idx()].request;
        if !self.pods[pod.idx()].selector_matches(&self.nodes[node.idx()]) {
            return Err(StateError::SelectorMismatch { pod, node });
        }
        if !req.fits_in(&self.free[node.idx()]) {
            return Err(StateError::InsufficientCapacity { pod, node });
        }
        self.free[node.idx()] -= req;
        self.assignment[pod.idx()] = Some(node);
        self.events.push(Event::Bind { pod, node });
        debug_assert!(self.check_invariants().is_ok());
        Ok(())
    }

    /// Evict a bound pod (returns the node it was on).
    pub fn evict(&mut self, pod: PodId) -> Result<NodeId, StateError> {
        let node = self.assignment[pod.idx()].ok_or(StateError::NotBound(pod))?;
        self.free[node.idx()] += self.pods[pod.idx()].request;
        self.assignment[pod.idx()] = None;
        self.events.push(Event::Evict { pod, node });
        debug_assert!(self.check_invariants().is_ok());
        Ok(node)
    }

    // ---- metrics ---------------------------------------------------------

    /// Number of placed pods per priority tier, index = priority value.
    /// This is the paper's comparison vector: allocation A beats B iff
    /// A's vector is lexicographically greater (more higher-priority pods
    /// placed first).
    pub fn placed_per_priority(&self, p_max: u32) -> Vec<usize> {
        let mut counts = vec![0usize; p_max as usize + 1];
        for (i, a) in self.assignment.iter().enumerate() {
            if a.is_some() {
                let Priority(p) = self.pods[i].priority;
                counts[p as usize] += 1;
            }
        }
        counts
    }

    /// Mean (cpu, ram) utilisation across nodes, in [0, 1].
    pub fn utilization(&self) -> (f64, f64) {
        if self.nodes.is_empty() {
            return (0.0, 0.0);
        }
        let (mut cpu, mut ram) = (0.0, 0.0);
        for n in &self.nodes {
            let used = n.capacity - self.free[n.id.idx()];
            if n.capacity.cpu > 0 {
                cpu += used.cpu as f64 / n.capacity.cpu as f64;
            }
            if n.capacity.ram > 0 {
                ram += used.ram as f64 / n.capacity.ram as f64;
            }
        }
        let k = self.nodes.len() as f64;
        (cpu / k, ram / k)
    }

    // ---- invariants ------------------------------------------------------

    /// Full recomputation of residuals; `Err` describes the first violation.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut used = vec![Resources::ZERO; self.nodes.len()];
        for (i, a) in self.assignment.iter().enumerate() {
            if let Some(n) = a {
                used[n.idx()] += self.pods[i].request;
            }
        }
        for (j, node) in self.nodes.iter().enumerate() {
            let expect_free = node.capacity - used[j];
            if expect_free != self.free[j] {
                return Err(format!(
                    "node {} residual drift: stored {:?}, recomputed {:?}",
                    node.name, self.free[j], expect_free
                ));
            }
            if expect_free.any_negative() {
                return Err(format!("node {} over capacity: {:?}", node.name, expect_free));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::identical_nodes;

    fn two_node_state() -> ClusterState {
        let nodes = identical_nodes(2, Resources::new(4000, 4096));
        let pods = vec![
            Pod::new(0, "a", Resources::new(2000, 2048), Priority(0)),
            Pod::new(1, "b", Resources::new(2000, 2048), Priority(0)),
            Pod::new(2, "c", Resources::new(3000, 3072), Priority(1)),
        ];
        ClusterState::new(nodes, pods)
    }

    #[test]
    fn bind_and_evict_roundtrip() {
        let mut s = two_node_state();
        s.bind(PodId(0), NodeId(0)).unwrap();
        assert_eq!(s.free(NodeId(0)), Resources::new(2000, 2048));
        assert_eq!(s.assignment_of(PodId(0)), Some(NodeId(0)));
        let node = s.evict(PodId(0)).unwrap();
        assert_eq!(node, NodeId(0));
        assert_eq!(s.free(NodeId(0)), Resources::new(4000, 4096));
        s.check_invariants().unwrap();
    }

    #[test]
    fn capacity_enforced() {
        let mut s = two_node_state();
        s.bind(PodId(0), NodeId(0)).unwrap();
        s.bind(PodId(1), NodeId(0)).unwrap(); // exactly fills node 0
        assert_eq!(
            s.bind(PodId(2), NodeId(0)),
            Err(StateError::InsufficientCapacity {
                pod: PodId(2),
                node: NodeId(0)
            })
        );
        s.bind(PodId(2), NodeId(1)).unwrap();
        s.check_invariants().unwrap();
    }

    #[test]
    fn double_bind_rejected() {
        let mut s = two_node_state();
        s.bind(PodId(0), NodeId(0)).unwrap();
        assert_eq!(s.bind(PodId(0), NodeId(1)), Err(StateError::AlreadyBound(PodId(0))));
    }

    #[test]
    fn evict_unbound_rejected() {
        let mut s = two_node_state();
        assert_eq!(s.evict(PodId(2)), Err(StateError::NotBound(PodId(2))));
    }

    #[test]
    fn placed_per_priority_vector() {
        let mut s = two_node_state();
        s.bind(PodId(0), NodeId(0)).unwrap();
        s.bind(PodId(2), NodeId(1)).unwrap();
        assert_eq!(s.placed_per_priority(1), vec![1, 1]);
        assert_eq!(s.placed_per_priority(3), vec![1, 1, 0, 0]);
    }

    #[test]
    fn utilization_mean_over_nodes() {
        let mut s = two_node_state();
        s.bind(PodId(0), NodeId(0)).unwrap(); // node0: 50% cpu, 50% ram
        let (cpu, ram) = s.utilization();
        assert!((cpu - 0.25).abs() < 1e-9);
        assert!((ram - 0.25).abs() < 1e-9);
    }

    #[test]
    fn pending_and_pods_on() {
        let mut s = two_node_state();
        assert_eq!(s.pending_pods().len(), 3);
        s.bind(PodId(1), NodeId(1)).unwrap();
        assert_eq!(s.pending_pods(), vec![PodId(0), PodId(2)]);
        assert_eq!(s.pods_on(NodeId(1)), vec![PodId(1)]);
        assert_eq!(s.placed_count(), 1);
    }

    #[test]
    fn selector_enforced_on_bind() {
        let nodes = identical_nodes(1, Resources::new(100, 100));
        let pods =
            vec![Pod::new(0, "p", Resources::new(1, 1), Priority(0)).with_selector("gpu", "yes")];
        let mut s = ClusterState::new(nodes, pods);
        assert!(matches!(
            s.bind(PodId(0), NodeId(0)),
            Err(StateError::SelectorMismatch { .. })
        ));
    }

    #[test]
    #[should_panic(expected = "sorted by name")]
    fn unsorted_nodes_rejected() {
        let mut nodes = identical_nodes(2, Resources::ZERO);
        nodes.swap(0, 1);
        // fix dense ids to trigger the name assertion specifically
        nodes[0].id = NodeId(0);
        nodes[1].id = NodeId(1);
        ClusterState::new(nodes, vec![]);
    }
}
