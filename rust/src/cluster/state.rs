//! Mutable cluster allocation state.
//!
//! `ClusterState` is the single source of truth the scheduler, the
//! optimiser, and the metrics all operate on: which pod is bound to which
//! node, and how much free capacity every node retains. All mutations go
//! through `bind` / `evict` so the residual-capacity invariant can never
//! drift (checked in debug builds and by `verify_invariants` in tests).

use std::collections::BTreeMap;

use super::events::{Event, EventLog, EvictCause};
use super::node::{Node, NodeId};
use super::pod::{Pod, PodId, Priority};
use super::resources::Resources;

/// Errors from state mutations.
#[derive(Clone, Debug, PartialEq)]
pub enum StateError {
    AlreadyBound(PodId),
    NotBound(PodId),
    InsufficientCapacity { pod: PodId, node: NodeId },
    /// Not enough of a named extended resource (GPU, ephemeral storage…).
    InsufficientExtended { pod: PodId, node: NodeId, resource: String },
    SelectorMismatch { pod: PodId, node: NodeId },
    /// Node carries a `NoSchedule` taint the pod does not tolerate.
    TaintNotTolerated { pod: PodId, node: NodeId },
    /// Another pod on the node excludes this one (or vice versa).
    AntiAffinityViolation { pod: PodId, other: PodId, node: NodeId },
    /// Pod already completed/terminated; it can never bind again.
    PodRetired(PodId),
    /// Node is cordoned or removed; it accepts no new binds.
    NodeUnschedulable { pod: PodId, node: NodeId },
    /// Node removal requires the node to be empty.
    NodeNotEmpty(NodeId),
}

impl std::fmt::Display for StateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StateError::AlreadyBound(p) => write!(f, "pod {p:?} already bound"),
            StateError::NotBound(p) => write!(f, "pod {p:?} not bound"),
            StateError::InsufficientCapacity { pod, node } => {
                write!(f, "pod {pod:?} does not fit on node {node:?}")
            }
            StateError::InsufficientExtended { pod, node, resource } => {
                write!(f, "pod {pod:?} exceeds {resource:?} capacity on node {node:?}")
            }
            StateError::SelectorMismatch { pod, node } => {
                write!(f, "pod {pod:?} selector rejects node {node:?}")
            }
            StateError::TaintNotTolerated { pod, node } => {
                write!(f, "pod {pod:?} does not tolerate taints of node {node:?}")
            }
            StateError::AntiAffinityViolation { pod, other, node } => {
                write!(f, "pod {pod:?} anti-affine with {other:?} on node {node:?}")
            }
            StateError::PodRetired(p) => write!(f, "pod {p:?} already retired"),
            StateError::NodeUnschedulable { pod, node } => {
                write!(f, "pod {pod:?} cannot bind to unschedulable node {node:?}")
            }
            StateError::NodeNotEmpty(n) => write!(f, "node {n:?} still has bound pods"),
        }
    }
}
impl std::error::Error for StateError {}

/// Node lifecycle status. `Ready` accepts binds; `Cordoned` keeps its
/// running pods but takes no new ones (drain step 1); `Removed` has left
/// the cluster (must be empty first) and is excluded from utilisation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeStatus {
    Ready,
    Cordoned,
    Removed,
}

/// The cluster's allocation state.
#[derive(Clone, Debug)]
pub struct ClusterState {
    nodes: Vec<Node>,
    pods: Vec<Pod>,
    /// Per-pod binding (`None` = pending/unscheduled).
    assignment: Vec<Option<NodeId>>,
    /// Per-node free capacity (capacity − Σ bound requests).
    free: Vec<Resources>,
    /// Per-node free *extended* resource capacity (name → remaining).
    free_ext: Vec<BTreeMap<String, i64>>,
    /// Per-node lifecycle status.
    status: Vec<NodeStatus>,
    /// Per-pod retirement flag (completed/terminated pods never reschedule).
    retired: Vec<bool>,
    /// Virtual lifecycle time stamped onto lifecycle events (ms).
    now_ms: u64,
    /// Event log of all mutations.
    pub events: EventLog,
}

impl ClusterState {
    /// Build a state with all pods pending. Nodes must arrive sorted by
    /// name (lexicographic NodeId invariant — see [`NodeId`]).
    pub fn new(nodes: Vec<Node>, pods: Vec<Pod>) -> Self {
        for w in nodes.windows(2) {
            assert!(
                w[0].name < w[1].name,
                "nodes must be sorted by name: {:?} !< {:?}",
                w[0].name,
                w[1].name
            );
        }
        for (i, n) in nodes.iter().enumerate() {
            assert_eq!(n.id.idx(), i, "node ids must be dense");
        }
        for (i, p) in pods.iter().enumerate() {
            assert_eq!(p.id.idx(), i, "pod ids must be dense");
        }
        let free = nodes.iter().map(|n| n.capacity).collect();
        let free_ext = nodes.iter().map(extended_map).collect();
        let assignment = vec![None; pods.len()];
        let status = vec![NodeStatus::Ready; nodes.len()];
        let retired = vec![false; pods.len()];
        ClusterState {
            nodes,
            pods,
            assignment,
            free,
            free_ext,
            status,
            retired,
            now_ms: 0,
            events: EventLog::new(),
        }
    }

    // ---- accessors -------------------------------------------------------

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn pods(&self) -> &[Pod] {
        &self.pods
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.idx()]
    }

    pub fn pod(&self, id: PodId) -> &Pod {
        &self.pods[id.idx()]
    }

    pub fn assignment_of(&self, pod: PodId) -> Option<NodeId> {
        self.assignment[pod.idx()]
    }

    pub fn assignment(&self) -> &[Option<NodeId>] {
        &self.assignment
    }

    pub fn free(&self, node: NodeId) -> Resources {
        self.free[node.idx()]
    }

    pub fn free_all(&self) -> &[Resources] {
        &self.free
    }

    /// Remaining capacity of a named extended resource on `node` (0 if
    /// the node does not offer it).
    pub fn free_extended(&self, node: NodeId, resource: &str) -> i64 {
        self.free_ext[node.idx()]
            .get(resource)
            .copied()
            .unwrap_or(0)
    }

    /// Whether `pod`'s extended resource requests all fit on `node` now
    /// (duplicate resource names in the request are summed).
    pub fn extended_fits(&self, pod: PodId, node: NodeId) -> bool {
        ext_demand_map(&self.pods[pod.idx()])
            .into_iter()
            .all(|(k, amt)| self.free_extended(node, k) >= amt)
    }

    pub fn node_status(&self, node: NodeId) -> NodeStatus {
        self.status[node.idx()]
    }

    /// Whether `node` currently accepts new binds.
    pub fn node_ready(&self, node: NodeId) -> bool {
        self.status[node.idx()] == NodeStatus::Ready
    }

    /// Whether `pod` completed/terminated (never reschedules).
    pub fn is_retired(&self, pod: PodId) -> bool {
        self.retired[pod.idx()]
    }

    pub fn retired_count(&self) -> usize {
        self.retired.iter().filter(|&&r| r).count()
    }

    /// Virtual lifecycle time, in milliseconds (0 unless a simulator
    /// drives [`ClusterState::set_time`]).
    pub fn time_ms(&self) -> u64 {
        self.now_ms
    }

    /// Advance the virtual clock stamped onto lifecycle events.
    pub fn set_time(&mut self, now_ms: u64) {
        debug_assert!(now_ms >= self.now_ms, "lifecycle time must be monotonic");
        self.now_ms = now_ms;
    }

    /// Pods with no binding that are still schedulable, in id order.
    pub fn pending_pods(&self) -> Vec<PodId> {
        self.assignment
            .iter()
            .enumerate()
            .filter_map(|(i, a)| {
                (a.is_none() && !self.retired[i]).then_some(PodId(i as u32))
            })
            .collect()
    }

    pub fn placed_count(&self) -> usize {
        self.assignment.iter().filter(|a| a.is_some()).count()
    }

    /// Pods bound to `node`, in id order.
    pub fn pods_on(&self, node: NodeId) -> Vec<PodId> {
        self.assignment
            .iter()
            .enumerate()
            .filter_map(|(i, a)| (*a == Some(node)).then_some(PodId(i as u32)))
            .collect()
    }

    // ---- mutations -------------------------------------------------------

    /// Append a pod (e.g. a new arrival); returns its id.
    pub fn add_pod(&mut self, mut pod: Pod) -> PodId {
        let id = PodId(self.pods.len() as u32);
        pod.id = id;
        self.pods.push(pod);
        self.assignment.push(None);
        self.retired.push(false);
        id
    }

    /// Append a node (a join). Keeps the lexicographic-name / dense-id
    /// invariant, so the new name must sort after every existing one.
    pub fn add_node(&mut self, name: impl Into<String>, capacity: Resources) -> NodeId {
        let name = name.into();
        self.push_node(Node::new(self.nodes.len() as u32, name, capacity))
    }

    /// The one node-append path: dense-id assignment, residual/status
    /// bookkeeping, the sorted-name invariant, and the `NodeJoined`
    /// event. `node.id` is overwritten with the next dense id.
    fn push_node(&mut self, mut node: Node) -> NodeId {
        if let Some(last) = self.nodes.last() {
            assert!(
                last.name < node.name,
                "joined node name must sort last: {:?} !< {:?}",
                last.name,
                node.name
            );
        }
        let id = NodeId(self.nodes.len() as u32);
        node.id = id;
        self.free.push(node.capacity);
        self.free_ext.push(extended_map(&node));
        self.status.push(NodeStatus::Ready);
        self.nodes.push(node);
        self.events.push(Event::NodeJoined {
            node: id,
            at_ms: self.now_ms,
        });
        id
    }

    /// Append a node with the canonical `node-NNN` naming scheme used by
    /// [`identical_nodes`](super::node::identical_nodes). Past the
    /// fixed-width ordinal range (1000 joins), names switch to a
    /// `node-z`-prefixed wide ordinal that still sorts after every
    /// canonical name, so long-horizon simulations never trip the
    /// sorted-name invariant.
    pub fn join_node(&mut self, capacity: Resources) -> NodeId {
        let name = self.next_join_name();
        self.add_node(name, capacity)
    }

    /// Next name under the canonical join scheme (see
    /// [`join_node`](ClusterState::join_node)).
    fn next_join_name(&self) -> String {
        let ord = self.nodes.len();
        let mut name = format!("node-{ord:03}");
        if let Some(last) = self.nodes.last() {
            if name <= last.name {
                // "node-1000" < "node-999": the zero-padding ran out.
                // 'z' > any digit, so this sorts after all canonical names.
                name = format!("node-z{ord:09}");
            }
        }
        name
    }

    /// Append a node shaped like `template` — capacity, labels, taints,
    /// and extended capacities — under the canonical join naming scheme.
    /// The template's own id and name are ignored. This is the
    /// autoscaler's scale-up path: provisioned pool nodes (GPU
    /// capacities, dedicated taints, …) join fully decorated, unlike the
    /// plain [`join_node`](ClusterState::join_node).
    pub fn join_node_from(&mut self, template: &Node) -> NodeId {
        let mut node = template.clone();
        node.name = self.next_join_name();
        self.push_node(node)
    }

    /// Bind a pending pod to a node, enforcing capacity (CPU/RAM and
    /// extended resources), selector, tolerations, pairwise
    /// anti-affinity, pod liveness, and node readiness. Topology spread
    /// is deliberately *not* enforced here: a multi-pod plan can pass
    /// through transiently skewed intermediate states on its way to a
    /// balanced target, so spread is a scheduler/optimiser policy, not a
    /// state invariant.
    pub fn bind(&mut self, pod: PodId, node: NodeId) -> Result<(), StateError> {
        if self.retired[pod.idx()] {
            return Err(StateError::PodRetired(pod));
        }
        if self.assignment[pod.idx()].is_some() {
            return Err(StateError::AlreadyBound(pod));
        }
        let req = self.pods[pod.idx()].request;
        if !self.pods[pod.idx()].selector_matches(&self.nodes[node.idx()]) {
            return Err(StateError::SelectorMismatch { pod, node });
        }
        if !self.pods[pod.idx()].tolerates(&self.nodes[node.idx()]) {
            return Err(StateError::TaintNotTolerated { pod, node });
        }
        if self.status[node.idx()] != NodeStatus::Ready {
            return Err(StateError::NodeUnschedulable { pod, node });
        }
        if !req.fits_in(&self.free[node.idx()]) {
            return Err(StateError::InsufficientCapacity { pod, node });
        }
        for (k, amt) in ext_demand_map(&self.pods[pod.idx()]) {
            if self.free_extended(node, k) < amt {
                return Err(StateError::InsufficientExtended {
                    pod,
                    node,
                    resource: k.to_string(),
                });
            }
        }
        for other in self.pods_on(node) {
            let (a, b) = (&self.pods[pod.idx()], &self.pods[other.idx()]);
            if a.anti_affine_with(b) || b.anti_affine_with(a) {
                return Err(StateError::AntiAffinityViolation { pod, other, node });
            }
        }
        self.free[node.idx()] -= req;
        self.charge_extended(pod, node, -1);
        self.assignment[pod.idx()] = Some(node);
        self.events.push(Event::Bind { pod, node });
        debug_assert!(self.check_invariants().is_ok());
        Ok(())
    }

    /// Add (`sign = +1`) or subtract (`sign = -1`) a pod's extended
    /// resource requests from a node's free pool.
    fn charge_extended(&mut self, pod: PodId, node: NodeId, sign: i64) {
        for (k, amt) in &self.pods[pod.idx()].extended {
            *self.free_ext[node.idx()].entry(k.clone()).or_insert(0) += sign * amt;
        }
    }

    /// Evict a bound pod as optimiser pre-emption (the historical
    /// default cause); returns the node it was on. Use [`evict_as`] when
    /// a different driver (sweep, drain) orders the eviction so the
    /// event log attributes it correctly.
    ///
    /// [`evict_as`]: ClusterState::evict_as
    pub fn evict(&mut self, pod: PodId) -> Result<NodeId, StateError> {
        self.evict_as(pod, EvictCause::Preemption)
    }

    /// [`evict`](ClusterState::evict) with an explicit attribution.
    pub fn evict_as(&mut self, pod: PodId, cause: EvictCause) -> Result<NodeId, StateError> {
        let node = self.assignment[pod.idx()].ok_or(StateError::NotBound(pod))?;
        self.free[node.idx()] += self.pods[pod.idx()].request;
        self.charge_extended(pod, node, 1);
        self.assignment[pod.idx()] = None;
        self.events.push(Event::Evict { pod, node, cause });
        debug_assert!(self.check_invariants().is_ok());
        Ok(node)
    }

    /// Terminate a pod: frees its capacity (if bound) and retires it so
    /// it never re-enters scheduling. Returns where it ran.
    pub fn terminate(&mut self, pod: PodId) -> Result<Option<NodeId>, StateError> {
        if self.retired[pod.idx()] {
            return Err(StateError::PodRetired(pod));
        }
        let node = self.assignment[pod.idx()];
        if let Some(n) = node {
            self.free[n.idx()] += self.pods[pod.idx()].request;
            self.charge_extended(pod, n, 1);
            self.assignment[pod.idx()] = None;
        }
        self.retired[pod.idx()] = true;
        self.events.push(Event::PodCompleted {
            pod,
            node,
            at_ms: self.now_ms,
        });
        debug_assert!(self.check_invariants().is_ok());
        Ok(node)
    }

    /// Mark a node unschedulable. Returns `false` if it was not Ready.
    pub fn cordon(&mut self, node: NodeId) -> bool {
        if self.status[node.idx()] != NodeStatus::Ready {
            return false;
        }
        self.status[node.idx()] = NodeStatus::Cordoned;
        self.events.push(Event::NodeCordoned {
            node,
            at_ms: self.now_ms,
        });
        true
    }

    /// Re-admit a cordoned node. Returns `false` if it was not Cordoned.
    pub fn uncordon(&mut self, node: NodeId) -> bool {
        if self.status[node.idx()] != NodeStatus::Cordoned {
            return false;
        }
        self.status[node.idx()] = NodeStatus::Ready;
        self.events.push(Event::NodeUncordoned {
            node,
            at_ms: self.now_ms,
        });
        true
    }

    /// Drain a node: cordon it and evict every pod bound to it. The
    /// evicted pods become pending again (they re-enter scheduling);
    /// returns them in id order. A removed node drains to nothing and
    /// records no events.
    pub fn drain(&mut self, node: NodeId) -> Vec<PodId> {
        if self.status[node.idx()] == NodeStatus::Removed {
            return Vec::new();
        }
        if self.status[node.idx()] == NodeStatus::Ready {
            self.cordon(node);
        }
        let victims = self.pods_on(node);
        for &pod in &victims {
            self.evict_as(pod, EvictCause::Drain)
                .expect("pods_on returned an unbound pod");
        }
        self.events.push(Event::NodeDrained {
            node,
            evicted: victims.len(),
            at_ms: self.now_ms,
        });
        victims
    }

    /// Remove an (empty) node from the cluster. The slot stays in the
    /// dense id space but is excluded from scheduling and utilisation.
    /// Idempotent: removing an already-removed node records no second
    /// event.
    pub fn remove_node(&mut self, node: NodeId) -> Result<(), StateError> {
        if self.status[node.idx()] == NodeStatus::Removed {
            return Ok(());
        }
        if !self.pods_on(node).is_empty() {
            return Err(StateError::NodeNotEmpty(node));
        }
        self.status[node.idx()] = NodeStatus::Removed;
        self.events.push(Event::NodeRemoved {
            node,
            at_ms: self.now_ms,
        });
        Ok(())
    }

    // ---- metrics ---------------------------------------------------------

    /// Number of placed pods per priority tier, index = priority value.
    /// This is the paper's comparison vector: allocation A beats B iff
    /// A's vector is lexicographically greater (more higher-priority pods
    /// placed first).
    pub fn placed_per_priority(&self, p_max: u32) -> Vec<usize> {
        let mut counts = vec![0usize; p_max as usize + 1];
        for (i, a) in self.assignment.iter().enumerate() {
            if a.is_some() {
                let Priority(p) = self.pods[i].priority;
                counts[p as usize] += 1;
            }
        }
        counts
    }

    /// Mean (cpu, ram) utilisation across non-removed nodes, in [0, 1].
    pub fn utilization(&self) -> (f64, f64) {
        let (mut cpu, mut ram) = (0.0, 0.0);
        let mut k = 0usize;
        for n in &self.nodes {
            if self.status[n.id.idx()] == NodeStatus::Removed {
                continue;
            }
            k += 1;
            let used = n.capacity - self.free[n.id.idx()];
            if n.capacity.cpu > 0 {
                cpu += used.cpu as f64 / n.capacity.cpu as f64;
            }
            if n.capacity.ram > 0 {
                ram += used.ram as f64 / n.capacity.ram as f64;
            }
        }
        if k == 0 {
            return (0.0, 0.0);
        }
        (cpu / k as f64, ram / k as f64)
    }

    // ---- invariants ------------------------------------------------------

    /// Full recomputation of residuals plus constraint-field violations
    /// (taints on bound pods, pairwise anti-affinity, extended-resource
    /// drift); `Err` describes the first violation. Topology spread is
    /// intentionally not an invariant (see [`ClusterState::bind`]).
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut used = vec![Resources::ZERO; self.nodes.len()];
        let mut used_ext: Vec<BTreeMap<&str, i64>> =
            vec![BTreeMap::new(); self.nodes.len()];
        for (i, a) in self.assignment.iter().enumerate() {
            if let Some(n) = a {
                if self.retired[i] {
                    return Err(format!("retired pod {} still bound", self.pods[i].name));
                }
                used[n.idx()] += self.pods[i].request;
                for (k, amt) in &self.pods[i].extended {
                    *used_ext[n.idx()].entry(k.as_str()).or_insert(0) += amt;
                }
                if !self.pods[i].tolerates(&self.nodes[n.idx()]) {
                    return Err(format!(
                        "pod {} bound to node {} whose taints it does not tolerate",
                        self.pods[i].name,
                        self.nodes[n.idx()].name
                    ));
                }
            }
        }
        for (j, node) in self.nodes.iter().enumerate() {
            let expect_free = node.capacity - used[j];
            if expect_free != self.free[j] {
                return Err(format!(
                    "node {} residual drift: stored {:?}, recomputed {:?}",
                    node.name, self.free[j], expect_free
                ));
            }
            if expect_free.any_negative() {
                return Err(format!("node {} over capacity: {:?}", node.name, expect_free));
            }
            if self.status[j] == NodeStatus::Removed && used[j] != Resources::ZERO {
                return Err(format!("removed node {} still hosts pods", node.name));
            }
            let mut expect_ext = extended_map(node);
            for (k, amt) in &used_ext[j] {
                let slot = expect_ext.entry((*k).to_string()).or_insert(0);
                *slot -= amt;
                if *slot < 0 {
                    return Err(format!("node {} over {k:?} capacity", node.name));
                }
            }
            for (k, v) in &expect_ext {
                if self.free_ext[j].get(k).copied().unwrap_or(0) != *v {
                    return Err(format!(
                        "node {} extended residual drift on {k:?}",
                        node.name
                    ));
                }
            }
            // pairwise anti-affinity among co-located pods
            let on = self.pods_on(NodeId(j as u32));
            for (x, &p) in on.iter().enumerate() {
                for &q in &on[x + 1..] {
                    let (a, b) = (&self.pods[p.idx()], &self.pods[q.idx()]);
                    if a.anti_affine_with(b) || b.anti_affine_with(a) {
                        return Err(format!(
                            "anti-affine pods {} and {} share node {}",
                            a.name, b.name, node.name
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

/// A node's extended capacities as a name → amount map (duplicate names
/// summed).
fn extended_map(node: &Node) -> BTreeMap<String, i64> {
    let mut m = BTreeMap::new();
    for (k, v) in &node.extended {
        *m.entry(k.clone()).or_insert(0) += v;
    }
    m
}

/// A pod's extended requests as a name → amount map (duplicate names
/// summed) — the one definition of "aggregate extended demand" shared by
/// `bind` and `extended_fits`.
fn ext_demand_map(pod: &Pod) -> BTreeMap<&str, i64> {
    let mut m = BTreeMap::new();
    for (k, amt) in &pod.extended {
        *m.entry(k.as_str()).or_insert(0) += amt;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::identical_nodes;

    fn two_node_state() -> ClusterState {
        let nodes = identical_nodes(2, Resources::new(4000, 4096));
        let pods = vec![
            Pod::new(0, "a", Resources::new(2000, 2048), Priority(0)),
            Pod::new(1, "b", Resources::new(2000, 2048), Priority(0)),
            Pod::new(2, "c", Resources::new(3000, 3072), Priority(1)),
        ];
        ClusterState::new(nodes, pods)
    }

    #[test]
    fn bind_and_evict_roundtrip() {
        let mut s = two_node_state();
        s.bind(PodId(0), NodeId(0)).unwrap();
        assert_eq!(s.free(NodeId(0)), Resources::new(2000, 2048));
        assert_eq!(s.assignment_of(PodId(0)), Some(NodeId(0)));
        let node = s.evict(PodId(0)).unwrap();
        assert_eq!(node, NodeId(0));
        assert_eq!(s.free(NodeId(0)), Resources::new(4000, 4096));
        s.check_invariants().unwrap();
    }

    #[test]
    fn capacity_enforced() {
        let mut s = two_node_state();
        s.bind(PodId(0), NodeId(0)).unwrap();
        s.bind(PodId(1), NodeId(0)).unwrap(); // exactly fills node 0
        assert_eq!(
            s.bind(PodId(2), NodeId(0)),
            Err(StateError::InsufficientCapacity {
                pod: PodId(2),
                node: NodeId(0)
            })
        );
        s.bind(PodId(2), NodeId(1)).unwrap();
        s.check_invariants().unwrap();
    }

    #[test]
    fn double_bind_rejected() {
        let mut s = two_node_state();
        s.bind(PodId(0), NodeId(0)).unwrap();
        assert_eq!(s.bind(PodId(0), NodeId(1)), Err(StateError::AlreadyBound(PodId(0))));
    }

    #[test]
    fn evict_unbound_rejected() {
        let mut s = two_node_state();
        assert_eq!(s.evict(PodId(2)), Err(StateError::NotBound(PodId(2))));
    }

    #[test]
    fn placed_per_priority_vector() {
        let mut s = two_node_state();
        s.bind(PodId(0), NodeId(0)).unwrap();
        s.bind(PodId(2), NodeId(1)).unwrap();
        assert_eq!(s.placed_per_priority(1), vec![1, 1]);
        assert_eq!(s.placed_per_priority(3), vec![1, 1, 0, 0]);
    }

    #[test]
    fn utilization_mean_over_nodes() {
        let mut s = two_node_state();
        s.bind(PodId(0), NodeId(0)).unwrap(); // node0: 50% cpu, 50% ram
        let (cpu, ram) = s.utilization();
        assert!((cpu - 0.25).abs() < 1e-9);
        assert!((ram - 0.25).abs() < 1e-9);
    }

    #[test]
    fn pending_and_pods_on() {
        let mut s = two_node_state();
        assert_eq!(s.pending_pods().len(), 3);
        s.bind(PodId(1), NodeId(1)).unwrap();
        assert_eq!(s.pending_pods(), vec![PodId(0), PodId(2)]);
        assert_eq!(s.pods_on(NodeId(1)), vec![PodId(1)]);
        assert_eq!(s.placed_count(), 1);
    }

    #[test]
    fn selector_enforced_on_bind() {
        let nodes = identical_nodes(1, Resources::new(100, 100));
        let pods =
            vec![Pod::new(0, "p", Resources::new(1, 1), Priority(0)).with_selector("gpu", "yes")];
        let mut s = ClusterState::new(nodes, pods);
        assert!(matches!(
            s.bind(PodId(0), NodeId(0)),
            Err(StateError::SelectorMismatch { .. })
        ));
    }

    #[test]
    fn terminate_frees_capacity_and_retires() {
        let mut s = two_node_state();
        s.set_time(1_500);
        s.bind(PodId(0), NodeId(0)).unwrap();
        let node = s.terminate(PodId(0)).unwrap();
        assert_eq!(node, Some(NodeId(0)));
        assert_eq!(s.free(NodeId(0)), Resources::new(4000, 4096));
        assert!(s.is_retired(PodId(0)));
        assert_eq!(s.retired_count(), 1);
        // retired pods are no longer pending and never rebind
        assert!(!s.pending_pods().contains(&PodId(0)));
        assert_eq!(s.bind(PodId(0), NodeId(0)), Err(StateError::PodRetired(PodId(0))));
        assert_eq!(s.terminate(PodId(0)), Err(StateError::PodRetired(PodId(0))));
        // the completion event carries the virtual timestamp
        assert!(s.events.all().iter().any(|e| matches!(
            e,
            Event::PodCompleted { pod: PodId(0), node: Some(NodeId(0)), at_ms: 1_500 }
        )));
        s.check_invariants().unwrap();
    }

    #[test]
    fn terminate_pending_pod_retires_without_node() {
        let mut s = two_node_state();
        assert_eq!(s.terminate(PodId(2)).unwrap(), None);
        assert!(s.is_retired(PodId(2)));
        assert_eq!(s.events.completions(), 1);
    }

    #[test]
    fn cordon_blocks_binds_until_uncordon() {
        let mut s = two_node_state();
        assert!(s.cordon(NodeId(0)));
        assert!(!s.cordon(NodeId(0))); // idempotent-ish: already cordoned
        assert_eq!(s.node_status(NodeId(0)), NodeStatus::Cordoned);
        assert_eq!(
            s.bind(PodId(0), NodeId(0)),
            Err(StateError::NodeUnschedulable { pod: PodId(0), node: NodeId(0) })
        );
        assert!(s.uncordon(NodeId(0)));
        s.bind(PodId(0), NodeId(0)).unwrap();
        s.check_invariants().unwrap();
    }

    #[test]
    fn drain_evicts_everything_and_cordons() {
        let mut s = two_node_state();
        s.bind(PodId(0), NodeId(0)).unwrap();
        s.bind(PodId(1), NodeId(0)).unwrap();
        s.bind(PodId(2), NodeId(1)).unwrap();
        let victims = s.drain(NodeId(0));
        assert_eq!(victims, vec![PodId(0), PodId(1)]);
        assert_eq!(s.events.evictions_by(EvictCause::Drain), 2);
        assert_eq!(s.events.evictions_by(EvictCause::Preemption), 0);
        assert!(!s.node_ready(NodeId(0)));
        assert_eq!(s.free(NodeId(0)), Resources::new(4000, 4096));
        // drained pods are pending again (not retired)
        assert_eq!(s.pending_pods(), vec![PodId(0), PodId(1)]);
        assert!(s.events.all().iter().any(|e| matches!(
            e,
            Event::NodeDrained { node: NodeId(0), evicted: 2, .. }
        )));
        s.check_invariants().unwrap();
    }

    #[test]
    fn remove_node_requires_empty() {
        let mut s = two_node_state();
        s.bind(PodId(0), NodeId(0)).unwrap();
        assert_eq!(s.remove_node(NodeId(0)), Err(StateError::NodeNotEmpty(NodeId(0))));
        s.drain(NodeId(0));
        s.remove_node(NodeId(0)).unwrap();
        assert_eq!(s.node_status(NodeId(0)), NodeStatus::Removed);
        // idempotent: no second NodeRemoved event, no phantom drains
        let events_before = s.events.len();
        s.remove_node(NodeId(0)).unwrap();
        assert_eq!(s.drain(NodeId(0)), Vec::<PodId>::new());
        assert_eq!(s.events.len(), events_before);
        // removed nodes are excluded from the utilisation mean
        s.bind(PodId(0), NodeId(1)).unwrap();
        let (cpu, ram) = s.utilization();
        assert!((cpu - 0.5).abs() < 1e-9, "cpu={cpu}");
        assert!((ram - 0.5).abs() < 1e-9, "ram={ram}");
        s.check_invariants().unwrap();
    }

    #[test]
    fn join_node_extends_cluster() {
        let mut s = two_node_state();
        let id = s.join_node(Resources::new(4000, 4096));
        assert_eq!(id, NodeId(2));
        assert_eq!(s.node(id).name, "node-002");
        assert!(s.node_ready(id));
        s.bind(PodId(2), id).unwrap();
        assert!(s.events.all().iter().any(|e| matches!(e, Event::NodeJoined { node: NodeId(2), .. })));
        s.check_invariants().unwrap();
    }

    #[test]
    fn join_node_from_carries_the_template_decorations() {
        use crate::cluster::constraints::{Taint, Toleration};
        let mut s = two_node_state();
        let template = Node::new(0, "ignored-name", Resources::new(2000, 2000))
            .with_label("tier", "burst")
            .with_taint(Taint::no_schedule("dedicated", "batch"))
            .with_extended("gpu", 4);
        let id = s.join_node_from(&template);
        assert_eq!(id, NodeId(2));
        assert_eq!(s.node(id).name, "node-002", "template name ignored");
        assert_eq!(s.node(id).capacity, Resources::new(2000, 2000));
        assert!(s.node(id).has_label("tier", "burst"));
        assert_eq!(s.free_extended(id, "gpu"), 4);
        assert!(s.node_ready(id));
        // the taint is live: untolerated pods are refused, tolerant bind
        let plain = s.add_pod(Pod::new(0, "plain", Resources::new(1, 1), Priority(0)));
        assert!(matches!(
            s.bind(plain, id),
            Err(StateError::TaintNotTolerated { .. })
        ));
        let tol = s.add_pod(
            Pod::new(0, "tol", Resources::new(1, 1), Priority(0))
                .with_toleration(Toleration::equal("dedicated", "batch")),
        );
        s.bind(tol, id).unwrap();
        assert!(s
            .events
            .all()
            .iter()
            .any(|e| matches!(e, Event::NodeJoined { node: NodeId(2), .. })));
        s.check_invariants().unwrap();
    }

    #[test]
    fn join_survives_the_fixed_width_ordinal_boundary() {
        // 1000 canonical names exhaust the 3-digit padding; the 1001st
        // join must still sort after "node-999" instead of panicking.
        let mut s = ClusterState::new(identical_nodes(1000, Resources::new(10, 10)), vec![]);
        let id = s.join_node(Resources::new(10, 10));
        assert_eq!(id, NodeId(1000));
        assert_eq!(s.node(id).name, "node-z000001000");
        assert!(s.node(id).name > "node-999".to_string());
        // and the scheme keeps working for the join after that
        let id2 = s.join_node(Resources::new(10, 10));
        assert_eq!(s.node(id2).name, "node-z000001001");
    }

    #[test]
    fn taints_enforced_on_bind() {
        use crate::cluster::constraints::{Taint, Toleration};
        let mut nodes = identical_nodes(1, Resources::new(1000, 1000));
        nodes[0] = nodes[0]
            .clone()
            .with_taint(Taint::no_schedule("dedicated", "batch"));
        let pods = vec![
            Pod::new(0, "plain", Resources::new(1, 1), Priority(0)),
            Pod::new(1, "tolerant", Resources::new(1, 1), Priority(0))
                .with_toleration(Toleration::equal("dedicated", "batch")),
        ];
        let mut s = ClusterState::new(nodes, pods);
        assert_eq!(
            s.bind(PodId(0), NodeId(0)),
            Err(StateError::TaintNotTolerated { pod: PodId(0), node: NodeId(0) })
        );
        s.bind(PodId(1), NodeId(0)).unwrap();
        s.check_invariants().unwrap();
    }

    #[test]
    fn anti_affinity_enforced_on_bind() {
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "a", Resources::new(1, 1), Priority(0))
                .with_label("app", "x")
                .with_anti_affinity("app", "x"),
            Pod::new(1, "b", Resources::new(1, 1), Priority(0)).with_label("app", "x"),
        ];
        let mut s = ClusterState::new(nodes, pods);
        s.bind(PodId(0), NodeId(0)).unwrap();
        // resident's anti-affinity fires against the incomer
        assert_eq!(
            s.bind(PodId(1), NodeId(0)),
            Err(StateError::AntiAffinityViolation {
                pod: PodId(1),
                other: PodId(0),
                node: NodeId(0)
            })
        );
        s.bind(PodId(1), NodeId(1)).unwrap();
        s.check_invariants().unwrap();
    }

    #[test]
    fn extended_resources_tracked_through_lifecycle() {
        let mut nodes = identical_nodes(1, Resources::new(1000, 1000));
        nodes[0] = nodes[0].clone().with_extended("gpu", 2);
        let pods = vec![
            Pod::new(0, "g1", Resources::new(1, 1), Priority(0)).with_extended("gpu", 1),
            Pod::new(1, "g2", Resources::new(1, 1), Priority(0)).with_extended("gpu", 2),
        ];
        let mut s = ClusterState::new(nodes, pods);
        assert_eq!(s.free_extended(NodeId(0), "gpu"), 2);
        s.bind(PodId(0), NodeId(0)).unwrap();
        assert_eq!(s.free_extended(NodeId(0), "gpu"), 1);
        assert!(matches!(
            s.bind(PodId(1), NodeId(0)),
            Err(StateError::InsufficientExtended { .. })
        ));
        s.evict(PodId(0)).unwrap();
        assert_eq!(s.free_extended(NodeId(0), "gpu"), 2);
        s.bind(PodId(1), NodeId(0)).unwrap();
        s.terminate(PodId(1)).unwrap();
        assert_eq!(s.free_extended(NodeId(0), "gpu"), 2);
        // an unknown resource reads as zero capacity
        assert_eq!(s.free_extended(NodeId(0), "tpu"), 0);
        s.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "sort last")]
    fn join_with_non_sorting_name_rejected() {
        let mut s = two_node_state();
        s.add_node("aaa-first", Resources::ZERO);
    }

    #[test]
    #[should_panic(expected = "sorted by name")]
    fn unsorted_nodes_rejected() {
        let mut nodes = identical_nodes(2, Resources::ZERO);
        nodes.swap(0, 1);
        // fix dense ids to trigger the name assertion specifically
        nodes[0].id = NodeId(0);
        nodes[1].id = NodeId(1);
        ClusterState::new(nodes, vec![]);
    }
}
