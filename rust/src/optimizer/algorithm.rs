//! Algorithm 1 — the per-priority optimisation loop.
//!
//! Pseudocode line numbers from the paper are cross-referenced in
//! comments. For each priority tier `pr = 0..=p_max` (0 = highest):
//!
//! 1. assemble the tier's model from the registered constraint modules
//!    (L3 — multi-knapsack plus whatever else the registry declares),
//! 2. **maximise the number of placed pods** with priority ≤ pr (L5–6),
//!    then lock the metric: `=` if proven optimal, `≥` otherwise (L7–10),
//! 3. **minimise disruption**: maximise Σ (Σ_j x_ij + 2·x_i,where) over
//!    currently-placed pods (L12–14), lock `=` / `≤` (L15–18).
//!
//! Our solver, like CP-SAT, has no incremental push/pop, so the model is
//! rebuilt for every solve with all accumulated lock constraints — and,
//! as the paper does, the previous solution is installed as a **hint**
//! to warm-start the next solve. Across *invocations* (churn cycles,
//! defrag sweeps) the session layer ([`super::session`]) adds
//! certificate replay and warm-start floors on top of this loop via
//! [`optimize_session`].
//!
//! Time accounting is the paper's: every solve gets
//! `α·T_total/(p_max+1)/2 + unused` (see
//! [`crate::telemetry::clock::TimeBudget`]).

use std::time::Duration;

use crate::autoscaler::AutoscaleConfig;
use crate::cluster::{ClusterState, NodeId, PodId};
use crate::portfolio::{solve_portfolio_probed, PortfolioConfig, PortfolioStats, SolveCache};
use crate::solver::{CmpOp, LinearExpr, Model, Probe, SearchStats, SolveStatus, SolverConfig};
use crate::telemetry::{clock::TimeBudget, Deadline, Stopwatch, Telemetry, Verbosity};

use super::builder::{PackingModelBuilder, VarTable};
use super::constraints::ModuleRegistry;

/// Configuration for one optimisation run.
#[derive(Clone, Debug)]
pub struct OptimizerConfig {
    /// `T_total`: overall wall-clock limit across all tiers and phases.
    pub total_timeout: Duration,
    /// `α`: fraction of `T_total` pre-partitioned across priority tiers.
    pub alpha: f64,
    /// Underlying CP solver feature toggles.
    pub solver: SolverConfig,
    /// Parallel portfolio knobs (decomposition + strategy race). The
    /// default `threads = 1` is bit-for-bit the single-threaded solver;
    /// `KUBE_PACKD_THREADS` raises the default.
    pub portfolio: PortfolioConfig,
    /// Constraint modules the per-tier model is assembled from. The
    /// default is [`ModuleRegistry::standard`]; register custom modules
    /// here to extend the model without touching the solver core.
    pub modules: ModuleRegistry,
    /// Drivers that own a long-lived loop (the fallback plugin, the
    /// churn runner, the `solve`/`churn` CLIs via `--incremental`)
    /// create a [`SolveSession`](super::session::SolveSession) when this
    /// is set, reusing proven certificates and warm starts across
    /// consecutive solves. `optimize` itself stays stateless; the knob
    /// only tells drivers to keep a session alive.
    pub incremental: bool,
    /// Opt-in CP-driven autoscaling. When set, the fallback scheduler
    /// ([`OptimizingScheduler`](super::plugin::OptimizingScheduler))
    /// reacts to *certified* unplaceability — a tier proven maximal with
    /// pods still pending — by solving the min-cost provisioning model
    /// and joining the resulting nodes; churn drivers additionally run
    /// the consolidation scale-down pass at sweep ticks. `optimize`
    /// itself never mutates the cluster; the knob only arms drivers.
    pub autoscale: Option<AutoscaleConfig>,
    /// Telemetry verbosity for drivers that do not pass an explicit
    /// handle: `Off` (the default) records nothing, `Info` records
    /// spans/counters silently, `Debug`/`Trace` additionally echo
    /// structured events to stderr — the successor of the old
    /// `KUBE_PACKD_DEBUG` env toggle. Telemetry observes only, so this
    /// knob never changes results (and is excluded from session
    /// config fingerprints).
    pub verbosity: Verbosity,
}

impl Default for OptimizerConfig {
    fn default() -> Self {
        OptimizerConfig {
            total_timeout: Duration::from_secs(10),
            alpha: 0.8,
            solver: SolverConfig::default(),
            portfolio: PortfolioConfig::default(),
            modules: ModuleRegistry::standard(),
            incremental: false,
            autoscale: None,
            verbosity: Verbosity::Off,
        }
    }
}

impl OptimizerConfig {
    pub fn with_timeout(secs: f64) -> Self {
        OptimizerConfig {
            total_timeout: Duration::from_secs_f64(secs),
            ..Default::default()
        }
    }

    /// Replace the module registry (builder style).
    pub fn with_modules(mut self, modules: ModuleRegistry) -> Self {
        self.modules = modules;
        self
    }

    /// Set the portfolio worker count (builder style; 0 clamps to 1).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.portfolio.threads = threads.max(1);
        self
    }

    /// Toggle incremental solve sessions (builder style).
    pub fn with_incremental(mut self, incremental: bool) -> Self {
        self.incremental = incremental;
        self
    }

    /// Arm CP-driven autoscaling (builder style).
    pub fn with_autoscale(mut self, autoscale: AutoscaleConfig) -> Self {
        self.autoscale = Some(autoscale);
        self
    }
}

/// Per-tier solve outcome (both phases).
#[derive(Clone, Debug)]
pub struct TierReport {
    pub priority: u32,
    pub phase1_status: SolveStatus,
    /// Number of pods (priority ≤ tier) placed by phase 1.
    pub phase1_placed: i64,
    /// Admissible upper bound on the phase-1 metric — with
    /// `phase1_status` this is the tier's optimality certificate
    /// (proven-optimal iff `phase1_status == Optimal`, in which case the
    /// bound equals `phase1_placed`).
    pub phase1_bound: i64,
    /// Constraint-graph components of the phase-1 model (0 on the
    /// single-threaded legacy path, which skips the probe).
    pub phase1_components: usize,
    /// How many of those components were individually proven optimal.
    pub phase1_components_certified: usize,
    pub phase2_status: SolveStatus,
    pub phase2_metric: i64,
    /// Upper bound on the phase-2 (stay) metric.
    pub phase2_bound: i64,
    /// The phase solve was answered by an incremental session's
    /// certificate cache (zero solver invocations).
    pub phase1_cache_hit: bool,
    pub phase2_cache_hit: bool,
    pub phase1_time: Duration,
    pub phase2_time: Duration,
    /// Search-effort counters of this tier's phase-1 + phase-2 solves
    /// combined (decisions, propagations, conflicts, prunes, symmetry
    /// skips, LNS rounds). Previously these only reached telemetry
    /// counters; surfacing them here lets `solve --json` report search
    /// effort per tier offline.
    pub search: SearchStats,
}

/// Result of the full Algorithm 1 loop.
#[derive(Clone, Debug)]
pub struct OptimizeResult {
    /// Target assignment for every pod (index = pod id).
    pub target: Vec<Option<NodeId>>,
    /// Placed pods per priority tier under `target`.
    pub placed_per_priority: Vec<usize>,
    /// True iff *every* phase-1 solve proved optimality — then `target`
    /// provably maximises the per-priority placement vector.
    pub proved_optimal: bool,
    pub tiers: Vec<TierReport>,
    /// Total wall-clock of the optimisation (incl. model builds).
    pub duration: Duration,
    pub stats: SearchStats,
    /// Portfolio-layer counters (components, strategy wins, …) summed
    /// over every per-phase solve of the run.
    pub portfolio: PortfolioStats,
}

/// Locked metric from an earlier phase, rebuilt against fresh VarIds on
/// every model reconstruction.
#[derive(Clone, Debug)]
enum LockMetric {
    /// Phase 1 of `tier`: Σ x over pods with priority ≤ tier.
    Placed { tier: u32 },
    /// Phase 2 of `tier`: Σ (Σ_j x_ij + 2 x_i,home) over placed pods ≤ tier.
    Stay { tier: u32 },
}

#[derive(Clone, Debug)]
struct Lock {
    metric: LockMetric,
    op: CmpOp,
    value: i64,
}

/// Build the model for tier `pr` from the registered constraint modules,
/// then append all accumulated phase locks (L8/L10/L16/L18).
fn build_model(
    state: &ClusterState,
    pr: u32,
    locks: &[Lock],
    modules: &ModuleRegistry,
) -> (Model, VarTable) {
    let (mut m, table) = PackingModelBuilder::new(state, pr, modules).build();
    let from = m.next_constraint_index();
    for lock in locks {
        let expr = metric_expr(state, &table, &lock.metric);
        m.add_constraint(expr, lock.op, lock.value);
    }
    // Solve forensics: phase-lock rows get their own provenance bucket —
    // they are Algorithm 1's rows, not any constraint module's.
    m.tag_constraints(from, "lock");
    (m, table)
}

/// Materialise a metric over the current var table.
fn metric_expr(state: &ClusterState, table: &VarTable, metric: &LockMetric) -> LinearExpr {
    let mut e = LinearExpr::new();
    match *metric {
        LockMetric::Placed { tier } => {
            for i in table.eligible_pods() {
                if state.pods()[i].priority.0 > tier {
                    continue;
                }
                for j in 0..state.nodes().len() {
                    if let Some(v) = table.var(i, j) {
                        e.add(v, 1);
                    }
                }
            }
        }
        LockMetric::Stay { tier } => {
            for i in table.eligible_pods() {
                let pod = &state.pods()[i];
                if pod.priority.0 > tier {
                    continue;
                }
                let Some(home) = state.assignment_of(PodId(i as u32)) else {
                    continue; // paper: only pods with where ≠ 0
                };
                for j in 0..state.nodes().len() {
                    if let Some(v) = table.var(i, j) {
                        // weight 1 for any placement + extra 2 for staying home
                        e.add(v, if j == home.idx() { 3 } else { 1 });
                    }
                }
            }
        }
    }
    e.normalized()
}

/// Install warm-start hints: prefer the running assignment / the previous
/// tier's solution (CP-SAT hint per the paper's "Solver" subsection).
fn install_hints(
    m: &mut Model,
    state: &ClusterState,
    table: &VarTable,
    previous: &[Option<NodeId>],
) {
    for i in table.eligible_pods() {
        let hint_node = previous[i].or_else(|| state.assignment_of(PodId(i as u32)));
        if let Some(n) = hint_node {
            if let Some(v) = table.var(i, n.idx()) {
                m.hint(v, true);
            }
        }
    }
}

/// Extract the assignment a solution encodes.
fn extract_assignment(
    state: &ClusterState,
    table: &VarTable,
    values: &[bool],
    into: &mut [Option<NodeId>],
) {
    for i in table.eligible_pods() {
        into[i] = None;
        for j in 0..state.nodes().len() {
            if let Some(v) = table.var(i, j) {
                if values[v.idx()] {
                    into[i] = Some(NodeId(j as u32));
                    break;
                }
            }
        }
    }
}

/// Run Algorithm 1 over the cluster. Returns `None` when the solver
/// produced no usable solution within the budget (the paper's *Failures*
/// category).
pub fn optimize(state: &ClusterState, p_max: u32, cfg: &OptimizerConfig) -> Option<OptimizeResult> {
    optimize_session(state, p_max, cfg, None)
}

/// [`optimize`] with an optional session certificate cache threaded
/// through every per-tier phase solve (see
/// [`SolveSession`](super::session::SolveSession), which owns the cache
/// and the surrounding full-state replay). With `None` this *is*
/// `optimize`; with a cache, unchanged phase solves and decomposed
/// components replay their proven certificates and dirty ones
/// warm-start — byte-identical results either way, when solves complete
/// in-window.
pub fn optimize_session(
    state: &ClusterState,
    p_max: u32,
    cfg: &OptimizerConfig,
    cache: Option<&mut SolveCache>,
) -> Option<OptimizeResult> {
    let local = Telemetry::from_verbosity(cfg.verbosity);
    optimize_traced(state, p_max, cfg, cache, &local)
}

/// [`optimize_session`] with an explicit telemetry handle. Every tier
/// contributes a `phase1`/`phase2` span pair (nesting the portfolio's
/// cache / decompose / warm-start / strategy-race spans), the old debug
/// eprintlns become structured `optimize` events, and per-run counters
/// land under `optimizer_*`. When no handle is passed,
/// [`optimize_session`] derives one from `cfg.verbosity`.
pub fn optimize_traced(
    state: &ClusterState,
    p_max: u32,
    cfg: &OptimizerConfig,
    cache: Option<&mut SolveCache>,
    tel: &Telemetry,
) -> Option<OptimizeResult> {
    optimize_probed(state, p_max, cfg, cache, tel, &Probe::off())
}

/// [`optimize_traced`] with a solve-forensics [`Probe`]. Each phase
/// solve runs under a `t{tier}.p{phase}` context frame, so the profile's
/// folded stacks and gap timelines separate per tier per phase. The
/// probe only ever *observes* the canonical exact-search lane (see
/// [`crate::portfolio::solve_portfolio_probed`]); arming it changes no
/// result.
pub fn optimize_probed(
    state: &ClusterState,
    p_max: u32,
    cfg: &OptimizerConfig,
    mut cache: Option<&mut SolveCache>,
    tel: &Telemetry,
    prof: &Probe,
) -> Option<OptimizeResult> {
    let sw = Stopwatch::start();
    let mut budget = TimeBudget::new(cfg.total_timeout, cfg.alpha, p_max + 1);
    let overall = budget.overall_deadline();
    let mut locks: Vec<Lock> = Vec::new();
    let mut tiers = Vec::new();
    let mut stats = SearchStats::default();
    let mut pstats = PortfolioStats::default();
    let mut target: Vec<Option<NodeId>> = vec![None; state.pods().len()];
    let mut have_solution = false;
    let mut proved_optimal = true;

    for pr in 0..=p_max {
        // ---- phase 1: maximise placed pods up to priority pr (L5–L10) ----
        let (mut m, table) = build_model(state, pr, &locks, &cfg.modules);
        install_hints(&mut m, state, &table, &target);
        let metric1 = metric_expr(state, &table, &LockMetric::Placed { tier: pr });

        let grant = budget.grant_phase().max(Duration::from_millis(2));
        let t = Stopwatch::start();
        let sp1 = tel.span("phase1");
        sp1.arg("tier", pr);
        let pf1 = prof.frame(&format!("t{pr}.p1"));
        let out1 = solve_portfolio_probed(
            &m,
            &metric1,
            Deadline::after(grant).min(overall),
            &cfg.solver,
            &cfg.portfolio,
            cache.as_deref_mut(),
            tel,
            prof,
        );
        drop(pf1);
        sp1.arg("status", out1.solution.status.label());
        sp1.arg("objective", out1.solution.objective);
        drop(sp1);
        let phase1_cache_hit = out1.stats.cache_hits > 0;
        let phase1_components = out1.components.len();
        let phase1_components_certified = out1
            .components
            .iter()
            .filter(|c| c.status == SolveStatus::Optimal)
            .count();
        let sol1 = out1.solution;
        let phase1_time = t.elapsed();
        budget.report_used(grant, phase1_time);
        stats.merge(&sol1.stats);
        pstats.merge(&out1.stats);

        tel.event("optimize", || {
            format!(
                "tier {pr} phase1: {:?} obj={} bound={} grant={:?} used={:?} \
                 dec={} prunes={} components={}",
                sol1.status,
                sol1.objective,
                sol1.bound,
                grant,
                phase1_time,
                sol1.stats.decisions,
                sol1.stats.bound_prunes,
                phase1_components
            )
        });
        if !sol1.status.has_solution() {
            // No feasible packing surfaced in time for this tier: the run
            // is a Failure (the paper's grey bar).
            tel.add("optimizer_failures_total", "", 1);
            return None;
        }
        locks.push(Lock {
            metric: LockMetric::Placed { tier: pr },
            op: if sol1.status == SolveStatus::Optimal {
                CmpOp::Eq // L8
            } else {
                CmpOp::Ge // L10
            },
            value: sol1.objective,
        });
        proved_optimal &= sol1.status == SolveStatus::Optimal;
        extract_assignment(state, &table, &sol1.values, &mut target);
        have_solution = true;

        // ---- phase 2: minimise disruption (L12–L18) -----------------------
        let (mut m2, table2) = build_model(state, pr, &locks, &cfg.modules);
        install_hints(&mut m2, state, &table2, &target);
        let metric2 = metric_expr(state, &table2, &LockMetric::Stay { tier: pr });

        let grant2 = budget.grant_phase().max(Duration::from_millis(2));
        let t2 = Stopwatch::start();
        let sp2 = tel.span("phase2");
        sp2.arg("tier", pr);
        let pf2 = prof.frame(&format!("t{pr}.p2"));
        let out2 = solve_portfolio_probed(
            &m2,
            &metric2,
            Deadline::after(grant2).min(overall),
            &cfg.solver,
            &cfg.portfolio,
            cache.as_deref_mut(),
            tel,
            prof,
        );
        drop(pf2);
        sp2.arg("status", out2.solution.status.label());
        sp2.arg("objective", out2.solution.objective);
        drop(sp2);
        let phase2_cache_hit = out2.stats.cache_hits > 0;
        let sol2 = out2.solution;
        let phase2_time = t2.elapsed();
        budget.report_used(grant2, phase2_time);
        stats.merge(&sol2.stats);
        pstats.merge(&out2.stats);

        tel.event("optimize", || {
            format!(
                "tier {pr} phase2: {:?} obj={} grant={:?} used={:?}",
                sol2.status, sol2.objective, grant2, phase2_time
            )
        });
        let (phase2_status, phase2_metric) = if sol2.status.has_solution() {
            locks.push(Lock {
                metric: LockMetric::Stay { tier: pr },
                op: if sol2.status == SolveStatus::Optimal {
                    CmpOp::Eq // L16
                } else {
                    CmpOp::Le // L18 (as printed in the paper)
                },
                value: sol2.objective,
            });
            extract_assignment(state, &table2, &sol2.values, &mut target);
            (sol2.status, sol2.objective)
        } else {
            // Keep phase 1's assignment; the tier is still placed-maximal.
            (sol2.status, 0)
        };

        let mut tier_search = sol1.stats.clone();
        tier_search.merge(&sol2.stats);
        tiers.push(TierReport {
            priority: pr,
            phase1_status: sol1.status,
            phase1_placed: sol1.objective,
            phase1_bound: sol1.bound,
            phase1_components,
            phase1_components_certified,
            phase2_status,
            phase2_metric,
            phase2_bound: sol2.bound,
            phase1_cache_hit,
            phase2_cache_hit,
            phase1_time,
            phase2_time,
            search: tier_search,
        });
    }

    if !have_solution {
        return None;
    }

    // Every module vouches for the final target (solution-audit hook).
    if cfg!(debug_assertions) {
        if let Err(e) = cfg.modules.audit(state, &target) {
            panic!("constraint-module audit rejected the solver target: {e}");
        }
    }

    // Per-priority placement vector of the target.
    let mut placed = vec![0usize; p_max as usize + 1];
    for (i, t) in target.iter().enumerate() {
        if t.is_some() {
            placed[state.pods()[i].priority.0 as usize] += 1;
        }
    }

    if tel.enabled() {
        tel.add("optimizer_runs_total", "", 1);
        tel.add("optimizer_tiers_total", "", tiers.len() as u64);
        tel.add(
            "optimizer_tiers_certified_total",
            "",
            tiers
                .iter()
                .filter(|t| t.phase1_status == SolveStatus::Optimal)
                .count() as u64,
        );
        tel.add(
            "optimizer_phase_cache_hits_total",
            "",
            tiers
                .iter()
                .map(|t| u64::from(t.phase1_cache_hit) + u64::from(t.phase2_cache_hit))
                .sum(),
        );
        tel.add(
            "optimizer_proved_optimal_total",
            "",
            u64::from(proved_optimal),
        );
    }

    Some(OptimizeResult {
        target,
        placed_per_priority: placed,
        proved_optimal,
        tiers,
        duration: sw.elapsed(),
        stats,
        portfolio: pstats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Pod, Priority, Resources};

    fn figure1() -> ClusterState {
        // Default scheduler already spread pods 0,1 over both nodes.
        let nodes = identical_nodes(2, Resources::new(4000, 4096));
        let pods = vec![
            Pod::new(0, "pod-1", Resources::new(10, 2048), Priority(0)),
            Pod::new(1, "pod-2", Resources::new(10, 2048), Priority(0)),
            Pod::new(2, "pod-3", Resources::new(10, 3072), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        st.bind(PodId(1), NodeId(1)).unwrap();
        st
    }

    #[test]
    fn figure1_repacked_optimally() {
        let st = figure1();
        let res = optimize(&st, 0, &OptimizerConfig::with_timeout(5.0)).unwrap();
        assert!(res.proved_optimal);
        assert_eq!(res.placed_per_priority, vec![3]); // all three pods fit
        // pods 0 and 1 now share one node, pod 2 takes the other
        let a = res.target[0].unwrap();
        let b = res.target[1].unwrap();
        let c = res.target[2].unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn figure1_identical_under_legacy_module_set() {
        // Pure-refactor parity: on a constraint-free workload, the
        // standard registry and the paper's original vocabulary build
        // the same model and produce the same target.
        let st = figure1();
        let full = optimize(&st, 0, &OptimizerConfig::with_timeout(5.0)).unwrap();
        let legacy = optimize(
            &st,
            0,
            &OptimizerConfig::with_timeout(5.0).with_modules(ModuleRegistry::resource_only()),
        )
        .unwrap();
        assert_eq!(full.target, legacy.target);
        assert_eq!(full.placed_per_priority, legacy.placed_per_priority);
    }

    #[test]
    fn thread_counts_agree_on_figure1() {
        let st = figure1();
        let base = optimize(&st, 0, &OptimizerConfig::with_timeout(5.0)).unwrap();
        for threads in [2, 8] {
            let res = optimize(
                &st,
                0,
                &OptimizerConfig::with_timeout(5.0).with_threads(threads),
            )
            .unwrap();
            assert_eq!(res.target, base.target, "threads={threads}");
            assert_eq!(res.placed_per_priority, base.placed_per_priority);
            assert!(res.proved_optimal);
            assert!(res.portfolio.solves > 0, "portfolio path not taken");
        }
    }

    #[test]
    fn tier_reports_carry_optimality_certificates() {
        let st = figure1();
        // threads pinned to 1 so the legacy-path counter assertion below
        // holds regardless of KUBE_PACKD_THREADS.
        let res = optimize(&st, 0, &OptimizerConfig::with_timeout(5.0).with_threads(1)).unwrap();
        let t = &res.tiers[0];
        assert_eq!(t.phase1_status, SolveStatus::Optimal);
        assert_eq!(t.phase1_bound, t.phase1_placed, "proven ⇒ bound closed");
        assert_eq!(t.phase2_status, SolveStatus::Optimal);
        assert_eq!(t.phase2_bound, t.phase2_metric);
        // the default config routed through the legacy path
        assert!(res.portfolio.legacy_solves > 0);
    }

    #[test]
    fn respects_priorities_over_counts() {
        // One node; a high-priority hog vs two small low-priority pods.
        // Placed-count maximisation per tier must keep the hog (tier 0)
        // even though evicting it would fit two tier-1 pods.
        let nodes = identical_nodes(1, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "hog", Resources::new(900, 900), Priority(0)),
            Pod::new(1, "s1", Resources::new(500, 500), Priority(1)),
            Pod::new(2, "s2", Resources::new(500, 500), Priority(1)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        let res = optimize(&st, 1, &OptimizerConfig::with_timeout(5.0)).unwrap();
        assert_eq!(res.placed_per_priority, vec![1, 0]);
        assert_eq!(res.target[0], Some(NodeId(0)));
    }

    #[test]
    fn minimises_moves_among_optimal_packings() {
        // Two nodes, two pods already placed apart; a third does not exist.
        // Any single-node packing is also "optimal" for placed-count; the
        // stay metric must keep both pods where they are.
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "a", Resources::new(400, 400), Priority(0)),
            Pod::new(1, "b", Resources::new(400, 400), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        st.bind(PodId(1), NodeId(1)).unwrap();
        let res = optimize(&st, 0, &OptimizerConfig::with_timeout(5.0)).unwrap();
        assert_eq!(res.target[0], Some(NodeId(0)));
        assert_eq!(res.target[1], Some(NodeId(1)));
        assert!(res.proved_optimal);
        // stay metric: both pods at home = 2 * 3
        assert_eq!(res.tiers[0].phase2_metric, 6);
    }

    #[test]
    fn multi_tier_locks_keep_higher_tiers_intact() {
        // Tier 0 fills the cluster; tier 1 cannot displace it.
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "h1", Resources::new(1000, 1000), Priority(0)),
            Pod::new(1, "h2", Resources::new(1000, 1000), Priority(0)),
            Pod::new(2, "lo", Resources::new(100, 100), Priority(1)),
        ];
        let st = ClusterState::new(nodes, pods);
        let res = optimize(&st, 1, &OptimizerConfig::with_timeout(5.0)).unwrap();
        assert_eq!(res.placed_per_priority, vec![2, 0]);
        assert_eq!(res.target[2], None);
        assert_eq!(res.tiers.len(), 2);
    }

    #[test]
    fn selector_restricts_candidate_nodes() {
        let mut nodes = identical_nodes(2, Resources::new(1000, 1000));
        nodes[1] = nodes[1].clone().with_label("disk", "ssd");
        let pods = vec![
            Pod::new(0, "p", Resources::new(100, 100), Priority(0)).with_selector("disk", "ssd"),
        ];
        let st = ClusterState::new(nodes, pods);
        let res = optimize(&st, 0, &OptimizerConfig::with_timeout(5.0)).unwrap();
        assert_eq!(res.target[0], Some(NodeId(1)));
    }

    #[test]
    fn infeasible_pod_left_unplaced_not_failure() {
        let nodes = identical_nodes(1, Resources::new(100, 100));
        let pods = vec![Pod::new(0, "xl", Resources::new(1000, 1000), Priority(0))];
        let st = ClusterState::new(nodes, pods);
        let res = optimize(&st, 0, &OptimizerConfig::with_timeout(2.0)).unwrap();
        assert_eq!(res.placed_per_priority, vec![0]);
        assert_eq!(res.target[0], None);
        assert!(res.proved_optimal);
    }
}
