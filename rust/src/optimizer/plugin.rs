//! Scheduler-framework integration of the optimiser — the paper's
//! "Kubernetes Plugin" section, one extension point at a time:
//!
//! * **PreEnqueue** — while a plan is in flight, pods that are part of it
//!   are admitted; unrelated new arrivals are buffered by the paused
//!   queue (the paper's "temporarily paused ... re-queued once the
//!   solver execution completes").
//! * **PreFilter** — plan pods are pinned to their solver-chosen node, so
//!   the default scheduling cycle binds them exactly where the optimiser
//!   decided ("assigns the affected pods to their target nodes, allowing
//!   the default scheduler to bind them accordingly").
//! * **PostFilter** — pods that fail filtering are recorded; they are the
//!   trigger signal for the optimiser (pre-emption hook in Kubernetes).
//! * **Reserve/Unreserve** — per-pod reservation bookkeeping (the paper
//!   reserves by resource since pod names change on rescheduling; our
//!   simulator keeps stable ids, so this tracks reservations for
//!   observability and rollback symmetry).
//! * **PostBind** — marks plan entries done and completes the plan when
//!   every intended allocation realised.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::autoscaler::{certified_unplaceable, plan_provisioning, ProvisionOutcome, ScaleUpReport};
use crate::cluster::{ClusterState, Event, NodeId, PodId, Resources};
use crate::metrics::lex_better;
use crate::scheduler::default::RunStats;
use crate::scheduler::framework::{
    CycleContext, PluginDecision, PostBindPlugin, PostFilterPlugin, PreEnqueuePlugin,
    PreFilterPlugin, ReservePlugin,
};
use crate::scheduler::DefaultScheduler;
use crate::telemetry::{Deadline, Stopwatch, Telemetry};

use crate::solver::Probe;

use super::algorithm::{optimize_probed, OptimizeResult, OptimizerConfig};
use super::plan::MovePlan;
use super::session::SolveSession;

/// Shared plan state between the five plugin instances and the driver.
#[derive(Debug, Default)]
pub struct PlanState {
    pub active: bool,
    /// Solver-chosen node per plan pod.
    pub targets: BTreeMap<PodId, NodeId>,
    /// Plan pods already bound.
    pub done: Vec<PodId>,
    /// Outstanding reservations (Reserve ran, PostBind pending).
    pub reserved: BTreeMap<PodId, NodeId>,
    /// Pods PostFilter saw fail (the optimiser trigger signal).
    pub filter_failures: Vec<PodId>,
}

impl PlanState {
    fn remaining(&self) -> usize {
        self.targets.len() - self.done.len()
    }
}

/// The five-extension-point plugin (one struct registered five times).
pub struct PackdPlugin {
    state: Rc<RefCell<PlanState>>,
}

impl PreEnqueuePlugin for PackdPlugin {
    fn pre_enqueue(&mut self, _state: &ClusterState, _pod: PodId) -> PluginDecision {
        // All pods may enqueue; non-plan arrivals during a solve are held
        // by the queue's pause, not rejected here.
        PluginDecision::Allow
    }
    fn name(&self) -> &'static str {
        "PackdPreEnqueue"
    }
}

impl PreFilterPlugin for PackdPlugin {
    fn pre_filter(
        &mut self,
        _state: &ClusterState,
        pod: PodId,
        ctx: &mut CycleContext,
    ) -> PluginDecision {
        let ps = self.state.borrow();
        if ps.active {
            if let Some(&target) = ps.targets.get(&pod) {
                ctx.pinned_node = Some(target);
            }
        }
        PluginDecision::Allow
    }
    fn name(&self) -> &'static str {
        "PackdPreFilter"
    }
}

impl PostFilterPlugin for PackdPlugin {
    fn post_filter(&mut self, _state: &ClusterState, pod: PodId) {
        self.state.borrow_mut().filter_failures.push(pod);
    }
    fn name(&self) -> &'static str {
        "PackdPostFilter"
    }
}

impl ReservePlugin for PackdPlugin {
    fn reserve(&mut self, _state: &ClusterState, pod: PodId, node: NodeId, ctx: &mut CycleContext) {
        ctx.reserved = Some(node);
        let mut ps = self.state.borrow_mut();
        if ps.active && ps.targets.contains_key(&pod) {
            ps.reserved.insert(pod, node);
        }
    }
    fn unreserve(&mut self, _state: &ClusterState, pod: PodId, ctx: &mut CycleContext) {
        ctx.reserved = None;
        self.state.borrow_mut().reserved.remove(&pod);
    }
    fn name(&self) -> &'static str {
        "PackdReserve"
    }
}

impl PostBindPlugin for PackdPlugin {
    fn post_bind(&mut self, _state: &ClusterState, pod: PodId, _node: NodeId) {
        let mut ps = self.state.borrow_mut();
        ps.reserved.remove(&pod);
        if ps.active && ps.targets.contains_key(&pod) && !ps.done.contains(&pod) {
            ps.done.push(pod);
            if ps.remaining() == 0 {
                ps.active = false; // plan complete
            }
        }
    }
    fn name(&self) -> &'static str {
        "PackdPostBind"
    }
}

/// Report of one `OptimizingScheduler::run` pass.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub default_stats: RunStats,
    pub solver_invoked: bool,
    /// Solver result (None if not invoked or failed).
    pub optimize: Option<OptimizeResult>,
    /// The pass ended strictly better (lexicographic placement vector)
    /// than it started — measured on the *actual* final state, so an
    /// aborted plan that changed nothing reads `false` even when the
    /// solver had promised an improvement.
    pub improved: bool,
    pub proved_optimal: bool,
    /// A filter plugin rejected part of an executing plan — reachable
    /// when a custom filter has no mirroring constraint module (the
    /// built-in filters always agree with the CP model; even the
    /// order-sensitive TopologySpread filter exempts plan-pinned
    /// placements). The run rolled back to ordinary scheduling instead
    /// of crashing.
    pub plan_incomplete: bool,
    /// Pods whose node changed to realise the plan.
    pub disruptions: usize,
    /// Certificate-guided scale-up taken this pass (None unless
    /// `OptimizerConfig.autoscale` is armed *and* the run certified
    /// unplaceable pods): the provisioning solve's outcome, applied or
    /// not.
    pub autoscale: Option<ScaleUpReport>,
    /// Placement vector before / after the full pass.
    pub placed_before: Vec<usize>,
    pub placed_after: Vec<usize>,
    pub solver_wall: std::time::Duration,
}

/// Default scheduler + optimiser fallback, wired through the plugin.
pub struct OptimizingScheduler {
    pub scheduler: DefaultScheduler,
    plan: Rc<RefCell<PlanState>>,
    pub cfg: OptimizerConfig,
    pub p_max: u32,
    /// Incremental solve session kept alive across `run` passes when
    /// `cfg.incremental` is set. Drivers that rebuild the scheduler per
    /// cycle (the churn runner) instead pass a longer-lived session via
    /// [`run_with_session`](OptimizingScheduler::run_with_session).
    session: Option<SolveSession>,
    /// Scale-up reference capacity, snapshotted on first use: deriving
    /// it per pass from the live fleet would let an autoscaled `large`
    /// node inflate every later candidate's size at the same cost.
    autoscale_reference: Option<Resources>,
    /// Memoized *proven-infeasible* provisioning outcome, keyed on the
    /// state and autoscale-config fingerprints: an unchanged cluster
    /// replays the certificate instead of re-burning the provisioning
    /// window every pass. Only certificates are cached — a
    /// deadline-truncated Unknown is a wall-clock artifact and must
    /// stay retryable.
    provision_memo: Option<(u64, ScaleUpReport)>,
}

impl OptimizingScheduler {
    pub fn new(p_max: u32, cfg: OptimizerConfig) -> Self {
        let plan = Rc::new(RefCell::new(PlanState::default()));
        let mut scheduler = DefaultScheduler::kwok_default();
        // Register the plugin at its five extension points.
        scheduler.framework.pre_enqueue.push(Box::new(PackdPlugin { state: plan.clone() }));
        scheduler.framework.pre_filter.push(Box::new(PackdPlugin { state: plan.clone() }));
        scheduler.framework.post_filter.push(Box::new(PackdPlugin { state: plan.clone() }));
        scheduler.framework.reserve.push(Box::new(PackdPlugin { state: plan.clone() }));
        scheduler.framework.post_bind.push(Box::new(PackdPlugin { state: plan.clone() }));
        let session = cfg.incremental.then(SolveSession::new);
        OptimizingScheduler {
            scheduler,
            plan,
            cfg,
            p_max,
            session,
            autoscale_reference: None,
            provision_memo: None,
        }
    }

    /// Full pass: default scheduling, then — if pods went pending — the
    /// solver fallback with plan execution (cross-node pre-emption).
    /// Uses the internal session when `cfg.incremental` created one.
    pub fn run(&mut self, state: &mut ClusterState) -> RunReport {
        let mut session = self.session.take();
        let report = self.run_with_session(state, session.as_mut());
        self.session = session;
        report
    }

    /// [`run`](OptimizingScheduler::run) with an explicit telemetry
    /// handle threaded through the fallback solve and any provisioning
    /// pass (the `--trace`/`--metrics` CLI path).
    pub fn run_traced(&mut self, state: &mut ClusterState, tel: &Telemetry) -> RunReport {
        let mut session = self.session.take();
        let report = self.run_with_session_traced(state, session.as_mut(), tel);
        self.session = session;
        report
    }

    /// Take the memoized non-applied provisioning outcome out of this
    /// scheduler. Drivers that rebuild the scheduler every cycle (the
    /// churn runner) carry it across instances with
    /// [`set_provision_memo`](OptimizingScheduler::set_provision_memo),
    /// the same way they carry the solve session.
    pub fn take_provision_memo(&mut self) -> Option<(u64, ScaleUpReport)> {
        self.provision_memo.take()
    }

    /// Install a memo taken from a previous scheduler instance (pure
    /// caching — outcomes are deterministic per (state, config), so a
    /// transplanted memo can only skip work, never change a decision).
    pub fn set_provision_memo(&mut self, memo: Option<(u64, ScaleUpReport)>) {
        self.provision_memo = memo;
    }

    /// [`run`](OptimizingScheduler::run) with a caller-owned incremental
    /// session (overrides the internal one for this pass). `None` solves
    /// cold — exactly the historical behaviour.
    pub fn run_with_session(
        &mut self,
        state: &mut ClusterState,
        session: Option<&mut SolveSession>,
    ) -> RunReport {
        let local = Telemetry::from_verbosity(self.cfg.verbosity);
        self.run_with_session_traced(state, session, &local)
    }

    /// [`run_with_session`](OptimizingScheduler::run_with_session) with
    /// an explicit telemetry handle.
    pub fn run_with_session_traced(
        &mut self,
        state: &mut ClusterState,
        session: Option<&mut SolveSession>,
        tel: &Telemetry,
    ) -> RunReport {
        self.run_with_session_probed(state, session, tel, &Probe::off())
    }

    /// [`run_with_session_traced`](OptimizingScheduler::run_with_session_traced)
    /// with a solve-forensics [`Probe`] threaded into the fallback solve
    /// (the serve daemon's `profile` op). The probe observes only — the
    /// pass is byte-identical armed or off.
    pub fn run_with_session_probed(
        &mut self,
        state: &mut ClusterState,
        session: Option<&mut SolveSession>,
        tel: &Telemetry,
        prof: &Probe,
    ) -> RunReport {
        self.scheduler.enqueue_pending(state);
        let default_stats = self.scheduler.run_queue(state);
        let placed_before = state.placed_per_priority(self.p_max);

        if self.scheduler.queue.unschedulable_len() == 0 {
            return RunReport {
                default_stats,
                solver_invoked: false,
                optimize: None,
                improved: false,
                proved_optimal: false,
                plan_incomplete: false,
                disruptions: 0,
                autoscale: None,
                placed_after: placed_before.clone(),
                placed_before,
                solver_wall: std::time::Duration::ZERO,
            };
        }

        // --- fallback path -------------------------------------------------
        self.scheduler.queue.pause();
        state.events.push(Event::SolverInvoked {
            pending: self.scheduler.queue.unschedulable_len(),
        });
        let sw = Stopwatch::start();
        let sp = tel.span("fallback");
        sp.arg("pending", self.scheduler.queue.unschedulable_len());
        let result = match session {
            Some(sess) => sess.solve_probed(state, self.p_max, &self.cfg, tel, prof),
            None => optimize_probed(state, self.p_max, &self.cfg, None, tel, prof),
        };
        drop(sp);
        let solver_wall = sw.elapsed();

        let mut proved = false;
        let mut disruptions = 0;
        let mut plan_incomplete = false;

        if let Some(res) = &result {
            proved = res.proved_optimal;
            if lex_better(&res.placed_per_priority, &placed_before) {
                let plan = MovePlan::build(state, &res.target);
                disruptions = plan.disruptions();
                // Evictions run as direct pre-emption events ...
                for &(pod, _) in &plan.evictions {
                    state.evict(pod).expect("plan eviction must apply");
                }
                // ... then placements go through the scheduling framework,
                // pinned to their targets by PackdPreFilter.
                {
                    let mut ps = self.plan.borrow_mut();
                    ps.active = true;
                    ps.targets = plan.placements.iter().copied().collect();
                    ps.done.clear();
                }
                // Plan pods are scheduled FIRST, while every other pending
                // pod stays parked (the paper's plugin keeps an internal
                // list and re-queues it only after the plan completes) —
                // otherwise a non-plan pod could race into capacity the
                // plan needs.
                self.scheduler.queue.resume();
                for &(pod, _) in &plan.placements {
                    if state.assignment_of(pod).is_none() {
                        // evicted movers + pending placements re-enter here
                        self.scheduler.enqueue(state, pod);
                    }
                }
                self.scheduler.run_queue(state);
                if self.plan.borrow().active {
                    // A plan pod was rejected by a filter plugin: the CP
                    // model admitted a target the filter set refuses —
                    // reachable when a custom filter has no mirroring
                    // constraint module. Roll back gracefully: deactivate
                    // the plan (keeping whatever already bound) and let
                    // every remaining pod retry through ordinary
                    // scheduling below.
                    plan_incomplete = true;
                    let mut ps = self.plan.borrow_mut();
                    let missing = ps.remaining();
                    let bound = ps.done.len();
                    ps.active = false;
                    ps.targets.clear();
                    ps.done.clear();
                    drop(ps);
                    state.events.push(Event::PlanAborted { bound, missing });
                } else {
                    for &(pod, node) in &plan.placements {
                        debug_assert_eq!(state.assignment_of(pod), Some(node));
                        state.events.push(Event::PlanBind { pod, node });
                    }
                }
                // Now the held-back pods get their ordinary retry.
                self.scheduler.queue.flush_unschedulable();
                self.scheduler.run_queue(state);
            } else {
                self.scheduler.queue.resume();
            }
        } else {
            self.scheduler.queue.resume();
        }

        // --- certificate-guided scale-up -----------------------------------
        // Only *proven* unplaceability triggers provisioning: the tier's
        // phase-1 bound must be closed, so "the cluster is full" is a
        // certificate, not a heuristic.
        let mut autoscale = None;
        if let (Some(acfg), Some(res)) = (self.cfg.autoscale.clone(), &result) {
            let stuck = certified_unplaceable(state, res);
            if !stuck.is_empty() {
                // Replay a memoized proven failure for an unchanged
                // cluster (applied plans mutate the state, so they can
                // never falsely hit).
                let memo_key =
                    super::session::fingerprint_state(state, self.p_max) ^ acfg.fingerprint();
                if let Some((key, cached)) = &self.provision_memo {
                    if *key == memo_key {
                        autoscale = Some(cached.clone());
                    }
                }
                if autoscale.is_none() {
                    let reference = *self
                        .autoscale_reference
                        .get_or_insert_with(|| acfg.reference_capacity(state));
                    let outcome = plan_provisioning(
                        state,
                        &stuck,
                        &acfg.pools,
                        reference,
                        acfg.max_per_pool,
                        Deadline::after(acfg.provision_timeout),
                        &self.cfg.solver,
                        &self.cfg.portfolio,
                        &self.cfg.modules,
                        tel,
                    );
                    let report = match outcome {
                        ProvisionOutcome::Plan(plan) => {
                            let applied = plan.apply(state, &acfg.pools, reference).is_ok();
                            ScaleUpReport {
                                pending: stuck.len(),
                                nodes_added: plan.node_count,
                                cost: plan.cost,
                                cost_bound: plan.cost_bound,
                                cost_status: plan.cost_status,
                                count_status: plan.count_status,
                                certified: plan.certified(),
                                proven_infeasible: false,
                                applied,
                                per_pool: plan.per_pool,
                            }
                        }
                        ProvisionOutcome::Infeasible => ScaleUpReport {
                            pending: stuck.len(),
                            per_pool: acfg.pools.iter().map(|p| (p.name.clone(), 0)).collect(),
                            nodes_added: 0,
                            cost: 0,
                            cost_bound: 0,
                            cost_status: crate::solver::SolveStatus::Infeasible,
                            count_status: crate::solver::SolveStatus::Infeasible,
                            certified: false,
                            proven_infeasible: true,
                            applied: false,
                        },
                        ProvisionOutcome::Unknown => ScaleUpReport {
                            pending: stuck.len(),
                            per_pool: acfg.pools.iter().map(|p| (p.name.clone(), 0)).collect(),
                            nodes_added: 0,
                            cost: 0,
                            cost_bound: 0,
                            cost_status: crate::solver::SolveStatus::Unknown,
                            count_status: crate::solver::SolveStatus::Unknown,
                            certified: false,
                            proven_infeasible: false,
                            applied: false,
                        },
                    };
                    // Memoize *proven* failures only: Infeasible is a
                    // certificate and replays soundly, while a
                    // deadline-truncated Unknown is a wall-clock
                    // artifact — caching it would disable retries
                    // forever on an unchanged cluster.
                    self.provision_memo = if report.proven_infeasible {
                        Some((memo_key, report.clone()))
                    } else {
                        None
                    };
                    autoscale = Some(report);
                }
            }
        }

        let placed_after = state.placed_per_priority(self.p_max);
        let improved = lex_better(&placed_after, &placed_before);
        state.events.push(Event::SolverFinished {
            improved,
            proved_optimal: proved,
            duration_ms: solver_wall.as_millis() as u64,
        });

        RunReport {
            default_stats,
            solver_invoked: true,
            optimize: result,
            improved,
            proved_optimal: proved,
            plan_incomplete,
            disruptions,
            autoscale,
            placed_after,
            placed_before,
            solver_wall,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Pod, Priority, Resources};

    fn figure1_pods() -> Vec<Pod> {
        vec![
            Pod::new(0, "pod-1", Resources::new(10, 2048), Priority(0)),
            Pod::new(1, "pod-2", Resources::new(10, 2048), Priority(0)),
            Pod::new(2, "pod-3", Resources::new(10, 3072), Priority(0)),
        ]
    }

    #[test]
    fn end_to_end_figure1_fallback() {
        let mut state = ClusterState::new(identical_nodes(2, Resources::new(4000, 4096)), figure1_pods());
        let mut osched = OptimizingScheduler::new(0, OptimizerConfig::with_timeout(5.0));
        let report = osched.run(&mut state);

        assert!(report.solver_invoked);
        assert!(report.improved);
        assert!(report.proved_optimal);
        assert_eq!(report.placed_before, vec![2]);
        assert_eq!(report.placed_after, vec![3]);
        assert_eq!(report.disruptions, 1); // one pod moved across nodes
        state.check_invariants().unwrap();
        // event trail tells the story
        assert!(state.events.evictions() >= 1);
        assert!(state
            .events
            .all()
            .iter()
            .any(|e| matches!(e, Event::SolverFinished { improved: true, .. })));
    }

    #[test]
    fn incremental_scheduler_matches_cold_run() {
        let mk_state = || {
            ClusterState::new(
                identical_nodes(2, Resources::new(4000, 4096)),
                figure1_pods(),
            )
        };
        let mut cold_state = mk_state();
        let mut cold = OptimizingScheduler::new(0, OptimizerConfig::with_timeout(5.0));
        let cold_report = cold.run(&mut cold_state);

        let mut warm_state = mk_state();
        let mut warm = OptimizingScheduler::new(
            0,
            OptimizerConfig::with_timeout(5.0).with_incremental(true),
        );
        let warm_report = warm.run(&mut warm_state);
        // byte-identical outcome: same placements, same final assignment
        assert_eq!(warm_report.placed_before, cold_report.placed_before);
        assert_eq!(warm_report.placed_after, cold_report.placed_after);
        assert_eq!(warm_report.disruptions, cold_report.disruptions);
        assert_eq!(warm_state.assignment(), cold_state.assignment());
    }

    #[test]
    fn no_call_when_default_suffices() {
        let mut state = ClusterState::new(
            identical_nodes(2, Resources::new(8000, 8192)),
            figure1_pods(),
        );
        let mut osched = OptimizingScheduler::new(0, OptimizerConfig::with_timeout(1.0));
        let report = osched.run(&mut state);
        assert!(!report.solver_invoked);
        assert_eq!(report.placed_after, vec![3]);
        assert_eq!(state.events.count(|e| matches!(e, Event::SolverInvoked { .. })), 0);
    }

    #[test]
    fn kwok_optimal_when_no_improvement_possible() {
        // One node, two pods that can never fit together.
        let pods = vec![
            Pod::new(0, "a", Resources::new(900, 900), Priority(0)),
            Pod::new(1, "b", Resources::new(900, 900), Priority(0)),
        ];
        let mut state = ClusterState::new(identical_nodes(1, Resources::new(1000, 1000)), pods);
        let mut osched = OptimizingScheduler::new(0, OptimizerConfig::with_timeout(2.0));
        let report = osched.run(&mut state);
        assert!(report.solver_invoked);
        assert!(!report.improved);
        assert!(report.proved_optimal); // proves KWOK's placement optimal
        assert_eq!(report.placed_after, vec![1]);
    }

    #[test]
    fn certified_unplaceable_pods_trigger_scale_up() {
        use crate::autoscaler::AutoscaleConfig;
        // One full node; a pending pod provably unplaceable on it. With
        // autoscale armed, the certificate buys the cheapest node that
        // hosts the pod and binds it — all in one pass.
        let pods = vec![
            Pod::new(0, "resident", Resources::new(900, 900), Priority(0)),
            Pod::new(1, "stuck", Resources::new(800, 800), Priority(0)),
        ];
        let mut state =
            ClusterState::new(identical_nodes(1, Resources::new(1000, 1000)), pods);
        state.bind(PodId(0), crate::cluster::NodeId(0)).unwrap();
        let cfg = OptimizerConfig::with_timeout(5.0).with_autoscale(AutoscaleConfig {
            provision_timeout: std::time::Duration::from_secs(5),
            ..AutoscaleConfig::default()
        });
        let mut osched = OptimizingScheduler::new(0, cfg);
        let report = osched.run(&mut state);
        assert!(report.solver_invoked);
        let up = report.autoscale.expect("certified pending pod must scale up");
        assert!(up.applied);
        assert!(up.certified, "tiny provisioning model certifies both phases");
        assert!(up.nodes_added >= 1);
        assert!(up.cost >= up.cost_bound && up.cost_bound > 0);
        assert_eq!(state.pending_pods(), Vec::<PodId>::new());
        assert!(report.improved, "the joined node placed the stuck pod");
        state.check_invariants().unwrap();
        assert!(state
            .events
            .all()
            .iter()
            .any(|e| matches!(e, Event::NodeJoined { .. })));
    }

    #[test]
    fn scale_up_reference_is_snapshotted_not_ratcheted() {
        use crate::autoscaler::AutoscaleConfig;
        // First scale-up joins a `large` (1500m at reference 1000m). A
        // later scale-up must size its candidates from the SAME
        // reference — deriving from the live fleet would make the next
        // large 2250m at the same cost (geometric ratchet).
        let pods = vec![
            Pod::new(0, "resident", Resources::new(1000, 1000), Priority(0)),
            Pod::new(1, "stuck-1", Resources::new(800, 800), Priority(0)),
        ];
        let mut state =
            ClusterState::new(identical_nodes(1, Resources::new(1000, 1000)), pods);
        state.bind(PodId(0), crate::cluster::NodeId(0)).unwrap();
        let cfg = OptimizerConfig::with_timeout(5.0).with_autoscale(AutoscaleConfig {
            provision_timeout: std::time::Duration::from_secs(5),
            ..AutoscaleConfig::default()
        });
        let mut osched = OptimizingScheduler::new(0, cfg);
        assert!(osched.run(&mut state).autoscale.expect("first scale-up").applied);
        assert_eq!(
            state.nodes().last().unwrap().capacity,
            Resources::new(1500, 1500),
            "800m pod needs the large pool at reference 1000m"
        );

        // Second stuck pod: 800m fits neither the full original node nor
        // the joined large's 700m residual, even re-packed.
        state.add_pod(Pod::new(0, "stuck-2", Resources::new(800, 800), Priority(0)));
        let up2 = osched.run(&mut state).autoscale.expect("second scale-up");
        assert!(up2.applied);
        assert_eq!(
            state.nodes().last().unwrap().capacity,
            Resources::new(1500, 1500),
            "reference snapshot: still 1500m, not 2250m"
        );
        state.check_invariants().unwrap();
    }

    #[test]
    fn provisioning_failures_are_memoized_for_unchanged_clusters() {
        use crate::autoscaler::AutoscaleConfig;
        // A pod no pool can host: proven infeasible. Re-running on the
        // unchanged cluster must replay the memoized outcome instead of
        // re-solving the provisioning model.
        let pods = vec![Pod::new(0, "xxl", Resources::new(99_999, 99_999), Priority(0))];
        let mut state =
            ClusterState::new(identical_nodes(1, Resources::new(1000, 1000)), pods);
        let cfg = OptimizerConfig::with_timeout(5.0).with_autoscale(AutoscaleConfig {
            provision_timeout: std::time::Duration::from_secs(5),
            ..AutoscaleConfig::default()
        });
        let mut osched = OptimizingScheduler::new(0, cfg);
        let first = osched.run(&mut state).autoscale.expect("outcome recorded");
        assert!(first.proven_infeasible);
        assert!(osched.provision_memo.is_some(), "failure memoized");

        let second = osched.run(&mut state).autoscale.expect("replayed outcome");
        assert!(second.proven_infeasible);
        assert_eq!(second.per_pool, first.per_pool);
        assert!(osched.provision_memo.is_some(), "memo survives the replay");
        assert_eq!(state.nodes().len(), 1, "fleet untouched throughout");
    }

    #[test]
    fn autoscale_stays_idle_without_certified_pending() {
        use crate::autoscaler::AutoscaleConfig;
        let mut state = ClusterState::new(
            identical_nodes(2, Resources::new(4000, 4096)),
            figure1_pods(),
        );
        let cfg = OptimizerConfig::with_timeout(5.0).with_autoscale(AutoscaleConfig::default());
        let mut osched = OptimizingScheduler::new(0, cfg);
        let report = osched.run(&mut state);
        // the re-pack places everything; nothing is certified-stuck
        assert_eq!(report.placed_after, vec![3]);
        assert!(report.autoscale.is_none(), "no certificate, no scale-up");
        assert_eq!(state.nodes().len(), 2, "fleet untouched");
    }

    #[test]
    fn priorities_respected_in_fallback() {
        // Low-priority pods already run on both nodes; a high-priority pod
        // arrives pending. The optimiser must place the high-priority pod
        // even at the cost of displacing a low one (cross-node pre-emption
        // that the default scheduler, with DefaultPreemption disabled,
        // cannot perform).
        let pods = vec![
            Pod::new(0, "lo-1", Resources::new(600, 600), Priority(1)),
            Pod::new(1, "lo-2", Resources::new(600, 600), Priority(1)),
            Pod::new(2, "hi", Resources::new(900, 900), Priority(0)),
        ];
        let mut state = ClusterState::new(identical_nodes(2, Resources::new(1000, 1000)), pods);
        state.bind(PodId(0), crate::cluster::NodeId(0)).unwrap();
        state.bind(PodId(1), crate::cluster::NodeId(1)).unwrap();
        let mut osched = OptimizingScheduler::new(1, OptimizerConfig::with_timeout(5.0));
        let report = osched.run(&mut state);
        assert!(report.solver_invoked);
        assert!(report.improved);
        // hi placed; exactly one lo survives (the other node can't fit two lo)
        assert!(state.assignment_of(PodId(2)).is_some());
        assert_eq!(report.placed_after, vec![1, 1]);
    }
}
