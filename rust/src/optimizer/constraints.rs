//! Composable constraint modules — the extensible vocabulary of the
//! packing model.
//!
//! The paper's model hard-codes three constraint families (at-most-one
//! placement, CPU knapsack, RAM knapsack). SAGE-style deployment solvers
//! pay off precisely when they encode the *full* constraint surface, so
//! this module turns each family into a [`ConstraintModule`] and lets
//! [`PackingModelBuilder`](super::builder::PackingModelBuilder) assemble
//! the per-tier model from whatever set is registered. A module
//! contributes through three hooks:
//!
//! * [`ConstraintModule::admits`] — variable admissibility: veto a
//!   (pod, node) pair before a decision variable is even created
//!   (cheaper than a constraint, and it shrinks the search space);
//! * [`ConstraintModule::emit`] — append the module's linear constraints
//!   over the built variable table;
//! * [`ConstraintModule::audit`] — check a finished assignment against
//!   the module's semantics (used by parity tests and debug builds).
//!
//! Every built-in module mirrors a scheduler-framework Filter plugin
//! (`scheduler::plugins`), so the CP optimiser and the default scheduler
//! provably agree on single-pod feasibility — the property pinned by the
//! CP/filter parity proptest in `rust/tests/constraints.rs`.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::rc::Rc;

use crate::cluster::{ClusterState, Node, NodeId, Pod};
use crate::solver::{LinearExpr, Model};

use super::builder::ModelCtx;

/// One composable constraint family of the packing model.
pub trait ConstraintModule {
    fn name(&self) -> &'static str;

    /// Variable admissibility: may `pod` ever be (newly) placed on
    /// `node`? Pairs vetoed here get no decision variable. The builder
    /// exempts a pod's *current* node from lifecycle readiness but not
    /// from this hook — a bound pod always satisfies it because
    /// [`ClusterState::bind`] enforces the same vocabulary.
    fn admits(&self, _state: &ClusterState, _pod: &Pod, _node: &Node) -> bool {
        true
    }

    /// Append this module's constraints for the tier being built.
    fn emit(&self, ctx: &ModelCtx, m: &mut Model);

    /// Audit a finished assignment (`target[pod] = node`) against this
    /// module's semantics. Default: vacuously fine.
    fn audit(
        &self,
        _state: &ClusterState,
        _target: &[Option<NodeId>],
    ) -> Result<(), String> {
        Ok(())
    }

    /// Cache identity of this module *including any internal
    /// configuration*. The incremental session layer
    /// (`optimizer::session`) replays whole cached results only while
    /// every registered module's fingerprint is unchanged, so a module
    /// carrying parameters (budgets, quarantined nodes, …) MUST fold
    /// them into this hash — the name-only default is correct for
    /// stateless modules only.
    fn fingerprint(&self) -> u64 {
        crate::util::fingerprint::Fnv64::new()
            .write_str(self.name())
            .finish()
    }
}

/// Sum of a pod's requests for one named extended resource.
fn ext_demand(pod: &Pod, resource: &str) -> i64 {
    pod.extended
        .iter()
        .filter(|(k, _)| k == resource)
        .map(|&(_, v)| v)
        .sum()
}

// ---------------------------------------------------------------------------
// Built-in modules
// ---------------------------------------------------------------------------

/// Constraint (3) of the paper: every pod lands on at most one node.
#[derive(Clone, Copy, Debug, Default)]
pub struct AtMostOnePlacement;

impl ConstraintModule for AtMostOnePlacement {
    fn name(&self) -> &'static str {
        "AtMostOnePlacement"
    }

    fn emit(&self, ctx: &ModelCtx, m: &mut Model) {
        for i in ctx.table.eligible_pods() {
            let amo = LinearExpr::of(
                (0..ctx.state.nodes().len()).filter_map(|j| ctx.table.var(i, j).map(|v| (v, 1))),
            );
            if !amo.terms.is_empty() {
                m.add_le(amo, 1);
            }
        }
    }
}

/// Constraints (1) and (2), generalised to N named resource dimensions:
/// per node, one knapsack per dimension — CPU, RAM, and every extended
/// resource (GPU, ephemeral storage, …) any tier pod requests. Each
/// dimension is declared as a named resource class so the solver's
/// aggregate capacity bound covers it.
#[derive(Clone, Copy, Debug, Default)]
pub struct NodeCapacity;

impl ConstraintModule for NodeCapacity {
    fn name(&self) -> &'static str {
        "NodeCapacity"
    }

    fn emit(&self, ctx: &ModelCtx, m: &mut Model) {
        let state = ctx.state;
        let nodes = state.nodes();
        let table = ctx.table;

        let mut cpu_class = Vec::with_capacity(nodes.len());
        let mut ram_class = Vec::with_capacity(nodes.len());
        for (j, node) in nodes.iter().enumerate() {
            let mut cpu = LinearExpr::new();
            let mut ram = LinearExpr::new();
            for i in table.eligible_pods() {
                if let Some(v) = table.var(i, j) {
                    let req = state.pods()[i].request;
                    cpu.add(v, req.cpu);
                    ram.add(v, req.ram);
                }
            }
            if !cpu.terms.is_empty() {
                cpu_class.push(m.next_constraint_index());
                m.add_le(cpu, node.capacity.cpu);
            }
            if !ram.terms.is_empty() {
                ram_class.push(m.next_constraint_index());
                m.add_le(ram, node.capacity.ram);
            }
        }
        if !cpu_class.is_empty() {
            m.add_named_resource_class("cpu", cpu_class);
        }
        if !ram_class.is_empty() {
            m.add_named_resource_class("ram", ram_class);
        }

        // Extended dimensions requested by any tier pod, in name order.
        let dims: BTreeSet<&str> = table
            .eligible_pods()
            .flat_map(|i| state.pods()[i].extended.iter())
            .filter(|(_, amt)| *amt > 0)
            .map(|(k, _)| k.as_str())
            .collect();
        for dim in dims {
            let mut class = Vec::with_capacity(nodes.len());
            for (j, node) in nodes.iter().enumerate() {
                let mut e = LinearExpr::new();
                for i in table.eligible_pods() {
                    let d = ext_demand(&state.pods()[i], dim);
                    if d > 0 {
                        if let Some(v) = table.var(i, j) {
                            e.add(v, d);
                        }
                    }
                }
                if !e.terms.is_empty() {
                    class.push(m.next_constraint_index());
                    m.add_le(e, node.extended_capacity(dim));
                }
            }
            if !class.is_empty() {
                m.add_named_resource_class(dim, class);
            }
        }
    }

    fn audit(&self, state: &ClusterState, target: &[Option<NodeId>]) -> Result<(), String> {
        let nodes = state.nodes();
        let mut used = vec![crate::cluster::Resources::ZERO; nodes.len()];
        let mut used_ext: Vec<BTreeMap<&str, i64>> = vec![BTreeMap::new(); nodes.len()];
        for (i, t) in target.iter().enumerate() {
            if let Some(n) = t {
                used[n.idx()] += state.pods()[i].request;
                for (k, amt) in &state.pods()[i].extended {
                    *used_ext[n.idx()].entry(k.as_str()).or_insert(0) += amt;
                }
            }
        }
        for (j, node) in nodes.iter().enumerate() {
            if (node.capacity - used[j]).any_negative() {
                return Err(format!("node {} over capacity", node.name));
            }
            for (k, amt) in &used_ext[j] {
                if *amt > node.extended_capacity(k) {
                    return Err(format!("node {} over {k:?} capacity", node.name));
                }
            }
        }
        Ok(())
    }
}

/// Required node labels (the paper's future-work affinity hook, already
/// present on the seed types). Pure admissibility — no constraints.
#[derive(Clone, Copy, Debug, Default)]
pub struct NodeSelector;

impl ConstraintModule for NodeSelector {
    fn name(&self) -> &'static str {
        "NodeSelector"
    }

    fn admits(&self, _state: &ClusterState, pod: &Pod, node: &Node) -> bool {
        pod.selector_matches(node)
    }

    fn emit(&self, _ctx: &ModelCtx, _m: &mut Model) {}

    fn audit(&self, state: &ClusterState, target: &[Option<NodeId>]) -> Result<(), String> {
        for (i, t) in target.iter().enumerate() {
            if let Some(n) = t {
                let pod = &state.pods()[i];
                if !pod.selector_matches(state.node(*n)) && state.assignment_of(pod.id) != Some(*n)
                {
                    return Err(format!("pod {} placed against its selector", pod.name));
                }
            }
        }
        Ok(())
    }
}

/// `NoSchedule` taints: an untolerated node accepts no new placements,
/// though a resident pod may stay (the builder's home-node exemption
/// never applies here because `bind` enforces tolerations too).
#[derive(Clone, Copy, Debug, Default)]
pub struct TaintsTolerations;

impl ConstraintModule for TaintsTolerations {
    fn name(&self) -> &'static str {
        "TaintsTolerations"
    }

    fn admits(&self, _state: &ClusterState, pod: &Pod, node: &Node) -> bool {
        pod.tolerates(node)
    }

    fn emit(&self, _ctx: &ModelCtx, _m: &mut Model) {}

    fn audit(&self, state: &ClusterState, target: &[Option<NodeId>]) -> Result<(), String> {
        for (i, t) in target.iter().enumerate() {
            if let Some(n) = t {
                let pod = &state.pods()[i];
                if !pod.tolerates(state.node(*n)) && state.assignment_of(pod.id) != Some(*n) {
                    return Err(format!("pod {} placed on untolerated node", pod.name));
                }
            }
        }
        Ok(())
    }
}

/// Pairwise pod anti-affinity: two pods that exclude each other (in
/// either direction, matching the Kubernetes InterPodAffinity filter)
/// never share a node — `x_ij + x_kj ≤ 1` on every common candidate.
#[derive(Clone, Copy, Debug, Default)]
pub struct PodAntiAffinity;

impl ConstraintModule for PodAntiAffinity {
    fn name(&self) -> &'static str {
        "PodAntiAffinity"
    }

    fn emit(&self, ctx: &ModelCtx, m: &mut Model) {
        let state = ctx.state;
        let pods = state.pods();
        let eligible: Vec<usize> = ctx.table.eligible_pods().collect();
        for (x, &i) in eligible.iter().enumerate() {
            for &k in &eligible[x + 1..] {
                let (a, b) = (&pods[i], &pods[k]);
                if a.anti_affinity.is_empty() && b.anti_affinity.is_empty() {
                    continue;
                }
                if !(a.anti_affine_with(b) || b.anti_affine_with(a)) {
                    continue;
                }
                for j in 0..state.nodes().len() {
                    if let (Some(vi), Some(vk)) = (ctx.table.var(i, j), ctx.table.var(k, j)) {
                        // Coefficient 2 on purpose: `2x + 2y ≤ 2` is the
                        // same exclusion as `x + y ≤ 1`, but the search
                        // engine classifies unit-coefficient/rhs-1 rows
                        // as at-most-one groups and drops them from its
                        // symmetry signatures — which would let node
                        // symmetry-skipping prune past an asymmetric
                        // anti-affinity pair.
                        m.add_le(LinearExpr::of([(vi, 2), (vk, 2)]), 2);
                    }
                }
            }
        }
    }

    fn audit(&self, state: &ClusterState, target: &[Option<NodeId>]) -> Result<(), String> {
        let pods = state.pods();
        for (i, ti) in target.iter().enumerate() {
            let Some(ni) = ti else { continue };
            for (k, tk) in target.iter().enumerate().skip(i + 1) {
                if tk != &Some(*ni) {
                    continue;
                }
                let (a, b) = (&pods[i], &pods[k]);
                if a.anti_affine_with(b) || b.anti_affine_with(a) {
                    return Err(format!(
                        "anti-affine pods {} and {} share a node",
                        a.name, b.name
                    ));
                }
            }
        }
        Ok(())
    }
}

/// Per-ReplicaSet topology spread over the node topology: for every
/// owner group declaring a max skew, the placed-replica counts of any
/// two candidate nodes may differ by at most that skew.
#[derive(Clone, Copy, Debug, Default)]
pub struct TopologySpread;

impl ConstraintModule for TopologySpread {
    fn name(&self) -> &'static str {
        "TopologySpread"
    }

    fn emit(&self, ctx: &ModelCtx, m: &mut Model) {
        let state = ctx.state;
        let pods = state.pods();
        // owner → eligible member pods
        let mut groups: BTreeMap<u32, Vec<usize>> = BTreeMap::new();
        for i in ctx.table.eligible_pods() {
            if let Some(owner) = pods[i].owner {
                groups.entry(owner).or_default().push(i);
            }
        }
        for members in groups.values() {
            let Some(skew) = members
                .iter()
                .filter_map(|&i| pods[i].spread_max_skew)
                .min()
            else {
                continue;
            };
            // candidate nodes = nodes where any member has a variable
            let domain: Vec<usize> = (0..state.nodes().len())
                .filter(|&j| members.iter().any(|&i| ctx.table.var(i, j).is_some()))
                .collect();
            if domain.len() < 2 {
                continue;
            }
            let count_terms: Vec<Vec<(crate::solver::VarId, i64)>> = domain
                .iter()
                .map(|&j| {
                    members
                        .iter()
                        .filter_map(|&i| ctx.table.var(i, j).map(|v| (v, 1)))
                        .collect()
                })
                .collect();
            for a in 0..domain.len() {
                for b in 0..domain.len() {
                    if a == b {
                        continue;
                    }
                    // count(a) − count(b) ≤ skew
                    let mut e = LinearExpr::of(count_terms[a].iter().copied());
                    for &(v, _) in &count_terms[b] {
                        e.add(v, -1);
                    }
                    m.add_le(e, skew);
                }
            }
        }
    }

    /// Occupied-domain audit: a necessary condition of the emitted
    /// pairwise constraints (max − min over *occupied* nodes ≤ skew).
    /// Empty candidate domains are not re-derived here because they
    /// depend on every module's `admits` hook.
    fn audit(&self, state: &ClusterState, target: &[Option<NodeId>]) -> Result<(), String> {
        let pods = state.pods();
        let mut counts: BTreeMap<u32, BTreeMap<NodeId, i64>> = BTreeMap::new();
        let mut skews: BTreeMap<u32, i64> = BTreeMap::new();
        for (i, t) in target.iter().enumerate() {
            let (Some(n), Some(owner)) = (t, pods[i].owner) else {
                continue;
            };
            *counts.entry(owner).or_default().entry(*n).or_insert(0) += 1;
            if let Some(s) = pods[i].spread_max_skew {
                let e = skews.entry(owner).or_insert(s);
                *e = (*e).min(s);
            }
        }
        for (owner, skew) in skews {
            let per_node = &counts[&owner];
            let max = per_node.values().max().copied().unwrap_or(0);
            let min = per_node.values().min().copied().unwrap_or(0);
            if max - min > skew {
                return Err(format!(
                    "owner group {owner} skew {} exceeds max {skew}",
                    max - min
                ));
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// An ordered set of constraint modules. Cloning is cheap (modules are
/// shared behind `Rc`), which lets [`OptimizerConfig`] stay `Clone`.
///
/// [`OptimizerConfig`]: super::algorithm::OptimizerConfig
#[derive(Clone)]
pub struct ModuleRegistry {
    modules: Vec<Rc<dyn ConstraintModule>>,
}

impl ModuleRegistry {
    /// No modules at all — only useful as a base for [`Self::with`].
    pub fn empty() -> Self {
        ModuleRegistry {
            modules: Vec::new(),
        }
    }

    /// The full built-in vocabulary: placement, N-dimensional capacity,
    /// node selectors, taints/tolerations, pod anti-affinity, and
    /// topology spread. With constraint-free workloads this produces the
    /// exact model of the paper's original `build_model`.
    pub fn standard() -> Self {
        ModuleRegistry::empty()
            .with(AtMostOnePlacement)
            .with(NodeCapacity)
            .with(NodeSelector)
            .with(TaintsTolerations)
            .with(PodAntiAffinity)
            .with(TopologySpread)
    }

    /// The paper's original constraint vocabulary only: at-most-one
    /// placement, resource knapsacks, node selectors.
    pub fn resource_only() -> Self {
        ModuleRegistry::empty()
            .with(AtMostOnePlacement)
            .with(NodeCapacity)
            .with(NodeSelector)
    }

    /// Append a module (builder style).
    pub fn with(mut self, module: impl ConstraintModule + 'static) -> Self {
        self.register(module);
        self
    }

    /// Append a module in place.
    pub fn register(&mut self, module: impl ConstraintModule + 'static) -> &mut Self {
        self.modules.push(Rc::new(module));
        self
    }

    pub fn modules(&self) -> &[Rc<dyn ConstraintModule>] {
        &self.modules
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.modules.iter().map(|m| m.name()).collect()
    }

    /// Per-module cache fingerprints, in registration order (see
    /// [`ConstraintModule::fingerprint`]).
    pub fn fingerprints(&self) -> Vec<u64> {
        self.modules.iter().map(|m| m.fingerprint()).collect()
    }

    /// Conjunction of every module's admissibility hook.
    pub fn admits(&self, state: &ClusterState, pod: &Pod, node: &Node) -> bool {
        self.modules.iter().all(|m| m.admits(state, pod, node))
    }

    /// Run every module's audit over a finished assignment; the first
    /// failure is returned prefixed with the offending module's name.
    pub fn audit(&self, state: &ClusterState, target: &[Option<NodeId>]) -> Result<(), String> {
        for m in &self.modules {
            m.audit(state, target)
                .map_err(|e| format!("{}: {e}", m.name()))?;
        }
        Ok(())
    }
}

impl Default for ModuleRegistry {
    fn default() -> Self {
        ModuleRegistry::standard()
    }
}

impl fmt::Debug for ModuleRegistry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_tuple("ModuleRegistry").field(&self.names()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Priority, Resources};
    use crate::optimizer::builder::PackingModelBuilder;

    fn build(state: &ClusterState, tier: u32) -> (Model, crate::optimizer::builder::VarTable) {
        let reg = ModuleRegistry::standard();
        PackingModelBuilder::new(state, tier, &reg).build()
    }

    #[test]
    fn registry_names_in_order() {
        assert_eq!(
            ModuleRegistry::standard().names(),
            vec![
                "AtMostOnePlacement",
                "NodeCapacity",
                "NodeSelector",
                "TaintsTolerations",
                "PodAntiAffinity",
                "TopologySpread"
            ]
        );
    }

    #[test]
    fn anti_affinity_emits_pairwise_exclusions() {
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "a", Resources::new(1, 1), Priority(0))
                .with_label("app", "x")
                .with_anti_affinity("app", "x"),
            Pod::new(1, "b", Resources::new(1, 1), Priority(0)).with_label("app", "x"),
        ];
        let st = ClusterState::new(nodes, pods);
        let (m, table) = build(&st, 0);
        // both pods on node 0 must be infeasible
        let mut values = vec![false; m.num_vars()];
        values[table.var(0, 0).unwrap().idx()] = true;
        values[table.var(1, 0).unwrap().idx()] = true;
        assert!(!m.feasible(&values));
        // split across nodes is fine
        let mut split = vec![false; m.num_vars()];
        split[table.var(0, 0).unwrap().idx()] = true;
        split[table.var(1, 1).unwrap().idx()] = true;
        assert!(m.feasible(&split));
    }

    #[test]
    fn extended_resources_get_their_own_class() {
        let mut nodes = identical_nodes(2, Resources::new(1000, 1000));
        nodes[1] = nodes[1].clone().with_extended("gpu", 1);
        let pods = vec![
            Pod::new(0, "g", Resources::new(1, 1), Priority(0)).with_extended("gpu", 1),
            Pod::new(1, "h", Resources::new(1, 1), Priority(0)).with_extended("gpu", 1),
        ];
        let st = ClusterState::new(nodes, pods);
        let (m, table) = build(&st, 0);
        assert!(m
            .resource_classes
            .iter()
            .any(|c| c.name == "gpu" && !c.cons.is_empty()));
        // both gpu pods on the single-gpu node: infeasible
        let mut values = vec![false; m.num_vars()];
        values[table.var(0, 1).unwrap().idx()] = true;
        values[table.var(1, 1).unwrap().idx()] = true;
        assert!(!m.feasible(&values));
        // gpu pod on the gpu-less node: also infeasible (capacity 0)
        let mut wrong = vec![false; m.num_vars()];
        wrong[table.var(0, 0).unwrap().idx()] = true;
        assert!(!m.feasible(&wrong));
    }

    #[test]
    fn topology_spread_bounds_pairwise_skew() {
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods: Vec<Pod> = (0..3)
            .map(|i| {
                Pod::new(i, format!("g-{i}"), Resources::new(1, 1), Priority(0))
                    .with_owner(7)
                    .with_spread(1)
            })
            .collect();
        let st = ClusterState::new(nodes, pods);
        let (m, table) = build(&st, 0);
        // 3 on one node, 0 on the other: skew 3 > 1
        let mut lopsided = vec![false; m.num_vars()];
        for i in 0..3 {
            lopsided[table.var(i, 0).unwrap().idx()] = true;
        }
        assert!(!m.feasible(&lopsided));
        // 2 + 1 split: skew 1, fine
        let mut split = vec![false; m.num_vars()];
        split[table.var(0, 0).unwrap().idx()] = true;
        split[table.var(1, 0).unwrap().idx()] = true;
        split[table.var(2, 1).unwrap().idx()] = true;
        assert!(m.feasible(&split));
    }

    #[test]
    fn audit_reports_offending_module() {
        let nodes = identical_nodes(1, Resources::new(10, 10));
        let pods = vec![Pod::new(0, "xl", Resources::new(100, 100), Priority(0))];
        let st = ClusterState::new(nodes, pods);
        let err = ModuleRegistry::standard()
            .audit(&st, &[Some(NodeId(0))])
            .unwrap_err();
        assert!(err.starts_with("NodeCapacity:"), "{err}");
        assert!(ModuleRegistry::standard().audit(&st, &[None]).is_ok());
    }
}
