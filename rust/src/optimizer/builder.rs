//! `PackingModelBuilder` — assembles the per-tier CP model from the
//! registered [`ConstraintModule`]s instead of one hard-coded function.
//!
//! The builder owns the two things every module needs to agree on:
//!
//! 1. **The variable table.** One binary variable per (pod, node) pair
//!    that is *admissible*: the pod is in the tier (priority ≤ `pr`, not
//!    retired) and the node either accepts new placements (`Ready`) or is
//!    the pod's current home (descheduler semantics: a resident pod may
//!    stay on a cordoned node, it just can't be joined there), and every
//!    registered module's [`ConstraintModule::admits`] hook agrees.
//!    Inadmissible pairs get no variable at all — the solver never even
//!    branches on them.
//! 2. **The emission pass.** Modules run in registration order, each
//!    appending its constraint family to the model through
//!    [`ConstraintModule::emit`] with read access to the table via
//!    [`ModelCtx`].
//!
//! With the standard registry and a constraint-free workload this
//! produces byte-for-byte the same model (same variable ids, same
//! constraint order) as the original monolithic `build_model`, which is
//! what keeps the paper-scenario results identical.

use crate::cluster::ClusterState;
use crate::solver::{Model, VarId};

use super::constraints::ModuleRegistry;

/// Tier-filtered variable table: `vars[pod] = Some(per-node VarIds)` for
/// pods with priority ≤ the tier; `None` per node marks an inadmissible
/// pair.
pub struct VarTable {
    vars: Vec<Option<Vec<Option<VarId>>>>,
}

impl VarTable {
    /// The variable for `(pod, node)`, if the pair is admissible.
    pub fn var(&self, pod: usize, node: usize) -> Option<VarId> {
        self.vars[pod].as_ref().and_then(|ns| ns[node])
    }

    /// Whether `pod` is part of this tier's model at all.
    pub fn is_eligible(&self, pod: usize) -> bool {
        self.vars[pod].is_some()
    }

    /// Pods that are part of this tier's model, in id order.
    pub fn eligible_pods(&self) -> impl Iterator<Item = usize> + '_ {
        self.vars
            .iter()
            .enumerate()
            .filter_map(|(i, v)| v.is_some().then_some(i))
    }
}

/// Read-only context handed to [`ConstraintModule::emit`].
///
/// [`ConstraintModule::emit`]: super::constraints::ConstraintModule::emit
/// [`ConstraintModule::admits`]: super::constraints::ConstraintModule::admits
pub struct ModelCtx<'a> {
    pub state: &'a ClusterState,
    /// Priority tier being solved (pods with priority ≤ tier participate).
    pub tier: u32,
    pub table: &'a VarTable,
}

/// Assembles one tier's model from a module registry.
pub struct PackingModelBuilder<'a> {
    state: &'a ClusterState,
    tier: u32,
    registry: &'a ModuleRegistry,
}

impl<'a> PackingModelBuilder<'a> {
    pub fn new(state: &'a ClusterState, tier: u32, registry: &'a ModuleRegistry) -> Self {
        PackingModelBuilder {
            state,
            tier,
            registry,
        }
    }

    /// Build the variable table and run every module's emission pass.
    pub fn build(self) -> (Model, VarTable) {
        let mut m = Model::new();
        let nodes = self.state.nodes();
        let mut vars: Vec<Option<Vec<Option<VarId>>>> = vec![None; self.state.pods().len()];

        for pod in self.state.pods() {
            if pod.priority.0 > self.tier || self.state.is_retired(pod.id) {
                continue;
            }
            let home = self.state.assignment_of(pod.id);
            let per_node: Vec<Option<VarId>> = nodes
                .iter()
                .map(|n| {
                    let lifecycle_ok = self.state.node_ready(n.id) || home == Some(n.id);
                    (lifecycle_ok && self.registry.admits(self.state, pod, n))
                        .then(|| m.new_var())
                })
                .collect();
            vars[pod.id.idx()] = Some(per_node);
        }

        let table = VarTable { vars };
        let ctx = ModelCtx {
            state: self.state,
            tier: self.tier,
            table: &table,
        };
        for module in self.registry.modules() {
            let from = m.next_constraint_index();
            module.emit(&ctx, &mut m);
            // Solve forensics: every emitted row carries its module's
            // provenance slug, so solver effort maps back to semantics.
            m.tag_constraints(from, provenance_slug(module.name()));
        }
        // Refine capacity rows per declared resource dimension: the
        // profiler reports capacity:cpu vs capacity:ram, not one
        // undifferentiated capacity bucket.
        let refinements: Vec<(String, Vec<u32>)> = m
            .resource_classes
            .iter()
            .filter(|c| !c.name.is_empty())
            .map(|c| (format!("capacity:{}", c.name), c.cons.clone()))
            .collect();
        for (slug, cons) in refinements {
            for ci in cons {
                m.tag_constraint(ci as usize, &slug);
            }
        }
        (m, table)
    }
}

/// Provenance slug for a constraint module — the stable labels the
/// solve-forensics profiler attributes effort under. Built-in modules
/// get the short names the paper uses; custom modules fall back to
/// their registered name verbatim.
fn provenance_slug(module: &str) -> &str {
    match module {
        "AtMostOnePlacement" => "placement",
        "NodeCapacity" => "capacity",
        "NodeSelector" => "selector",
        "TaintsTolerations" => "taints",
        "PodAntiAffinity" => "anti-affinity",
        "TopologySpread" => "spread",
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, NodeId, Pod, PodId, Priority, Resources, Taint};

    fn state() -> ClusterState {
        let mut nodes = identical_nodes(2, Resources::new(1000, 1000));
        nodes[0] = nodes[0]
            .clone()
            .with_taint(Taint::no_schedule("dedicated", "batch"));
        let pods = vec![
            Pod::new(0, "a", Resources::new(100, 100), Priority(0)),
            Pod::new(1, "b", Resources::new(100, 100), Priority(1)),
        ];
        ClusterState::new(nodes, pods)
    }

    #[test]
    fn tier_filters_pods_and_admits_filters_nodes() {
        let st = state();
        let reg = ModuleRegistry::standard();
        let (m, table) = PackingModelBuilder::new(&st, 0, &reg).build();
        // pod 1 (priority 1) is out of tier 0
        assert!(table.is_eligible(0));
        assert!(!table.is_eligible(1));
        // node 0 is tainted and the pod has no toleration
        assert_eq!(table.var(0, 0), None);
        assert!(table.var(0, 1).is_some());
        assert_eq!(m.num_vars(), 1);
    }

    #[test]
    fn emitted_rows_carry_module_provenance() {
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "a", Resources::new(100, 100), Priority(0)),
            Pod::new(1, "b", Resources::new(100, 100), Priority(0)),
        ];
        let st = ClusterState::new(nodes, pods);
        let reg = ModuleRegistry::standard();
        let (m, _) = PackingModelBuilder::new(&st, 0, &reg).build();
        let slugs: Vec<&str> = (0..m.constraints.len())
            .map(|ci| m.constraint_provenance(ci))
            .collect();
        assert!(slugs.contains(&"placement"));
        // Capacity rows refined per declared dimension.
        assert!(slugs.contains(&"capacity:cpu"));
        assert!(slugs.contains(&"capacity:ram"));
        // Nothing left untagged in a builder-produced model.
        assert!(!slugs.contains(&crate::solver::UNTAGGED_PROVENANCE));
    }

    #[test]
    fn home_node_keeps_a_variable_on_cordoned_node() {
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![Pod::new(0, "a", Resources::new(100, 100), Priority(0))];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        st.cordon(NodeId(0));
        let reg = ModuleRegistry::standard();
        let (_, table) = PackingModelBuilder::new(&st, 0, &reg).build();
        assert!(table.var(0, 0).is_some(), "resident pod may stay home");
        assert!(table.var(0, 1).is_some());
    }
}
