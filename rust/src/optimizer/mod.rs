//! The paper's contribution: constraint-based pod packing as a fallback
//! to the default scheduler.
//!
//! * [`algorithm`] — Algorithm 1: the per-priority two-phase optimisation
//!   loop (maximise placements, then minimise moves) with the α time
//!   budget and phase-locking constraints.
//! * [`plan`]      — diff a solver target against the live assignment
//!   into an executable eviction/placement plan (cross-node pre-emption
//!   with separate scheduling events, per the paper's Kubernetes-API
//!   workaround).
//! * [`plugin`]    — the scheduler-framework integration: queue pausing,
//!   PreFilter node pinning, PostFilter failure tracking, Reserve
//!   bookkeeping, PostBind plan completion — the five extension points
//!   the paper's Go plugin uses.

pub mod algorithm;
pub mod plan;
pub mod plugin;

pub use algorithm::{optimize, OptimizeResult, OptimizerConfig, TierReport};
pub use plan::MovePlan;
pub use plugin::OptimizingScheduler;
