//! The paper's contribution: constraint-based pod packing as a fallback
//! to the default scheduler.
//!
//! * [`algorithm`] — Algorithm 1: the per-priority two-phase optimisation
//!   loop (maximise placements, then minimise moves) with the α time
//!   budget and phase-locking constraints.
//! * [`constraints`] — the composable [`ConstraintModule`] vocabulary:
//!   at-most-one placement, N-dimensional node capacity, node selectors,
//!   taints/tolerations, pod anti-affinity, and topology spread, plus
//!   the [`ModuleRegistry`] they are assembled from.
//! * [`builder`]   — [`PackingModelBuilder`]: turns a cluster state, a
//!   priority tier, and a module registry into a solver [`Model`].
//! * [`plan`]      — diff a solver target against the live assignment
//!   into an executable eviction/placement plan (cross-node pre-emption
//!   with separate scheduling events, per the paper's Kubernetes-API
//!   workaround).
//! * [`plugin`]    — the scheduler-framework integration: queue pausing,
//!   PreFilter node pinning, PostFilter failure tracking, Reserve
//!   bookkeeping, PostBind plan completion — the five extension points
//!   the paper's Go plugin uses.
//! * [`session`]   — incremental solve sessions for drivers that re-run
//!   Algorithm 1 over an evolving cluster: full-state and per-component
//!   certificate replay plus warm-start incumbent floors, byte-identical
//!   to cold solves (the `incremental` knob / `--incremental` flags).
//!
//! # Adding a custom constraint
//!
//! The per-tier model is assembled from whatever modules the
//! [`OptimizerConfig`]'s registry holds, so a new constraint family
//! never touches the solver core. A module that quarantines one node
//! from all `batch-*` pods, end to end:
//!
//! ```
//! use kube_packd::cluster::{ClusterState, Node, NodeId, Pod};
//! use kube_packd::optimizer::constraints::{ConstraintModule, ModuleRegistry};
//! use kube_packd::optimizer::builder::ModelCtx;
//! use kube_packd::optimizer::OptimizerConfig;
//! use kube_packd::solver::Model;
//!
//! struct Quarantine {
//!     node: NodeId,
//! }
//!
//! impl ConstraintModule for Quarantine {
//!     fn name(&self) -> &'static str {
//!         "Quarantine"
//!     }
//!     // Veto (pod, node) pairs before variables exist — the cheapest
//!     // way to encode a hard exclusion.
//!     fn admits(&self, _state: &ClusterState, pod: &Pod, node: &Node) -> bool {
//!         !(node.id == self.node && pod.name.starts_with("batch-"))
//!     }
//!     // Pairwise/aggregate families add linear constraints here instead.
//!     fn emit(&self, _ctx: &ModelCtx, _m: &mut Model) {}
//!     // Optional: vouch for finished assignments (runs in debug builds
//!     // and parity tests).
//!     fn audit(
//!         &self,
//!         state: &ClusterState,
//!         target: &[Option<NodeId>],
//!     ) -> Result<(), String> {
//!         for (i, t) in target.iter().enumerate() {
//!             if *t == Some(self.node) && state.pods()[i].name.starts_with("batch-") {
//!                 return Err(format!("batch pod {i} on quarantined node"));
//!             }
//!         }
//!         Ok(())
//!     }
//! }
//!
//! let cfg = OptimizerConfig::with_timeout(1.0)
//!     .with_modules(ModuleRegistry::standard().with(Quarantine { node: NodeId(0) }));
//! assert!(format!("{cfg:?}").contains("Quarantine"));
//! ```
//!
//! Mirror hard per-pod exclusions with a scheduler
//! [`FilterPlugin`](crate::scheduler::framework::FilterPlugin) so the
//! default scheduler agrees with the optimiser on feasibility; if the
//! two disagree, an executing plan can be rejected mid-flight, which the
//! driver surfaces as [`RunReport::plan_incomplete`] (graceful rollback)
//! rather than a crash.
//!
//! [`ConstraintModule`]: constraints::ConstraintModule
//! [`ModuleRegistry`]: constraints::ModuleRegistry
//! [`PackingModelBuilder`]: builder::PackingModelBuilder
//! [`Model`]: crate::solver::Model
//! [`RunReport::plan_incomplete`]: plugin::RunReport

pub mod algorithm;
pub mod builder;
pub mod constraints;
pub mod explain;
pub mod plan;
pub mod plugin;
pub mod session;

pub use algorithm::{
    optimize, optimize_probed, optimize_session, optimize_traced, OptimizeResult, OptimizerConfig,
    TierReport,
};
pub use builder::{ModelCtx, PackingModelBuilder, VarTable};
pub use constraints::{
    AtMostOnePlacement, ConstraintModule, ModuleRegistry, NodeCapacity, NodeSelector,
    PodAntiAffinity, TaintsTolerations, TopologySpread,
};
pub use explain::{explain_pod, node_rejection, ExplainReport};
pub use plan::MovePlan;
pub use plugin::{OptimizingScheduler, RunReport};
pub use session::{DeltaLog, SessionStats, SolveSession};
